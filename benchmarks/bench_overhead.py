"""Table-2 analogue: profiler overhead on a real (tiny) training run.

Runs the *same compiled* N-step loop with the tracer+sampler disabled and
enabled and reports O/H %, the critical-slice ratio (CR), profiler memory
(M) and post-processing time (PPT) — the columns of paper Table 2, measured
on this framework's training loop instead of Parsec.
"""
from __future__ import annotations

import time

import jax


def run():
    from repro import configs
    from repro.core.session import ProfileSession
    from repro.data.pipeline import PrefetchLoader, SyntheticLM
    from repro.optim import adamw
    from repro.train.step import make_train_step

    cfg = configs.get_tiny("deepseek-7b")
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    from repro.models import init_lm
    steps = 30

    def loop(gapp):
        src = SyntheticLM(cfg.vocab_size, 64, 4)
        loader = PrefetchLoader(src, depth=2, gapp=gapp)
        wid = gapp.register_worker("trainer", "host") if gapp else None
        params = init_lm(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        err = None
        if gapp:
            gapp.start()
        t0 = time.perf_counter()
        for _ in range(steps):
            batch = loader.get()          # blocking wait -> inactive
            if gapp:
                gapp.begin(wid, "train/step")
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            params, opt, m, err = step_fn(params, opt, batch, err)
            jax.block_until_ready(m["loss"])
            if gapp:
                gapp.end(wid)
        wall = time.perf_counter() - t0
        if gapp:
            gapp.stop()
        loader.stop()
        return wall

    import statistics
    loop(None)                     # compile warm-up
    # alternate off/on and take medians: on a shared 1-core host the wall
    # noise is comparable to the effect, so single samples can even go
    # negative
    offs, ons, gapps = [], [], []
    for _ in range(3):
        offs.append(loop(None))
        g = ProfileSession(dt=0.002)
        ons.append(loop(g))
        gapps.append(g)
    wall_off = statistics.median(offs)
    wall_on = statistics.median(ons)
    g = gapps[ons.index(wall_on)]
    overhead = (wall_on - wall_off) / wall_off * 100
    t0 = time.perf_counter()
    rep = g.snapshot()
    ppt = time.perf_counter() - t0
    mem = g.tracer.memory_bytes() + g.probe.buffer.times.nbytes * 3
    events = g.tracer.ring.total_events()
    rows = [
        ("overhead_train_loop", wall_on * 1e6 / steps,
         f"OH%={overhead:.1f};CR%={100 * rep.critical_ratio:.1f};"
         f"M_MB={mem / 2**20:.1f};PPT_s={ppt:.4f};slices={rep.total_slices}"),
        ("overhead_events_per_step", events / steps,
         f"ring_events={events};dropped={g.tracer.ring.dropped};"
         f"samples={len(g.probe.buffer)}"),
    ]
    return rows
