"""CMetric cost: per-event online probe cost + offline fold throughput.

Paper claim: the in-kernel probe is cheap enough for ~4% average overhead.
Our analogue: the probe microbenchmark (sharded lock-free hot path vs the
retained locked seed body, single-thread and contended — see
``bench_probe``), the offline backends' events/second (numpy oracle,
streaming scan, vectorised, Pallas fold), and the carry-resumable chunked
fold's throughput — the numbers behind the PPT column.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (FoldCarry, compute, compute_numpy, compute_streaming,
                        compute_vectorized, fold_chunk, synthetic_log)


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    # --- online probe cost: sharded hot path vs locked seed body ----------
    from benchmarks.bench_probe import run_probe
    p = run_probe(pairs=10_000, reps=2)
    rows.append(("cmetric_probe_pair", 2 * p["sharded_us_per_event_1t"],
                 f"events/s={1e6 / p['sharded_us_per_event_1t']:.0f};"
                 f"vs_locked_1t={p['speedup_1t']:.1f}x;"
                 f"vs_locked_{p['threads']}t={p['speedup_mt']:.1f}x"))
    rows.append(("cmetric_probe_pair_locked",
                 2 * p["locked_us_per_event_1t"],
                 f"events/s={1e6 / p['locked_us_per_event_1t']:.0f}"))

    # --- offline fold throughput ------------------------------------------
    rng = np.random.default_rng(0)
    log = synthetic_log(rng, 64, 4000)      # 512k events
    e = len(log)
    backends = {
        "numpy": lambda: compute_numpy(log),
        "stream": lambda: compute_streaming(log),
        "vector": lambda: compute_vectorized(log),
        "pallas_interp": lambda: compute(log, backend="pallas"),
    }
    for name, fn in backends.items():
        fn()                                 # warm up / compile
        dt = _time(fn, reps=2 if name != "numpy" else 1)
        rows.append((f"cmetric_fold_{name}", dt / e * 1e6,
                     f"events/s={e / dt:.0f};events={e}"))

    # --- chunked (bounded-memory) fold throughput -------------------------
    def chunked():
        carry = FoldCarry.init(log.num_workers)
        for lo in range(0, e, 65_536):
            carry, _ = fold_chunk(carry, log.chunk(lo, lo + 65_536),
                                  backend="numpy")
        return carry

    chunked()
    dt = _time(chunked, reps=2)
    rows.append(("cmetric_fold_chunked_numpy", dt / e * 1e6,
                 f"events/s={e / dt:.0f};chunk=65536"))
    return rows
