"""CMetric cost: per-event online probe cost + offline fold throughput.

Paper claim: the in-kernel probe is cheap enough for ~4% average overhead.
Our analogue: the probe body (Python, tracer lock + map updates) per event,
and the offline backends' events/second (numpy oracle, streaming scan,
vectorised, Pallas fold) — the throughput table behind the PPT column.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Tracer, compute_numpy, compute_streaming,
                        compute_vectorized, compute, synthetic_log)


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    # --- online probe cost (per begin/end pair) ---------------------------
    tr = Tracer(n_min=1)
    w = tr.register_worker("w")
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        tr.begin(w, "x")
        tr.end(w)
    dt = time.perf_counter() - t0
    rows.append(("cmetric_probe_pair", dt / n * 1e6,
                 f"events/s={2 * n / dt:.0f}"))

    # --- offline fold throughput ------------------------------------------
    rng = np.random.default_rng(0)
    log = synthetic_log(rng, 64, 4000)      # 512k events
    e = len(log)
    backends = {
        "numpy": lambda: compute_numpy(log),
        "stream": lambda: compute_streaming(log),
        "vector": lambda: compute_vectorized(log),
        "pallas_interp": lambda: compute(log, backend="pallas"),
    }
    for name, fn in backends.items():
        fn()                                 # warm up / compile
        dt = _time(fn, reps=2 if name != "numpy" else 1)
        rows.append((f"cmetric_fold_{name}", dt / e * 1e6,
                     f"events/s={e / dt:.0f};events={e}"))
    return rows
