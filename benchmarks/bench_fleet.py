"""Fleet ingest benchmark: localhost loopback, N producers → one report.

Measures the new subsystem end-to-end on one machine:

* aggregate ingest throughput (events/s through RemoteSink → IngestServer
  → FleetSource merge → background fold) with all producers streaming
  concurrently;
* the time from "all producers done" to the final fleet-wide report;
* losslessness accounting (rows sent == rows ingested == rows folded).
"""
from __future__ import annotations

import threading
import time

from repro.core import ProfileSession
from repro.fleet import IngestServer, attach_remote


def _producer(server_addr, hi, seconds, counter, barrier):
    s = ProfileSession(n_min=1.0, drain_interval=0.002)
    wid = s.register_worker("w0")
    sink = attach_remote(s, server_addr, host_id=f"bench-host{hi}",
                         clock_offset_ns=0)
    h = s.handle(wid)
    barrier.wait()
    n = 0
    t_end = time.perf_counter() + seconds
    with s.running():
        while time.perf_counter() < t_end:
            h.begin("work")
            h.end()
            n += 1
    s.result()
    sink.close()
    counter.append((2 * n, sink.rows_sent, sink.stats()))


def run_fleet(producers: int = 2, seconds: float = 1.0,
              chunk_events: int = 1 << 14) -> dict:
    server = IngestServer(chunk_events=chunk_events)
    server.start()
    sess = ProfileSession(server.source, n_min=1.0)
    sess.start()
    counter: list = []
    barrier = threading.Barrier(producers)
    threads = [threading.Thread(target=_producer,
                                args=(server.address, hi, seconds, counter,
                                      barrier))
               for hi in range(producers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ingest_wall = time.perf_counter() - t0
    idle_ok = server.wait_idle(30.0)
    t1 = time.perf_counter()
    rep = sess.result()
    report_s = time.perf_counter() - t1
    stats = server.stats()
    server.close()
    events = sum(c[0] for c in counter)
    sent = sum(c[1] for c in counter)
    return {
        "producers": producers,
        "seconds": seconds,
        "events_captured": events,
        "rows_sent": sent,
        "rows_ingested": stats["rows_in"],
        "ingest_events_per_s": events / max(ingest_wall, 1e-9),
        "final_report_ms": report_s * 1e3,
        "total_slices": rep.total_slices,
        "hosts_reported": len(rep.hosts),
        "lossless": bool(idle_ok and sent == stats["rows_in"]),
        "clock_clamped": stats["clock_clamped"],
        "stale_chunks": stats["stale_chunks"],
        "proto_errors": stats["proto_errors"],
    }


def main() -> None:
    res = run_fleet()
    print("name,value")
    for k, v in res.items():
        print(f"fleet_{k},{v}")


if __name__ == "__main__":
    main()
