"""Fleet ingest benchmark: localhost loopback, N producers → one report.

Measures the fleet subsystem end-to-end on one machine:

* aggregate ingest throughput (events/s through RemoteSink → IngestServer
  → FleetSource merge → background fold) with all producers streaming
  concurrently over the negotiated zlib wire;
* the time from "all producers done" to the final fleet-wide report;
* wire-bytes savings of the compressed frames vs the raw columnar layout;
* losslessness accounting — and, since every producer journals durably
  and the server keeps per-host stores under a ``fleet_dir``, the
  **ingest-vs-offline equality check**: the live fleet report must match
  ``detect_offline`` over the merged journals exactly.

This smoke is a CI **gate** (not report-only): any lost or duplicated
chunk, or any divergence between the live merge and the offline replay
of the journals, raises and fails the job.
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core import ProfileSession, detect_offline
from repro.fleet import FleetSource, IngestServer, attach_remote


def _producer(server_addr, hi, seconds, counter, ready, journal):
    s = ProfileSession(n_min=1.0, drain_interval=0.002)
    wid = s.register_worker("w0")
    sink = attach_remote(s, server_addr, host_id=f"bench-host{hi}",
                         clock_offset_ns=0, journal=journal)
    h = s.handle(wid)
    ready.wait(10.0)        # all HELLOs land before any rows stream, so
    #                         the watermark covers every host (clamp-free)
    n = 0
    t_end = time.perf_counter() + seconds
    with s.running():
        while time.perf_counter() < t_end:
            h.begin("work")
            h.end()
            n += 1
    s.result()
    sink.close()
    counter.append((2 * n, sink.rows_sent, sink.stats()))


def _ranked(rep):
    return [(rep.path_str(p), p.cmetric, p.slices) for p in rep.paths]


def run_fleet(producers: int = 2, seconds: float = 1.0,
              chunk_events: int = 1 << 14) -> dict:
    work_dir = tempfile.mkdtemp(prefix="gapp-fleet-bench-")
    server = IngestServer(chunk_events=chunk_events,
                          fleet_dir=f"{work_dir}/fleet")
    server.start()
    sess = ProfileSession(server.source, n_min=1.0)
    sess.start()
    counter: list = []
    ready = threading.Event()
    threads = [threading.Thread(target=_producer,
                                args=(server.address, hi, seconds, counter,
                                      ready, f"{work_dir}/host{hi}.journal"))
               for hi in range(producers)]
    # teardown in finally: a failure anywhere must not leave the accept
    # thread (or the session worker) alive to pin the CI job until its
    # 45-minute timeout
    try:
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        deadline = time.time() + 10
        while server.stats()["hosts"] < producers and time.time() < deadline:
            time.sleep(0.005)
        ready.set()
        for t in threads:
            t.join()
        ingest_wall = time.perf_counter() - t0
        idle_ok = server.wait_idle(30.0)
        t1 = time.perf_counter()
        rep = sess.result()
        report_s = time.perf_counter() - t1
        stats = server.stats()

        events = sum(c[0] for c in counter)
        sent = sum(c[1] for c in counter)
        wire_bytes = sum(c[2]["wire_bytes"] for c in counter)
        raw_bytes = sum(c[2]["raw_bytes"] for c in counter)
        codecs = sorted({c[2]["codec"] for c in counter})

        # ingest-vs-offline equality: replay the server's durable per-host
        # stores and recompute offline — the live watermark merge must be
        # bit-equal (numpy backend on both sides)
        offline_src = FleetSource.from_fleet_dir(f"{work_dir}/fleet",
                                                 chunk_events=chunk_events)
        merged = offline_src.full_log()
        oracle = detect_offline(merged, offline_src.tags, offline_src.stacks,
                                n_min=1.0)
        np.testing.assert_array_equal(rep.per_worker, oracle.per_worker)
        assert rep.total_slices == oracle.total_slices, \
            (rep.total_slices, oracle.total_slices)
        assert rep.total_critical == oracle.total_critical
        assert rep.idle_time == oracle.idle_time
        assert _ranked(rep) == _ranked(oracle)

        # losslessness gate: every produced row arrived exactly once
        assert idle_ok, f"producers never went idle: {stats}"
        assert sent == stats["rows_in"], (sent, stats["rows_in"])
        assert stats["lost_chunks"] == 0, stats
        assert stats["duplicate_chunks"] == 0, stats
        assert stats["proto_errors"] == 0, stats

        return {
            "producers": producers,
            "seconds": seconds,
            "events_captured": events,
            "rows_sent": sent,
            "rows_ingested": stats["rows_in"],
            "ingest_events_per_s": events / max(ingest_wall, 1e-9),
            "final_report_ms": report_s * 1e3,
            "total_slices": rep.total_slices,
            "hosts_reported": len(rep.hosts),
            "lossless": True,               # asserted above
            "offline_equal": True,          # asserted above
            "wire_codecs": codecs,
            "wire_bytes": wire_bytes,
            "wire_raw_bytes": raw_bytes,
            "wire_compression_ratio": raw_bytes / max(wire_bytes, 1),
            "lost_chunks": stats["lost_chunks"],
            "duplicate_chunks": stats["duplicate_chunks"],
            "clock_clamped": stats["clock_clamped"],
            "stale_chunks": stats["stale_chunks"],
            "proto_errors": stats["proto_errors"],
        }
    finally:
        sess.stop()
        server.close()
        shutil.rmtree(work_dir, ignore_errors=True)


def main() -> None:
    res = run_fleet()
    print("name,value")
    for k, v in res.items():
        print(f"fleet_{k},{v}")


if __name__ == "__main__":
    main()
