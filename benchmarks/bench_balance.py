"""Figures 4/5 analogue: CMetric exposes load imbalance; rebalancing fixes it.

The paper's Ferret experiment reallocates threads across pipeline phases
until per-thread CMetric flattens (50% speedup).  Fleet transplant: a
4-stage pipeline with a hot stage.  We simulate the schedule twice — with
the naive 1-1-1-1 worker split and with a CMetric-guided split — ingest
both traces, and report the imbalance statistics and makespan improvement.
"""
from __future__ import annotations

import numpy as np

from repro.core import ProfileSession, imbalance_stats


def _simulate_pipeline(worker_split, stage_cost, n_items=64):
    """Queue simulation of a 4-stage pipeline; returns (trace, makespan).

    trace: list of (worker_name, t_start, t_end) busy intervals (seconds).
    Workers process items from their stage queue; stage s item arrives when
    stage s-1 finishes it.
    """
    trace = []
    ready = {0: [0.0] * n_items}           # item ready times per stage
    for s, (n_workers, cost) in enumerate(zip(worker_split, stage_cost)):
        free = [0.0] * n_workers
        done = []
        for i, t_ready in enumerate(ready[s]):
            w = int(np.argmin(free))
            t0 = max(free[w], t_ready)
            t1 = t0 + cost
            free[w] = t1
            trace.append((f"s{s}w{w}", t0, t1))
            done.append(t1)
        ready[s + 1] = done
    return trace, max(ready[len(worker_split)])


def _profile(trace):
    g = ProfileSession(n_min=None)
    wids = {}
    events = []
    for name, t0, t1 in trace:
        if name not in wids:
            wids[name] = g.register_worker(name, "stage")
        events.append((t0, wids[name], +1, name.split("w")[0]))
        events.append((t1, wids[name], -1, ""))
    for t, w, d, tag in sorted(events, key=lambda x: x[0]):
        g.ingest(int(t * 1e9), w, d, tag)
    return g


def run():
    stage_cost = [1.0, 4.0, 2.0, 1.0]      # stage 1 is the hot stage
    naive = [2, 2, 2, 2]
    trace, makespan_naive = _simulate_pipeline(naive, stage_cost)
    g = _profile(trace)
    pw = g.tracer.per_worker_cm()
    stats = imbalance_stats(pw)
    # CMetric-guided reallocation: workers proportional to stage CMetric
    names = [w.name for w in g.tracer.workers]
    stage_cm = np.zeros(4)
    for n, v in zip(names, pw):
        stage_cm[int(n[1])] += v
    alloc = np.maximum(1, np.round(stage_cm / stage_cm.sum() * 8)).astype(int)
    while alloc.sum() > 8:
        alloc[np.argmax(alloc)] -= 1
    while alloc.sum() < 8:
        alloc[np.argmax(stage_cm / alloc)] += 1
    trace2, makespan_bal = _simulate_pipeline(alloc.tolist(), stage_cost)
    stats2 = imbalance_stats(_profile(trace2).tracer.per_worker_cm())
    speedup = (makespan_naive - makespan_bal) / makespan_naive * 100
    rows = [
        ("balance_naive_cv", stats["cv"] * 1e6,
         f"cv={stats['cv']:.3f};max_over_mean={stats['max_over_mean']:.2f};"
         f"makespan={makespan_naive:.0f}"),
        ("balance_guided_cv", stats2["cv"] * 1e6,
         f"cv={stats2['cv']:.3f};alloc={'-'.join(map(str, alloc))};"
         f"makespan={makespan_bal:.0f};speedup%={speedup:.0f}"),
    ]
    return rows
