"""Chaos gate: 64 producers under a scripted FaultPlan, recovery exact.

The stress half of the fleet story.  N journaled producers stream
deterministic captures into a journaled (``fleet_dir``) IngestServer
while a seeded :class:`repro.fleet.faults.FaultPlan` injects the whole
failure-modes matrix live:

* **producer kills** — ``RemoteSink.abort()`` mid-capture (no BYE, no
  flush, queue discarded like a SIGKILL), then a fresh session+sink on
  the same journal resumes the capture instance;
* **server kill/restarts** — the ingest server is closed and reopened on
  the same port + ``fleet_dir`` at scheduled points while every producer
  is mid-stream (reconnect storm, floor restore, history backfill);
* **partitions** — scripted connection drops followed by refused
  redials (bounded outage, full-jitter backoff);
* **slow hosts** — per-frame latency injection on a subset.

Gates (raise on violation — this smoke FAILS the job, it does not warn):

1. **Recovery equality**: ``FleetSource.from_fleet_dir`` (what the
   server durably accepted) is bit-equal — merged rows AND the
   detect_offline report (numpy backend) — to
   ``FleetSource.from_producer_journals`` over the union of every
   producer's journal (ground truth: everything ever captured).
2. **Exact reconciliation**: per host, server-journaled chunks ==
   producer-journaled chunks; ``lost_chunks == 0`` summed over every
   server incarnation; on the final incarnation
   ``rows_in == rows_folded + shed_rows`` — accepted rows are folded or
   shed, never silently dropped (shed rows remain recoverable offline,
   which gate 1 just proved).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core import ProfileSession, SpillStore, detect_offline
from repro.fleet import FaultPlan, FleetSource, IngestServer, attach_remote
from repro.fleet.aggregate import load_json


class _StepClock:
    """Deterministic per-producer capture clock (ns)."""

    def __init__(self, base: int):
        self.t = base

    def __call__(self) -> int:
        return self.t

    def advance(self, ns: int) -> None:
        self.t += ns


def _ranked(rep):
    return [(rep.path_str(p), p.cmetric, p.slices) for p in rep.paths]


def _producer(addr, host_id, seed, journal, plan, rounds, spans,
              kill_rounds, progress, errors):
    """One producer: `rounds` snapshot-bounded chunks, restarting itself
    from the journal after each scripted kill."""
    clk = _StepClock(0)
    sess = sink = None
    wid = None

    def boot():
        nonlocal sess, sink, wid
        sess = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
        wid = sess.register_worker("w0")
        sink = attach_remote(sess, addr, host_id=host_id, clock_offset_ns=0,
                             journal=journal, fault_plan=plan,
                             reconnect_delay=0.01, backoff_max=0.1,
                             backoff_seed=seed,
                             max_reconnects=1 << 30,
                             heartbeat_interval=None)

    try:
        boot()
        for r in range(rounds):
            if r in kill_rounds:
                # SIGKILL semantics: sever mid-stream, lose the process,
                # keep only the journal — then resume the capture from it
                sink.abort()
                sess.close()
                boot()
            for _ in range(spans):
                sess.begin(wid, "work")
                clk.advance(1000)
                sess.end(wid)
                clk.advance(500)
            sess.snapshot()             # one deterministic chunk per round
            with progress["lock"]:
                progress["steps"] += 1
            # pace production in real time: the capture clock is synthetic
            # and a round is microseconds of CPU, so without this every
            # producer finishes all its rounds before the first scheduled
            # fault lands — the chaos must hit captures MID-delivery
            time.sleep(0.004)
        sess.result()
        sink.close(timeout=30.0)
        st = sink.stats()
        if sink.failed or st["pending"]:
            errors.append((host_id, f"undelivered: {st}"))
    except Exception as e:              # surfaced by the driver's gate
        errors.append((host_id, repr(e)))


def run_chaos(producers: int = 64, rounds: int = 8, spans: int = 4,
              seed: int = 20260808, kills: int = 8, partitions: int = 4,
              server_restarts: int = 4, slow_hosts: int = 2,
              max_pending_rows: int = 48,
              rotate_bytes: int | None = None) -> dict:
    plan = FaultPlan(seed)
    rng = plan.rng
    hosts = [f"chaos{i:03d}" for i in range(producers)]
    journals: dict[str, str] = {}

    # scripted producer kills: which host, at which round (mid-capture)
    kill_at: dict[str, set] = {h: set() for h in hosts}
    for h in rng.sample(hosts, kills):
        kill_at[h].add(rng.randrange(2, max(rounds - 1, 3)))
    # partitions: drop an established connection, then refuse the redials
    for h in rng.sample(hosts, partitions):
        plan.drop(h, frame=rng.randrange(3, 3 + rounds))
        plan.refuse_connect(h, times=rng.randrange(1, 3))
    # persistently slow producers
    for h in rng.sample(hosts, slow_hosts):
        plan.slow(h, per_frame=0.005)
    # server kill/restart schedule over global round progress
    total_steps = producers * rounds
    plan.schedule("server_restart",
                  sorted(rng.sample(range(total_steps // 8,
                                          total_steps - total_steps // 8),
                                    server_restarts)))

    work_dir = tempfile.mkdtemp(prefix="gapp-chaos-")
    fleet_dir = f"{work_dir}/fleet"

    def new_server(addr=("127.0.0.1", 0)):
        s = IngestServer(addr, fleet_dir=fleet_dir,
                         fleet_rotate_bytes=rotate_bytes,
                         max_pending_rows=max_pending_rows,
                         read_deadline=30.0, idle_release=30.0)
        s.start()
        return s

    server = new_server()
    addr = server.address
    progress = {"lock": threading.Lock(), "steps": 0}
    errors: list = []
    cum = {"lost_chunks": 0, "duplicate_chunks": 0, "shed_chunks": 0,
           "shed_rows": 0, "proto_errors": 0, "deadline_closed": 0,
           "journal_errors": 0}
    restarts_done = 0

    def fold_stats(st):
        for k in cum:
            cum[k] += st.get(k, 0)

    threads = []
    t0 = time.perf_counter()
    try:
        for i, h in enumerate(hosts):
            journals[h] = f"{work_dir}/{h}.journal"
            t = threading.Thread(target=_producer,
                                 args=(addr, h, seed ^ i, journals[h], plan,
                                       rounds, spans, kill_at[h], progress,
                                       errors),
                                 name=f"chaos-{h}")
            t.start()
            threads.append(t)
        # the chaos driver: watch global progress, kill/restart the
        # server at the scheduled steps.  The wall gap keeps restarts
        # from collapsing into one burst when production outpaces the
        # schedule — each incarnation must live long enough to accept
        # real traffic before it is killed
        last_restart = time.monotonic()
        while any(t.is_alive() for t in threads):
            with progress["lock"]:
                step = progress["steps"]
            if (time.monotonic() - last_restart >= 0.08
                    and plan.due("server_restart", step)):
                fold_stats(server.stats())
                server.close()          # hard server loss mid-fleet
                server = new_server(addr)
                restarts_done += 1
                last_restart = time.monotonic()
            time.sleep(0.005)
        for t in threads:
            t.join()
        assert not errors, f"producer failures: {errors[:5]}"
        assert server.wait_idle(60.0), server.stats()
        wall_s = time.perf_counter() - t0

        # final fold: drain whatever the last incarnation holds (live
        # pushes + backfilled history) — shed rows degrade THIS report
        # only, never the journals
        t1 = time.perf_counter()
        fleet_sess = ProfileSession(server.source, n_min=1.0)
        live_rep = fleet_sess.result()
        fold_ms = (time.perf_counter() - t1) * 1e3
        folded = fleet_sess.stats()["events_folded"]
        final_stats = server.stats()
        fold_stats(final_stats)
    finally:
        try:
            server.close()
        except Exception:
            pass

    # ---- gate 1: recovered server state == producer-journal union ----
    fleet_src = FleetSource.from_fleet_dir(fleet_dir)
    host_order = [h.host_id for h in fleet_src.hosts]
    assert sorted(host_order) == sorted(hosts), (host_order, len(hosts))
    prod_src = FleetSource.from_producer_journals(
        [journals[h] for h in host_order])
    flog, plog = fleet_src.full_log(), prod_src.full_log()
    expected_rows = producers * rounds * spans * 2
    assert len(plog) == expected_rows, (len(plog), expected_rows)
    np.testing.assert_array_equal(flog.times, plog.times)
    np.testing.assert_array_equal(flog.workers, plog.workers)
    np.testing.assert_array_equal(flog.deltas, plog.deltas)
    ra = detect_offline(flog, fleet_src.tags, fleet_src.stacks, n_min=1.0)
    rb = detect_offline(plog, prod_src.tags, prod_src.stacks, n_min=1.0)
    np.testing.assert_array_equal(ra.per_worker, rb.per_worker)
    assert ra.total_slices == rb.total_slices
    assert ra.total_critical == rb.total_critical
    assert ra.idle_time == rb.idle_time
    assert _ranked(ra) == _ranked(rb)

    # ---- gate 2: exact reconciliation -------------------------------
    produced_chunks = accepted_chunks = 0
    for h in host_order:
        ps = SpillStore.open_readonly(journals[h])
        produced_chunks += ps.blocks
    for mp in sorted(os.listdir(fleet_dir)):
        if mp.endswith(".meta.json"):
            m = load_json(os.path.join(fleet_dir, mp))
            ss = SpillStore.open_readonly(
                os.path.join(fleet_dir, m["journal"]))
            accepted_chunks += ss.blocks
    assert accepted_chunks == produced_chunks, \
        (accepted_chunks, produced_chunks)
    assert cum["lost_chunks"] == 0, cum
    assert final_stats["rows_in"] == folded + final_stats["shed_rows"], \
        (final_stats["rows_in"], folded, final_stats["shed_rows"])

    faults = {}
    for _h, kind, _d in plan.events:
        faults[kind] = faults.get(kind, 0) + 1
    shutil.rmtree(work_dir, ignore_errors=True)
    return {
        "producers": producers,
        "rounds": rounds,
        "rows_total": expected_rows,
        "seed": seed,
        "wall_s": wall_s,
        "ingest_events_per_s": expected_rows / wall_s if wall_s else 0.0,
        "final_fold_ms": fold_ms,
        "producer_kills": kills,
        "server_restarts": restarts_done,
        "partitions": partitions,
        "slow_hosts": slow_hosts,
        "faults_injected": faults,
        "produced_chunks": produced_chunks,
        "accepted_chunks": accepted_chunks,
        "lost_chunks": cum["lost_chunks"],
        "duplicate_chunks": cum["duplicate_chunks"],
        "shed_chunks": cum["shed_chunks"],
        "shed_rows": cum["shed_rows"],
        "proto_errors": cum["proto_errors"],
        "deadline_closed": cum["deadline_closed"],
        "live_report_slices": int(live_rep.total_slices),
        "oracle_slices": int(ra.total_slices),
        "recovery_equal": True,
        "reconciled": True,
    }


def run():
    res = run_chaos(producers=16, rounds=6, server_restarts=2, kills=4,
                    partitions=2)
    yield ("chaos_recovery_equal", res["wall_s"] * 1e6,
           f"lost={res['lost_chunks']} shed={res['shed_chunks']}")


if __name__ == "__main__":
    import json
    print(json.dumps(run_chaos(), indent=2))
