"""BENCH_*.json trend gate: diff fresh smoke results against a baseline.

Every PR's CI run regenerates ``BENCH_detect.json`` / ``BENCH_probe.json``;
the committed copies are the perf trajectory.  This tool compares a fresh
artifact against the committed baseline metric-by-metric and fails (exit 1)
when a lower-is-better metric regressed by more than ``--max-regression``
(default 20%) — the ROADMAP's "wire BENCH_*.json trend reporting across PRs
into CI" item.

Usage (what ci.yml runs)::

    cp BENCH_probe.json /tmp/probe_base.json        # committed baseline
    python -m benchmarks.run --smoke probe          # fresh result
    python -m benchmarks.trend --base /tmp/probe_base.json \
        --new BENCH_probe.json \
        --keys sharded_us_per_event_1t,sharded_us_per_event_mt

``--warn-only`` reports the trend without failing (used for the detect
smoke, whose absolute numbers swing more across runner generations).
"""
from __future__ import annotations

import argparse
import json
import sys

# Default metrics per artifact kind, keyed by a substring of the file name
# (override with --keys).  A leading ``+`` marks a higher-is-better metric
# (speedup ratios — machine-independent, so they trend cleanly across CI
# runner generations); bare names are lower-is-better (absolute costs).
_DEFAULT_KEYS = {
    "probe": ("+speedup_1t", "+speedup_mt"),
    "detect": ("+speedup",),
}


def _pick_default_keys(path: str) -> tuple[str, ...]:
    for kind, keys in _DEFAULT_KEYS.items():
        if kind in path:
            return keys
    return ()


def compare(base: dict, new: dict, keys: tuple[str, ...],
            max_regression: float) -> list[str]:
    """Returns the list of regression messages (empty == pass)."""
    failures = []
    for spec in keys:
        higher_better = spec.startswith("+")
        k = spec.lstrip("+")
        if k not in base or k not in new:
            print(f"# trend: {k}: missing "
                  f"({'base' if k not in base else 'new'}), skipped")
            continue
        b, n = float(base[k]), float(new[k])
        if b <= 0:
            continue
        # regression = relative move in the bad direction
        delta = (b - n) / b if higher_better else (n - b) / b
        mark = "REGRESSED" if delta > max_regression else "ok"
        print(f"# trend: {k}: base {b:.4g} -> new {n:.4g} "
              f"({'-' if delta > 0 else '+'}{abs(delta) * 100:.1f}% "
              f"{'worse' if delta > 0 else 'better/flat'}) [{mark}]")
        if delta > max_regression:
            failures.append(
                f"{k} regressed {delta * 100:.1f}% "
                f"(limit {max_regression * 100:.0f}%): {b:.4g} -> {n:.4g}")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--base", required=True, help="baseline JSON (committed)")
    ap.add_argument("--new", required=True, help="fresh JSON (this run)")
    ap.add_argument("--keys", default=None,
                    help="comma-separated lower-is-better metrics "
                         "(default: inferred from the file name)")
    ap.add_argument("--max-regression", type=float, default=0.2,
                    help="allowed relative increase before failing "
                         "(0.2 == 20%%)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report the trend but always exit 0")
    args = ap.parse_args(argv)
    with open(args.base) as f:
        base = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    keys = tuple(k for k in (args.keys or "").split(",") if k) \
        or _pick_default_keys(args.new) or _pick_default_keys(args.base)
    if not keys:
        print("# trend: no metrics selected (use --keys)", file=sys.stderr)
        return 2
    failures = compare(base, new, keys, args.max_regression)
    for msg in failures:
        print(f"TREND FAILURE: {msg}", file=sys.stderr)
    if failures and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
