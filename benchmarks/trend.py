"""BENCH_*.json trend gate + series: diff against a baseline, keep history.

Every PR's CI run regenerates ``BENCH_detect.json`` / ``BENCH_probe.json``;
the committed copies are the perf trajectory.  This tool compares a fresh
artifact against the committed baseline metric-by-metric and fails (exit 1)
when a lower-is-better metric regressed by more than ``--max-regression``
(default 20%) — the ROADMAP's "wire BENCH_*.json trend reporting across PRs
into CI" item.

Usage (what ci.yml runs)::

    cp BENCH_probe.json /tmp/probe_base.json        # committed baseline
    python -m benchmarks.run --smoke probe          # fresh result
    python -m benchmarks.trend --base /tmp/probe_base.json \
        --new BENCH_probe.json \
        --keys sharded_us_per_event_1t,sharded_us_per_event_mt

``--warn-only`` reports the trend without failing (used for the detect
smoke, whose absolute numbers swing more across runner generations).

**Series mode** (``--append-series DIR``) persists a trend *series*
instead of only the pairwise diff: every run appends one timestamped JSON
(``<kind>-<timestamp>[-<sha>].json`` with the tracked metrics + commit
metadata) into ``DIR``, and the recent trajectory is printed.  CI restores
``DIR`` from the previous run's cache and uploads it as an artifact, so
the chain of per-PR points survives across runs — each artifact carries
the whole history, not just one pairwise delta.  Pure addition: series
mode never fails the run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# Default metrics per artifact kind, keyed by a substring of the file name
# (override with --keys).  A leading ``+`` marks a higher-is-better metric
# (speedup ratios — machine-independent, so they trend cleanly across CI
# runner generations); bare names are lower-is-better (absolute costs).
_DEFAULT_KEYS = {
    "probe": ("+speedup_1t", "+speedup_mt"),
    "detect": ("+speedup",),
    "session": ("+ram_events_per_s", "capped_snapshot_ms"),
    "fleet": ("+ingest_events_per_s", "final_report_ms",
              "+wire_compression_ratio"),
    "chaos": ("+ingest_events_per_s",),
    "service": ("report_ms", "top_window_ms", "metrics_ms"),
    "whatif": ("whatif_fold_ms", "service_whatif_ms", "moe_rel_err",
               "pipeline_rel_err"),
}


def _pick_default_keys(path: str) -> tuple[str, ...]:
    for kind, keys in _DEFAULT_KEYS.items():
        if kind in path:
            return keys
    return ()


def compare(base: dict, new: dict, keys: tuple[str, ...],
            max_regression: float) -> list[str]:
    """Returns the list of regression messages (empty == pass)."""
    failures = []
    for spec in keys:
        higher_better = spec.startswith("+")
        k = spec.lstrip("+")
        if k not in base or k not in new:
            print(f"# trend: {k}: missing "
                  f"({'base' if k not in base else 'new'}), skipped")
            continue
        b, n = float(base[k]), float(new[k])
        if b <= 0:
            continue
        # regression = relative move in the bad direction
        delta = (b - n) / b if higher_better else (n - b) / b
        mark = "REGRESSED" if delta > max_regression else "ok"
        print(f"# trend: {k}: base {b:.4g} -> new {n:.4g} "
              f"({'-' if delta > 0 else '+'}{abs(delta) * 100:.1f}% "
              f"{'worse' if delta > 0 else 'better/flat'}) [{mark}]")
        if delta > max_regression:
            failures.append(
                f"{k} regressed {delta * 100:.1f}% "
                f"(limit {max_regression * 100:.0f}%): {b:.4g} -> {n:.4g}")
    return failures


def _series_kind(path: str) -> str:
    base = os.path.basename(path)
    for kind in ("probe", "detect", "session", "fleet", "chaos",
                 "service", "whatif"):
        if kind in base:
            return kind
    return os.path.splitext(base)[0] or "bench"


def append_series(series_dir: str, new_path: str, new: dict,
                  keys: tuple[str, ...], window: int = 12) -> str:
    """Append one timestamped point for ``new`` into ``series_dir`` and
    print the recent trajectory of the tracked metrics."""
    os.makedirs(series_dir, exist_ok=True)
    kind = _series_kind(new_path)
    stamp = time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
    # disambiguate same-second appends without a commit id
    sha = (os.environ.get("GITHUB_SHA") or "")[:9] or f"p{os.getpid()}"
    name = f"{kind}-{stamp}-{sha}.json"
    bare = {k.lstrip("+"): new[k.lstrip("+")] for k in keys
            if k.lstrip("+") in new}
    point = {
        "kind": kind,
        "timestamp": new.get("timestamp") or stamp,
        "recorded_at": stamp,
        "sha": os.environ.get("GITHUB_SHA"),
        "run_id": os.environ.get("GITHUB_RUN_ID"),
        "metrics": bare or {k: v for k, v in new.items()
                            if isinstance(v, (int, float))},
    }
    out = os.path.join(series_dir, name)
    with open(out, "w") as f:
        json.dump(point, f, indent=2)
    # print the tail of the chain (lexicographic == chronological)
    entries = sorted(e for e in os.listdir(series_dir)
                     if e.startswith(f"{kind}-") and e.endswith(".json"))
    print(f"# series: {kind}: {len(entries)} point(s) in {series_dir} "
          f"(+ {name})")
    for e in entries[-window:]:
        try:
            with open(os.path.join(series_dir, e)) as f:
                p = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        vals = ", ".join(f"{k}={v:.4g}" for k, v in
                         sorted(p.get("metrics", {}).items()))
        print(f"#   {e[len(kind) + 1:-5]}: {vals}")
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--base", default=None,
                    help="baseline JSON (committed); omit to skip the "
                         "pairwise diff (series-only mode)")
    ap.add_argument("--new", required=True, help="fresh JSON (this run)")
    ap.add_argument("--keys", default=None,
                    help="comma-separated lower-is-better metrics "
                         "(default: inferred from the file name)")
    ap.add_argument("--max-regression", type=float, default=0.2,
                    help="allowed relative increase before failing "
                         "(0.2 == 20%%)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report the trend but always exit 0")
    ap.add_argument("--append-series", metavar="DIR", default=None,
                    help="append a timestamped point for --new into DIR "
                         "and print the recent trajectory (never fails)")
    ap.add_argument("--series-window", type=int, default=12,
                    help="how many trailing series points to print")
    args = ap.parse_args(argv)
    with open(args.new) as f:
        new = json.load(f)
    keys = tuple(k for k in (args.keys or "").split(",") if k) \
        or _pick_default_keys(args.new) \
        or (_pick_default_keys(args.base) if args.base else ())
    failures: list[str] = []
    if args.base:
        if not keys:
            print("# trend: no metrics selected (use --keys)",
                  file=sys.stderr)
            return 2
        with open(args.base) as f:
            base = json.load(f)
        failures = compare(base, new, keys, args.max_regression)
        for msg in failures:
            print(f"TREND FAILURE: {msg}", file=sys.stderr)
    if args.append_series:
        append_series(args.append_series, args.new, new, keys,
                      args.series_window)
    if failures and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
