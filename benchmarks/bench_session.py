"""Streaming ProfileSession benchmark: drain+fold overlap and spill cost.

Measures what the session API added over batch mode:

* capture throughput with the background drain worker running (events/s
  through live spans while the worker folds concurrently);
* incremental ``snapshot()`` latency taken mid-capture;
* the same capture with a disk-spill store — the resident-memory bound's
  throughput price;
* the same capture with a per-shard decode budget (``max_rows_per_sync``)
  — the capped mid-capture snapshot latency.  Note: under full-rate
  producers on an oversubscribed box the contended per-row decode cost is
  dominated by GIL convoying, so the capped latency ≈ budget × contended
  row cost, well above the uncontended budget decode (the ROADMAP's
  "batched C decode" item is the next lever).
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

from repro.core import ProfileSession


def _hammer(session, wid, stop_evt, counter):
    h = session.handle(wid)
    n = 0
    while not stop_evt.is_set():
        h.begin("work")
        h.end()
        n += 1
    counter.append(2 * n)


def run_session(threads: int = 4, seconds: float = 1.0,
                chunk_events: int = 1 << 14,
                max_rows_per_sync: int = 1024) -> dict:
    out: dict = {"threads": threads, "seconds": seconds,
                 "chunk_events": chunk_events,
                 "max_rows_per_sync": max_rows_per_sync}
    # three configs: all-RAM store, disk spill, and the per-shard decode
    # budget (the capped mid-capture snapshot latency is the ROADMAP item:
    # a multi-MHz producer must not starve snapshot())
    for mode in ("ram", "spill", "capped"):
        spill = mode == "spill"
        path = tempfile.mktemp(suffix=".gappspill") if spill else None
        s = ProfileSession(
            n_min=1.0, drain_interval=0.002, spill_path=path,
            chunk_events=chunk_events,
            max_rows_per_sync=max_rows_per_sync if mode == "capped"
            else None)
        wids = [s.register_worker(f"t{i}") for i in range(threads)]
        stop_evt = threading.Event()
        counter: list[int] = []
        workers = [threading.Thread(target=_hammer,
                                    args=(s, w, stop_evt, counter))
                   for w in wids]
        s.start()
        for t in workers:
            t.start()
        time.sleep(seconds / 2)
        t0 = time.perf_counter()
        snap = s.snapshot()
        snap_s = time.perf_counter() - t0
        time.sleep(seconds / 2)
        stop_evt.set()
        for t in workers:
            t.join()
        rep = s.result()
        total = sum(counter)
        out[f"{mode}_events"] = total
        out[f"{mode}_events_per_s"] = total / seconds
        out[f"{mode}_snapshot_ms"] = snap_s * 1e3
        out[f"{mode}_final_slices"] = rep.total_slices
        if spill:
            st = s.tracer.store
            out["spill_max_resident_rows"] = st.max_resident_rows
            out["spill_rows_on_disk"] = st.rows_on_disk
            st.close()
            os.unlink(path)
        del snap
    out["spill_slowdown"] = (out["ram_events_per_s"]
                             / max(out["spill_events_per_s"], 1.0))
    return out


def main() -> None:
    res = run_session()
    print("name,value")
    for k, v in res.items():
        print(f"session_{k},{v}")


if __name__ == "__main__":
    main()
