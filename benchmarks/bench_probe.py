"""Per-event probe cost: sharded lock-free hot path vs the locked body.

The paper's headline claim is probe cheapness (~4% runtime overhead from an
O(1) in-kernel body).  The seed's software analogue serialized every
``begin``/``end`` of every worker through one global ``threading.Lock``
plus per-event Python map updates — retained as
:class:`repro.core.tracer.LockedTracer` and measured here as the baseline.
The sharded tracer's per-worker handles (:meth:`Tracer.handle`) are the
replacement hot path.

Two scenarios:

* ``1t`` — one worker, one thread: pure per-event bookkeeping cost.
* ``mt`` — ``threads`` real threads hammering their own workers
  concurrently, the workload GAPP actually profiles.  Under the global
  lock this convoys (a preempted lock holder blocks every other worker
  for a scheduling quantum), so per-event cost explodes; the sharded
  path has no cross-worker coordination at all.

``run_probe()`` is the ``--smoke probe`` payload (BENCH_probe.json);
``bench_cmetric`` reuses it for the CSV harness.
"""
from __future__ import annotations

import threading
import time

from repro.core import LockedTracer, Tracer


def _drive_locked(tr, wid, pairs, tag="probe/x"):
    b, e = tr.begin, tr.end
    t0 = time.perf_counter()
    for _ in range(pairs):
        b(wid, tag)
        e(wid)
    return time.perf_counter() - t0


def _drive_sharded(handle, pairs, tag="probe/x"):
    b, e = handle.begin, handle.end
    t0 = time.perf_counter()
    for _ in range(pairs):
        b(tag)
        e()
    return time.perf_counter() - t0


def _single_thread(make, drive, pairs, reps):
    best = float("inf")
    for _ in range(reps):
        target = make()
        drive(target, pairs // 10)              # warm-up
        best = min(best, drive(target, pairs))
    return best / (2 * pairs)                   # seconds per event


def _contended(make, drive, pairs, threads, reps):
    best = float("inf")
    for _ in range(reps):
        targets = make(threads)
        ts = [threading.Thread(target=drive, args=(t, pairs))
              for t in targets]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        best = min(best, time.perf_counter() - t0)
    return best / (2 * pairs * threads)         # seconds per event


def run_probe(pairs: int = 20_000, threads: int = 4, reps: int = 3) -> dict:
    # headroom for warm-up + measured events so neither tracer ever hits
    # its capacity slow path (drop/flush) inside the timed region
    cap = 5 * pairs

    # --- locked baseline (the seed probe body) ----------------------------
    def locked_one():
        tr = LockedTracer(n_min=0.0, capacity=cap)
        return tr, tr.register_worker("w")

    def locked_many(n):
        tr = LockedTracer(n_min=0.0, capacity=n * cap)
        return [(tr, tr.register_worker(f"w{i}")) for i in range(n)]

    locked_1t = _single_thread(
        locked_one, lambda tw, p: _drive_locked(tw[0], tw[1], p), pairs,
        reps)
    locked_mt = _contended(
        locked_many, lambda tw, p: _drive_locked(tw[0], tw[1], p), pairs,
        threads, reps)

    # --- sharded hot path --------------------------------------------------
    def sharded_one():
        tr = Tracer(n_min=0.0, capacity=cap)
        return tr.handle(tr.register_worker("w"))

    def sharded_many(n):
        tr = Tracer(n_min=0.0, capacity=cap)
        return [tr.handle(tr.register_worker(f"w{i}")) for i in range(n)]

    sharded_1t = _single_thread(sharded_one, _drive_sharded, pairs, reps)
    sharded_mt = _contended(sharded_many, _drive_sharded, pairs, threads,
                            reps)

    return {
        "pairs": pairs,
        "threads": threads,
        "locked_us_per_event_1t": locked_1t * 1e6,
        "sharded_us_per_event_1t": sharded_1t * 1e6,
        "locked_us_per_event_mt": locked_mt * 1e6,
        "sharded_us_per_event_mt": sharded_mt * 1e6,
        "speedup_1t": locked_1t / sharded_1t,
        "speedup_mt": locked_mt / sharded_mt,
        # headline: per-event hot-path cost in the contended (parallel
        # application) scenario the profiler exists for
        "speedup": locked_mt / sharded_mt,
    }


def run():
    r = run_probe(pairs=10_000, reps=2)
    return [
        ("probe_sharded_event_1t", r["sharded_us_per_event_1t"],
         f"events/s={1e6 / r['sharded_us_per_event_1t']:.0f}"),
        ("probe_locked_event_1t", r["locked_us_per_event_1t"],
         f"speedup_1t={r['speedup_1t']:.1f}x"),
        ("probe_sharded_event_mt", r["sharded_us_per_event_mt"],
         f"threads={r['threads']}"),
        ("probe_locked_event_mt", r["locked_us_per_event_mt"],
         f"speedup_mt={r['speedup_mt']:.1f}x"),
    ]
