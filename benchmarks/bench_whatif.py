"""What-if accuracy smoke: counterfactual projections vs ground truth.

The causal engine's contract is that its projections are *actionable* —
so this smoke GATES projection accuracy against workloads where the
true gain from fixing the bottleneck is known by construction (raise on
violation; the job fails, it does not warn):

1. **MoE hot expert** (``examples/moe_imbalance.py``): project removing
   the hot expert's work, then physically re-profile with that expert's
   load zeroed — projected speedup must match measured within 15%;
2. **Pipeline serial section** (``examples/pipeline_bubbles.py``): an
   injected serial optimizer step of known duration — removal *and*
   ``shrink=0.5`` projections must match the analytic truth within 15%
   (they are exact by construction);
3. **Service byte-consistency**: ``GET /api/whatif`` over a journaled
   fleet_dir must be byte-identical to the offline
   ``report.what_if(...).to_json()`` on the same fleet_dir.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time
import urllib.request

import numpy as np

# the ground-truth scenarios live in examples/ (a repo-root namespace
# package); make them importable when this file runs as a script too
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from repro.core import ProfileSession
from repro.fleet import (FleetSource, IngestServer, ProfilerService,
                         attach_remote)

TOLERANCE = 0.15


class _StepClock:
    """Deterministic per-producer capture clock (ns)."""

    def __init__(self, base: int = 0):
        self.t = base

    def __call__(self) -> int:
        return self.t

    def advance(self, ns: int) -> None:
        self.t += ns


def _rel_err(projected: float, actual: float) -> float:
    return abs(projected - actual) / max(abs(actual), 1e-12)


def _moe_accuracy() -> dict:
    """Ground truth 1: drop the hot expert, measure vs project."""
    from examples.moe_imbalance import expert_loads, profile_loads
    loads, _ = expert_loads(2.5)
    g, _ = profile_loads(loads)
    rep = g.result()
    hot = int(np.argmax(rep.per_worker))
    t0 = time.perf_counter()
    wi = rep.what_if(f"moe/expert{hot}", shrink=0.0)
    fold_ms = (time.perf_counter() - t0) * 1e3
    fixed = loads.copy()
    fixed[hot] = 0
    g2, _ = profile_loads(fixed)
    actual = rep.total_time / g2.result().total_time
    err = _rel_err(wi.speedup, actual)
    assert err <= TOLERANCE, (wi.speedup, actual, err)
    assert wi.matched_slices > 0, wi.to_doc()
    return {"projected": wi.speedup, "actual": actual, "rel_err": err,
            "fold_ms": fold_ms, "hot": hot}


def _pipeline_accuracy() -> dict:
    """Ground truth 2: injected serial section of known duration."""
    from examples.pipeline_bubbles import profile_schedule
    serial_ns = 2_000_000
    _, _, g = profile_schedule(8, 8, serial_update_ns=serial_ns)
    rep = g.result()
    out = {}
    for key, shrink in (("remove", 0.0), ("half", 0.5)):
        wi = rep.what_if("optimizer/serial_update", shrink=shrink)
        truth_total = rep.total_time - (1.0 - shrink) * serial_ns / 1e9
        actual = rep.total_time / truth_total
        err = _rel_err(wi.speedup, actual)
        assert err <= TOLERANCE, (key, wi.speedup, actual, err)
        out[key] = {"projected": wi.speedup, "actual": actual,
                    "rel_err": err}
    return out


def _service_consistency(producers: int = 2, spans: int = 120) -> dict:
    """Ground truth 3: /api/whatif bytes == offline what_if bytes."""
    work_dir = tempfile.mkdtemp(prefix="gapp-whatif-")
    fleet_dir = f"{work_dir}/fleet"
    try:
        server = IngestServer(fleet_dir=fleet_dir)
        server.start()
        try:
            for i in range(producers):
                clk = _StepClock(i * spans * 1500)
                s = ProfileSession(n_min=1.0, clock=clk,
                                   drain_interval=0.001)
                w = s.register_worker("w0")
                sink = attach_remote(
                    s, server.address, host_id=f"host{i}",
                    clock_offset_ns=0,
                    journal=f"{work_dir}/host{i}.journal")
                for _ in range(spans):
                    s.begin(w, "work")
                    clk.advance(1000)
                    s.end(w)
                    clk.advance(500)
                s.result()
                sink.close()
                assert not sink.failed and sink.dropped_chunks == 0
            assert server.wait_idle(30.0), server.stats()
        finally:
            server.close()

        svc = ProfilerService.from_fleet_dir(fleet_dir,
                                             n_min=float(producers)).start()
        try:
            url = ("http://%s:%d/api/whatif?tag=work&shrink=0.5"
                   % svc.address)
            t0 = time.perf_counter()
            with urllib.request.urlopen(url, timeout=10.0) as r:
                status, body = r.status, r.read()
            http_ms = (time.perf_counter() - t0) * 1e3
            assert status == 200, status
        finally:
            svc.close()

        off = ProfileSession(FleetSource.from_fleet_dir(fleet_dir),
                             n_min=float(producers))
        rep = off.result()
        offline = rep.what_if("work", shrink=0.5).to_json().encode("utf-8")
        equal = body == offline
        assert equal, (len(body), len(offline))
        doc = json.loads(body)
        assert doc["speedup"] and doc["speedup"] > 1.0, doc["speedup"]
        return {"byte_equal": equal, "http_ms": http_ms,
                "speedup": doc["speedup"], "bytes": len(body)}
    finally:
        shutil.rmtree(work_dir, ignore_errors=True)


def run_whatif() -> dict:
    moe = _moe_accuracy()
    pipe = _pipeline_accuracy()
    svc = _service_consistency()
    return {
        "tolerance": TOLERANCE,
        "whatif_fold_ms": moe["fold_ms"],
        "moe_projected_speedup": moe["projected"],
        "moe_actual_speedup": moe["actual"],
        "moe_rel_err": moe["rel_err"],
        "pipeline_projected_speedup": pipe["remove"]["projected"],
        "pipeline_actual_speedup": pipe["remove"]["actual"],
        "pipeline_rel_err": pipe["remove"]["rel_err"],
        "pipeline_half_rel_err": pipe["half"]["rel_err"],
        "service_byte_equal": svc["byte_equal"],
        "service_whatif_ms": svc["http_ms"],
        "service_whatif_speedup": svc["speedup"],
        "accuracy_ok": True,
    }


def run():
    res = run_whatif()
    yield ("whatif_counterfactual_fold", res["whatif_fold_ms"] * 1e3,
           f"moe_err={res['moe_rel_err'] * 100:.1f}% "
           f"pipe_err={res['pipeline_rel_err'] * 100:.1f}%")
    yield ("whatif_service_get", res["service_whatif_ms"] * 1e3,
           f"byte_equal={res['service_byte_equal']}")


if __name__ == "__main__":
    print(json.dumps(run_whatif(), indent=2))
