"""Service smoke: live HTTP query API latency + contract gates.

Starts a journaled 2-producer ingest (durable ``fleet_dir``), attaches a
:class:`repro.fleet.ProfilerService`, and measures endpoint latency while
the contracts that make the API trustworthy are GATED (raise on
violation — this smoke fails the job, it does not warn):

1. ``GET /api/report`` is byte-identical to ``session.export("json")``
   — the live API is the canonical exporter, not a lookalike;
2. ``GET /api/top?window=`` over the tail window returns real entries
   (the incremental journal re-fold sees the bottleneck paths);
3. ``GET /metrics`` carries the session / ingest / journal / service
   gauge families in Prometheus 0.0.4 text exposition;
4. ``GET /api/hosts`` lists exactly the producing hosts.
"""
from __future__ import annotations

import json
import shutil
import statistics
import tempfile
import time
import urllib.request

from repro.core import ProfileSession
from repro.fleet import IngestServer, ProfilerService, attach_remote


class _StepClock:
    """Deterministic per-producer capture clock (ns)."""

    def __init__(self, base: int = 0):
        self.t = base

    def __call__(self) -> int:
        return self.t

    def advance(self, ns: int) -> None:
        self.t += ns


def _get(addr, path, timeout=10.0):
    url = "http://%s:%d%s" % (addr[0], addr[1], path)
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.read()


def run_service(producers: int = 2, spans: int = 200,
                requests: int = 50) -> dict:
    work_dir = tempfile.mkdtemp(prefix="gapp-svc-")
    fleet_dir = f"{work_dir}/fleet"
    server = IngestServer(fleet_dir=fleet_dir)
    server.start()
    sess = ProfileSession(server.source, n_min=float(producers))
    sess.start()
    svc = ProfilerService(sess, server=server).start()
    try:
        # Disjoint capture timelines: exactly one worker is ever active,
        # so every slice is serialized under n_min == producers and the
        # top-N gates exercise real bottleneck paths.
        for i in range(producers):
            clk = _StepClock(i * spans * 1500)
            s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
            w = s.register_worker("w0")
            sink = attach_remote(s, server.address, host_id=f"host{i}",
                                 clock_offset_ns=0,
                                 journal=f"{work_dir}/host{i}.journal")
            for _ in range(spans):
                s.begin(w, "work")
                clk.advance(1000)
                s.end(w)
                clk.advance(500)
            s.result()
            sink.close()
            assert not sink.failed and sink.dropped_chunks == 0, sink.stats()
        assert server.wait_idle(30.0), server.stats()
        want_events = producers * spans * 2
        deadline = time.time() + 30.0
        while (sess.stats()["events_folded"] < want_events
               and time.time() < deadline):
            time.sleep(0.01)
        folded = sess.stats()["events_folded"]
        assert folded == want_events, (folded, want_events)

        addr = svc.address

        def timed(path, n):
            lat, body = [], b""
            for _ in range(n):
                t0 = time.perf_counter()
                status, body = _get(addr, path)
                lat.append((time.perf_counter() - t0) * 1e3)
                assert status == 200, (path, status)
            return statistics.median(lat), body

        report_ms, body = timed("/api/report", requests)
        # gate 1: the live API IS the canonical exporter
        assert body == sess.export("json").encode("utf-8")
        rep = json.loads(body)
        assert rep["schema_version"] == 4, rep["schema_version"]

        # tail window: a third of the fleet-time span, always populated
        window_s = producers * spans * 1500 / 3 / 1e9
        top_ms, tbody = timed(f"/api/top?n=10&window={window_s:g}",
                              max(requests // 5, 1))
        top = json.loads(tbody)
        assert top["entries"], top              # gate 2

        metrics_ms, mbody = timed("/metrics", max(requests // 5, 1))
        text = mbody.decode("utf-8")
        for needle in ("gapp_session_events_folded", "gapp_fleet_rows_in",
                       "gapp_ingest_lost_chunks", "gapp_journal_blocks",
                       "gapp_service_requests"):
            assert needle in text, needle       # gate 3

        hosts_ms, hbody = timed("/api/hosts", 5)
        hosts = json.loads(hbody)["hosts"]
        assert set(hosts) == {f"host{i}" for i in range(producers)}  # gate 4

        st = svc.stats()
        return {
            "producers": producers,
            "spans": spans,
            "events_folded": int(folded),
            "report_ms": report_ms,
            "report_bytes": len(body),
            "report_requests_per_s": 1e3 / report_ms if report_ms else 0.0,
            "top_window_ms": top_ms,
            "top_window_s": window_s,
            "top_entries": len(top["entries"]),
            "metrics_ms": metrics_ms,
            "hosts_ms": hosts_ms,
            "service_requests": st["requests"],
            "service_http_errors": st["http_errors"],
            "window_folds": st["window_folds"],
            "report_equal": True,
        }
    finally:
        svc.close()
        sess.stop()
        server.close()
        shutil.rmtree(work_dir, ignore_errors=True)


def run():
    res = run_service()
    yield ("service_report_get", res["report_ms"] * 1e3,
           f"{res['report_bytes']}B equal={res['report_equal']}")
    yield ("service_top_window", res["top_window_ms"] * 1e3,
           f"entries={res['top_entries']}")


if __name__ == "__main__":
    print(json.dumps(run_service(), indent=2))
