"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus context lines prefixed
with '#').  Mapping to the paper:

  overhead_*   -> Table 2 columns O/H (tracer overhead on a real training
                  loop), CR (critical ratio), M (profiler memory), PPT
                  (post-processing time)
  cmetric_*    -> the "extremely low overhead" claim: per-event probe cost
                  and offline fold throughput for every backend
  balance_*    -> Figures 4/5: per-worker CMetric imbalance detection and
                  the effect of rebalancing (Ferret thread-reallocation
                  experiment, transplanted to pipeline stages)
  detect_*     -> §5.2: injected-bottleneck identification accuracy
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def smoke_detect(n_slices: int, out: str) -> dict:
    """CI smoke target: the detection-stage scaling benchmark on a synthetic
    10^5-critical-slice table, persisted as JSON so successive PRs leave a
    perf trajectory (``python -m benchmarks.run --smoke detect``)."""
    from benchmarks import bench_detect
    res = bench_detect.run_scale(n_slices)
    res["n_slices_requested"] = n_slices
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# detection stage @ {res['n_critical']} critical slices: "
          f"seed loop {res['seed_loop_s'] * 1e3:.1f} ms, columnar "
          f"{res['table_s'] * 1e3:.1f} ms "
          f"({res['speedup']:.1f}x) -> {out}")
    return res


def smoke_probe(pairs: int, threads: int, out: str) -> dict:
    """CI smoke target: per-event probe cost, sharded lock-free hot path vs
    the retained locked seed body, single-thread and contended
    (``python -m benchmarks.run --smoke probe`` -> BENCH_probe.json)."""
    from benchmarks import bench_probe
    res = bench_probe.run_probe(pairs=pairs, threads=threads)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print("# probe hot path: locked "
          f"{res['locked_us_per_event_1t']:.2f}us/ev 1t "
          f"/ {res['locked_us_per_event_mt']:.2f}us/ev {threads}t, sharded "
          f"{res['sharded_us_per_event_1t']:.2f}us/ev 1t "
          f"/ {res['sharded_us_per_event_mt']:.2f}us/ev {threads}t "
          f"-> {res['speedup_1t']:.1f}x single, {res['speedup_mt']:.1f}x "
          f"contended -> {out}")
    return res


def smoke_session(threads: int, out: str) -> dict:
    """Streaming-session smoke: live capture throughput with the background
    drain+fold worker, mid-capture snapshot latency, and the disk-spill
    store's cost (``python -m benchmarks.run --smoke session``)."""
    from benchmarks import bench_session
    res = bench_session.run_session(threads=threads)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# streaming session: {res['ram_events_per_s']:.0f} ev/s live "
          f"(snapshot {res['ram_snapshot_ms']:.1f} ms mid-capture), "
          f"{res['spill_events_per_s']:.0f} ev/s spilling "
          f"(resident <= {res['spill_max_resident_rows']} rows, "
          f"{res['spill_slowdown']:.2f}x slowdown), capped snapshot "
          f"{res['capped_snapshot_ms']:.1f} ms @ budget "
          f"{res['max_rows_per_sync']} -> {out}")
    return res


def smoke_fleet(producers: int, out: str) -> dict:
    """Fleet-ingest smoke: localhost loopback, N producer sessions
    streaming compressed frames over real sockets — with durable journals
    on both ends — into one IngestServer+FleetSource session
    (``python -m benchmarks.run --smoke fleet`` -> BENCH_fleet.json).
    GATED in CI: losslessness (zero lost/duplicate chunks) and
    ingest-vs-offline equality are asserted inside the benchmark, so any
    regression fails the run instead of printing a warning."""
    from benchmarks import bench_fleet
    res = bench_fleet.run_fleet(producers=producers)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# fleet ingest: {res['producers']} producers, "
          f"{res['ingest_events_per_s']:.0f} ev/s over loopback "
          f"({res['wire_compression_ratio']:.1f}x wire compression), "
          f"final report {res['final_report_ms']:.1f} ms, "
          f"lossless={res['lossless']} "
          f"offline_equal={res['offline_equal']} -> {out}")
    return res


def smoke_chaos(producers: int, out: str) -> dict:
    """Chaos smoke: N journaled producers stream through a seeded
    FaultPlan (producer kills, server kill/restarts, partitions, slow
    hosts) while the recovery gates assert bit-equal journals and exact
    chunk reconciliation (``python -m benchmarks.run --smoke chaos`` ->
    BENCH_chaos.json).  GATED inside the benchmark: any lost chunk,
    duplicate fold, or recovered-vs-oracle drift raises."""
    from benchmarks import bench_chaos
    res = bench_chaos.run_chaos(producers=producers)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# chaos: {res['producers']} producers, "
          f"{res['producer_kills']} kills / "
          f"{res['server_restarts']} server restarts / "
          f"{res['partitions']} partitions in {res['wall_s']:.1f}s — "
          f"lost={res['lost_chunks']} dup={res['duplicate_chunks']} "
          f"shed={res['shed_chunks']} "
          f"recovery_equal={res['recovery_equal']} -> {out}")
    return res


def smoke_service(producers: int, out: str) -> dict:
    """Service smoke: the live HTTP query API (ProfilerService) over a
    journaled 2-producer ingest — endpoint latency plus GATED contracts:
    /api/report byte-equal to export("json"), windowed /api/top entries
    from the journal re-fold, /metrics exposition families, /api/hosts
    roster (``python -m benchmarks.run --smoke service`` ->
    BENCH_service.json)."""
    from benchmarks import bench_service
    res = bench_service.run_service(producers=producers)
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# service: /api/report {res['report_ms']:.2f} ms "
          f"({res['report_bytes']} B, equal={res['report_equal']}), "
          f"/api/top?window {res['top_window_ms']:.2f} ms "
          f"({res['top_entries']} entries), /metrics "
          f"{res['metrics_ms']:.2f} ms -> {out}")
    return res


def smoke_whatif(out: str) -> dict:
    """What-if accuracy smoke: counterfactual projections checked against
    constructible ground truth — MoE hot-expert removal and an injected
    serial optimizer step, both with known true gains, plus /api/whatif
    byte-consistency with the offline engine (``python -m benchmarks.run
    --smoke whatif`` -> BENCH_whatif.json).  GATED inside the benchmark:
    projected-vs-measured relative error above 15% or a wire/offline
    byte mismatch raises."""
    from benchmarks import bench_whatif
    res = bench_whatif.run_whatif()
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# whatif: moe projected {res['moe_projected_speedup']:.3f}x vs "
          f"measured {res['moe_actual_speedup']:.3f}x "
          f"(err {res['moe_rel_err'] * 100:.1f}%), pipeline err "
          f"{res['pipeline_rel_err'] * 100:.1f}%, service byte_equal="
          f"{res['service_byte_equal']} "
          f"({res['service_whatif_ms']:.2f} ms) -> {out}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", choices=["detect", "probe", "session",
                                        "fleet", "chaos", "service",
                                        "whatif"],
                    help="run one fast smoke benchmark and write a JSON "
                         "artifact instead of the full CSV harness")
    ap.add_argument("--producers", type=int, default=2,
                    help="producer sessions for --smoke fleet")
    ap.add_argument("--chaos-producers", type=int, default=64,
                    help="producer sessions for --smoke chaos")
    ap.add_argument("--n-slices", type=int, default=250_000,
                    help="table size for --smoke detect (~43%% of rows land "
                         "under n_min, so the default yields >=1e5 critical "
                         "slices)")
    ap.add_argument("--pairs", type=int, default=20_000,
                    help="begin/end pairs per worker for --smoke probe")
    ap.add_argument("--threads", type=int, default=4,
                    help="contending workers for --smoke probe")
    ap.add_argument("--out", default=None,
                    help="JSON artifact path (default BENCH_<smoke>.json)")
    args = ap.parse_args()
    if args.smoke == "detect":
        smoke_detect(args.n_slices, args.out or "BENCH_detect.json")
        return
    if args.smoke == "probe":
        smoke_probe(args.pairs, args.threads, args.out or "BENCH_probe.json")
        return
    if args.smoke == "session":
        smoke_session(args.threads, args.out or "BENCH_session.json")
        return
    if args.smoke == "fleet":
        smoke_fleet(args.producers, args.out or "BENCH_fleet.json")
        return
    if args.smoke == "chaos":
        smoke_chaos(args.chaos_producers, args.out or "BENCH_chaos.json")
        return
    if args.smoke == "service":
        smoke_service(args.producers, args.out or "BENCH_service.json")
        return
    if args.smoke == "whatif":
        smoke_whatif(args.out or "BENCH_whatif.json")
        return

    from benchmarks import (bench_balance, bench_cmetric, bench_detect,
                            bench_overhead, bench_probe)
    print("# GAPP benchmark harness — paper-table analogues")
    print("name,us_per_call,derived")
    for mod in (bench_probe, bench_cmetric, bench_overhead, bench_balance,
                bench_detect):
        t0 = time.time()
        for row in mod.run():
            name, us, derived = row
            print(f"{name},{us:.3f},{derived}", flush=True)
        print(f"# {mod.__name__} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
