"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus context lines prefixed
with '#').  Mapping to the paper:

  overhead_*   -> Table 2 columns O/H (tracer overhead on a real training
                  loop), CR (critical ratio), M (profiler memory), PPT
                  (post-processing time)
  cmetric_*    -> the "extremely low overhead" claim: per-event probe cost
                  and offline fold throughput for every backend
  balance_*    -> Figures 4/5: per-worker CMetric imbalance detection and
                  the effect of rebalancing (Ferret thread-reallocation
                  experiment, transplanted to pipeline stages)
  detect_*     -> §5.2: injected-bottleneck identification accuracy
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def smoke_detect(n_slices: int, out: str) -> dict:
    """CI smoke target: the detection-stage scaling benchmark on a synthetic
    10^5-critical-slice table, persisted as JSON so successive PRs leave a
    perf trajectory (``python -m benchmarks.run --smoke detect``)."""
    from benchmarks import bench_detect
    res = bench_detect.run_scale(n_slices)
    res["n_slices_requested"] = n_slices
    res["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"# detection stage @ {res['n_critical']} critical slices: "
          f"seed loop {res['seed_loop_s'] * 1e3:.1f} ms, columnar "
          f"{res['table_s'] * 1e3:.1f} ms "
          f"({res['speedup']:.1f}x) -> {out}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", choices=["detect"],
                    help="run one fast smoke benchmark and write a JSON "
                         "artifact instead of the full CSV harness")
    ap.add_argument("--n-slices", type=int, default=250_000,
                    help="table size for --smoke detect (~43%% of rows land "
                         "under n_min, so the default yields >=1e5 critical "
                         "slices)")
    ap.add_argument("--out", default="BENCH_detect.json",
                    help="JSON artifact path for --smoke detect")
    args = ap.parse_args()
    if args.smoke == "detect":
        smoke_detect(args.n_slices, args.out)
        return

    from benchmarks import (bench_balance, bench_cmetric, bench_detect,
                            bench_overhead)
    print("# GAPP benchmark harness — paper-table analogues")
    print("name,us_per_call,derived")
    for mod in (bench_cmetric, bench_overhead, bench_balance, bench_detect):
        t0 = time.time()
        for row in mod.run():
            name, us, derived = row
            print(f"{name},{us:.3f},{derived}", flush=True)
        print(f"# {mod.__name__} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
