"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus context lines prefixed
with '#').  Mapping to the paper:

  overhead_*   -> Table 2 columns O/H (tracer overhead on a real training
                  loop), CR (critical ratio), M (profiler memory), PPT
                  (post-processing time)
  cmetric_*    -> the "extremely low overhead" claim: per-event probe cost
                  and offline fold throughput for every backend
  balance_*    -> Figures 4/5: per-worker CMetric imbalance detection and
                  the effect of rebalancing (Ferret thread-reallocation
                  experiment, transplanted to pipeline stages)
  detect_*     -> §5.2: injected-bottleneck identification accuracy
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_balance, bench_cmetric, bench_detect,
                            bench_overhead)
    print("# GAPP benchmark harness — paper-table analogues")
    print("name,us_per_call,derived")
    for mod in (bench_cmetric, bench_overhead, bench_balance, bench_detect):
        t0 = time.time()
        for row in mod.run():
            name, us, derived = row
            print(f"{name},{us:.3f},{derived}", flush=True)
        print(f"# {mod.__name__} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
