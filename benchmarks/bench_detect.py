"""§5.2 analogue: injected-bottleneck identification accuracy.

Across many randomized synthetic fleets we inject a known serialization
bottleneck (straggler host / hot MoE expert / slow data loader tag) and
score whether GAPP's top-1 ranked path or worker names it.  The paper
validates on Parsec by confirming known bottlenecks; our substrate is the
fleet simulation, so we can measure *accuracy* over many trials.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Gapp


def _fleet_trial(rng, kind: str) -> bool:
    g = Gapp(n_min=None, top_n=3)
    n_hosts = 16
    wids = [g.register_worker(f"host{i}", "host") for i in range(n_hosts)]
    target = int(rng.integers(0, n_hosts))
    t = 0
    for step in range(12):
        if kind == "straggler":
            durs = rng.normal(1e6, 5e4, n_hosts)
            durs[target] *= 3.0
            tags = ["train/step"] * n_hosts
        elif kind == "hot_expert":
            # all fast except the hot expert's host during moe phase
            durs = rng.normal(1e6, 5e4, n_hosts)
            durs[target] *= 2.5
            tags = ["moe/expert_ffn"] * n_hosts
        else:  # slow loader: one host blocks on data
            durs = rng.normal(1e6, 5e4, n_hosts)
            durs[target] *= 2.0
            tags = ["train/step"] * n_hosts
            tags[target] = "data/wait"
        for h in range(n_hosts):
            g.ingest(t, wids[h], +1, tags[h])
        for h in np.argsort(durs):
            g.ingest(t + int(durs[h]), wids[int(h)], -1)
        t += int(durs.max()) + int(rng.integers(1e4, 1e5))
    rep = g.report()
    if not rep.paths:
        return False
    hit_worker = int(np.argmax(rep.per_worker)) == target
    if kind == "slow_loader":
        return hit_worker and "data/wait" in rep.path_str(rep.paths[0])
    return hit_worker


def run():
    rows = []
    rng = np.random.default_rng(42)
    for kind in ("straggler", "hot_expert", "slow_loader"):
        t0 = time.perf_counter()
        trials = 25
        hits = sum(_fleet_trial(rng, kind) for _ in range(trials))
        dt = time.perf_counter() - t0
        rows.append((f"detect_{kind}", dt / trials * 1e6,
                     f"top1_acc={hits / trials:.2f};trials={trials}"))
    return rows
