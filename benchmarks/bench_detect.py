"""§5.2 analogue: injected-bottleneck identification accuracy, plus the
detection-stage scaling benchmark (paper Table 2 "PPT" column).

Accuracy: across many randomized synthetic fleets we inject a known
serialization bottleneck (straggler host / hot MoE expert / slow data loader
tag) and score whether GAPP's top-1 ranked path or worker names it.

Scale: the post-processing stage (critical extraction + sample attachment +
path merge) over a synthetic table of ≥10^5 critical slices, comparing the
columnar vectorised pipeline against the retained seed per-slice Python
loop (``detector._merge_python``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ProfileSession, SampleBuffer, SliceTable,
                        StackRegistry, merge_table)
from repro.core import detector as detector_lib
from repro.core.slices import CriticalSlice


def _fleet_trial(rng, kind: str) -> bool:
    g = ProfileSession(n_min=None, top_n=3)
    n_hosts = 16
    wids = [g.register_worker(f"host{i}", "host") for i in range(n_hosts)]
    target = int(rng.integers(0, n_hosts))
    t = 0
    for step in range(12):
        if kind == "straggler":
            durs = rng.normal(1e6, 5e4, n_hosts)
            durs[target] *= 3.0
            tags = ["train/step"] * n_hosts
        elif kind == "hot_expert":
            # all fast except the hot expert's host during moe phase
            durs = rng.normal(1e6, 5e4, n_hosts)
            durs[target] *= 2.5
            tags = ["moe/expert_ffn"] * n_hosts
        else:  # slow loader: one host blocks on data
            durs = rng.normal(1e6, 5e4, n_hosts)
            durs[target] *= 2.0
            tags = ["train/step"] * n_hosts
            tags[target] = "data/wait"
        for h in range(n_hosts):
            g.ingest(t, wids[h], +1, tags[h])
        for h in np.argsort(durs):
            g.ingest(t + int(durs[h]), wids[int(h)], -1)
        t += int(durs.max()) + int(rng.integers(1e4, 1e5))
    rep = g.snapshot()
    if not rep.paths:
        return False
    hit_worker = int(np.argmax(rep.per_worker)) == target
    if kind == "slow_loader":
        return hit_worker and "data/wait" in rep.path_str(rep.paths[0])
    return hit_worker


def _synthetic_table(n_slices: int, n_workers: int = 32, n_paths: int = 50,
                     n_tags: int = 64, samples_per_slice: float = 1.5,
                     seed: int = 0):
    """A full slice table (mixed critical/non-critical), a stack registry and
    a matching sample stream — the detector's exact input shape at scale."""
    rng = np.random.default_rng(seed)
    stacks = StackRegistry()
    for _ in range(n_paths):
        depth = int(rng.integers(1, 6))
        stacks.intern(tuple(int(x) for x in rng.integers(0, n_tags, depth)))
    per_w = max(n_slices // n_workers, 1)
    s = per_w * n_workers
    dur = rng.integers(10_000, 1_000_000, size=(n_workers, per_w))
    gap = rng.integers(1_000, 100_000, size=(n_workers, per_w))
    step = dur + gap
    start = np.cumsum(step, axis=1) - step + rng.integers(
        0, 100_000, size=(n_workers, 1))
    end = start + dur
    threads_av = rng.uniform(0.5, 4.0, size=s)
    table = SliceTable.from_arrays(
        worker=np.repeat(np.arange(n_workers), per_w),
        start_ns=start.reshape(-1), end_ns=end.reshape(-1),
        cm=dur.reshape(-1) * 1e-9 / threads_av, threads_av=threads_av,
        stack_id=rng.integers(0, len(stacks.paths), size=s),
        n_at_exit=rng.integers(1, 4, size=s))
    n_samp = int(s * samples_per_slice)
    buf = SampleBuffer(capacity=n_samp)
    pick = rng.integers(0, s, size=n_samp)
    frac = rng.random(n_samp)
    buf.times[:] = (table.start_ns[pick]
                    + (frac * (table.end_ns - table.start_ns)[pick])
                    ).astype(np.int64)
    buf.workers[:] = table.worker[pick]
    buf.tags[:] = rng.integers(0, n_tags, size=n_samp)
    buf.head = n_samp
    return table, stacks, buf


def _extract_python(table: SliceTable, n_min: float) -> list[CriticalSlice]:
    """The seed's per-slice critical extraction loop (oracle cost model)."""
    out = []
    for i in np.flatnonzero(table.threads_av < n_min):
        out.append(CriticalSlice(
            worker=int(table.worker[i]), start_ns=int(table.start_ns[i]),
            end_ns=int(table.end_ns[i]), cm=float(table.cm[i]),
            threads_av=float(table.threads_av[i]),
            stack_id=int(table.stack_id[i]),
            n_at_exit=int(table.n_at_exit[i])))
    return out


def run_scale(n_slices: int = 100_000, n_min: float = 2.0, seed: int = 0,
              repeats: int = 3) -> dict:
    """Detection stage (critical extraction + sample attachment + path
    merge): columnar pipeline vs seed per-slice Python loop."""
    table, stacks, samples = _synthetic_table(n_slices, seed=seed)

    t0 = time.perf_counter()
    crit_list = _extract_python(table, n_min)
    by_path, attached_py = detector_lib._merge_python(crit_list, samples,
                                                      stacks, n_min)
    seed_s = time.perf_counter() - t0

    # symmetric methodology: the headline speedup compares single cold runs;
    # the warm minimum over further repeats is reported separately
    table_s = float("inf")
    for r in range(repeats):
        t0 = time.perf_counter()
        crit = table.critical(n_min)
        profiles, attached_tb = merge_table(crit, samples, stacks, n_min)
        dt = time.perf_counter() - t0
        if r == 0:
            table_cold_s = dt
        table_s = min(table_s, dt)

    assert attached_py == attached_tb
    assert len(profiles) == len(by_path)
    for p in profiles:
        assert abs(p.cmetric - by_path[p.stack].cmetric) < 1e-9
    return {
        "n_slices": len(table),
        "n_critical": len(crit),
        "samples": len(samples),
        "seed_loop_s": seed_s,
        "table_s": table_cold_s,
        "table_warm_s": table_s,
        "speedup": seed_s / table_cold_s,
    }


def run():
    rows = []
    rng = np.random.default_rng(42)
    for kind in ("straggler", "hot_expert", "slow_loader"):
        t0 = time.perf_counter()
        trials = 25
        hits = sum(_fleet_trial(rng, kind) for _ in range(trials))
        dt = time.perf_counter() - t0
        rows.append((f"detect_{kind}", dt / trials * 1e6,
                     f"top1_acc={hits / trials:.2f};trials={trials}"))
    scale = run_scale(20_000)
    rows.append(("detect_merge_columnar", scale["table_s"] * 1e6,
                 f"speedup={scale['speedup']:.1f}x;"
                 f"n_critical={scale['n_critical']}"))
    return rows
