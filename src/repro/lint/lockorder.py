"""lock-order rule: the lock-acquisition graph must be acyclic.

Nodes are canonical lock names (``Class.attr`` / ``module.py::name``).
An edge A → B means "B was acquired while A was held", from either:

* lexical nesting — ``with A:`` … ``with B:`` inside one function
  (a method contract counts as holding its lock on entry); or
* interprocedural flow — calling ``f()`` while holding A adds A → L for
  every lock L that ``f`` (transitively, through the resolvable call
  graph) acquires.

Non-blocking acquires (``lock.acquire(False)``) never appear — only
``with`` statements create edges — and an RLock self-edge is legal
re-entrancy, not a deadlock.  Graphs are built per defining module (the
issue's "per module" scope); a cycle spanning modules is reported once,
in the module contributing its first edge.  Any strongly connected
component with more than one node, or a non-reentrant self-edge, is an
ABBA-style deadlock shape and is reported with one example site per edge.
"""
from __future__ import annotations

from repro.lint import analysis
from repro.lint.engine import Finding

RULE = "lock-order"


def _transitive_acquires(project, func, memo, visiting):
    """All locks ``func`` may acquire, directly or through callees."""
    if func in memo:
        return memo[func]
    if func in visiting:
        return frozenset()  # recursion cycle in the call graph
    visiting.add(func)
    acquired = {lock for lock, _held, _line in func.with_acquisitions(project)}
    for call, _held, _stmt in func.call_sites(project):
        for callee in project.resolve_call(call, func):
            acquired |= _transitive_acquires(project, callee, memo, visiting)
    visiting.discard(func)
    memo[func] = frozenset(acquired)
    return memo[func]


def _build_edges(project):
    """edge (a, b) -> list of (path, line, qualname) example sites."""
    edges: dict[tuple[str, str], list[tuple[str, int, str]]] = {}
    memo: dict = {}

    def add(a, b, module, line, func):
        if a == b and project.lock_kind(a) == "RLock":
            return
        edges.setdefault((a, b), []).append((module.path, line, func.qualname))

    for module in project.modules:
        for func in module.all_functions:
            for lock, held, line in func.with_acquisitions(project):
                for h in held:
                    add(h, lock, module, line, func)
            for call, held, stmt in func.call_sites(project):
                if not held:
                    continue
                for callee in project.resolve_call(call, func):
                    for lock in _transitive_acquires(project, callee, memo,
                                                     set()):
                        for h in held:
                            add(h, lock, module, call.lineno, func)
    return edges


def _sccs(nodes, adj):
    """Tarjan's strongly connected components, iteratively."""
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    out: list[list[str]] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work = [(root, iter(adj.get(root, ())))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            node, it = work[-1]
            advanced = False
            for nxt in it:
                if nxt not in index:
                    index[nxt] = low[nxt] = counter[0]
                    counter[0] += 1
                    stack.append(nxt)
                    on_stack.add(nxt)
                    work.append((nxt, iter(adj.get(nxt, ()))))
                    advanced = True
                    break
                if nxt in on_stack:
                    low[node] = min(low[node], index[nxt])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == node:
                        break
                out.append(comp)
    return out


def check_lock_order(project: analysis.Project) -> list[Finding]:
    edges = _build_edges(project)
    adj: dict[str, list[str]] = {}
    nodes: set[str] = set()
    for (a, b) in edges:
        nodes.update((a, b))
        adj.setdefault(a, []).append(b)

    findings: list[Finding] = []
    seen_cycles: set[frozenset[str]] = set()
    for comp in _sccs(sorted(nodes), adj):
        comp_set = frozenset(comp)
        cyclic = len(comp) > 1 or (comp[0], comp[0]) in edges
        if not cyclic or comp_set in seen_cycles:
            continue
        seen_cycles.add(comp_set)
        cycle_edges = sorted((a, b) for (a, b) in edges
                             if a in comp_set and b in comp_set)
        examples = []
        for a, b in cycle_edges:
            path, line, qual = edges[(a, b)][0]
            examples.append(f"{a} -> {b} at {path}:{line} ({qual})")
        path, line, _qual = edges[cycle_edges[0]][0]
        findings.append(Finding(
            rule=RULE, path=path, line=line,
            message=("lock-order cycle between "
                     + ", ".join(sorted(comp_set)) + ": "
                     + "; ".join(examples)),
            symbol="cycle:" + "->".join(sorted(comp_set))))
    return findings
