"""guarded-by rule: mutations of annotated shared state must hold the lock.

Contract grammar (trailing comment on the attribute's initialisation)::

    self.next_seq = 0          # guarded-by: self.lock
    self.got_bye = False       # guarded-by: IngestServer._lock

``self.<x>`` specs are *receiver-relative*: a mutation spelled
``st.next_seq = 1`` requires ``st.lock`` held, which the shared resolver
canonicalises to the same node as ``with st.lock:``.  Class-qualified
specs (``Class.attr``) pin the lock to one object regardless of receiver.

A ``# guarded-by:`` on a ``def`` line is a *method contract*: the body is
checked as if the lock were held (caller-holds-it idiom, e.g.
``SpillStore._write_block``), and every resolvable call to that method is
checked for the lock being held at the call site.

Mutations inside the owning class's ``__init__`` are exempt (construction
happens before the object is shared).
"""
from __future__ import annotations

import ast

from repro.lint import analysis
from repro.lint.analysis import MUTATOR_METHODS, expr_text
from repro.lint.engine import Finding

RULE = "guarded-by"


def _mutation_paths(stmt: ast.stmt):
    """Yield ``(dotted_path, node)`` for attribute paths this statement
    writes: plain/aug/subscript assigns, dels, and in-place container
    mutator calls (``x.append(...)``)."""
    targets: list[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.Delete):
        targets = list(stmt.targets)
    elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        func = stmt.value.func
        if isinstance(func, ast.Attribute) and func.attr in MUTATOR_METHODS:
            text = expr_text(func.value)
            if text:
                yield text, stmt.value
        return
    stack = targets
    while stack:
        t = stack.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        elif isinstance(t, ast.Starred):
            stack.append(t.value)
        elif isinstance(t, ast.Subscript):
            text = expr_text(t.value)
            if text:
                yield text, t
        else:
            text = expr_text(t)
            if text:
                yield text, t


def _owner_for(path: str, func: analysis.FunctionInfo,
               project: analysis.Project):
    """Which class's guarded-attr contract governs a mutation of
    ``path``?  ``self.x`` binds to the enclosing class only; any other
    receiver binds through the attr name when exactly one class in the
    project guards it."""
    if "." not in path:
        return None, None, None
    receiver, attr = path.rsplit(".", 1)
    if receiver == "self":
        if func.cls is not None and attr in func.cls.guarded_attrs:
            return func.cls, attr, receiver
        return None, None, None
    owners = project.guarded_attr_owners.get(attr, [])
    # Foreign receivers are untyped: enforce only when the attr name is
    # unique among every class that defines it — if some other class also
    # has a `self.<attr>` (e.g. the lock-free EventShard.times next to the
    # guarded EventRing.times), the receiver could be either, so stay out.
    if len(owners) == 1 and project.attr_definers.get(attr, set()) == {owners[0].name}:
        return owners[0], attr, receiver
    return None, None, None


def check_guarded_by(project: analysis.Project) -> list[Finding]:
    findings: list[Finding] = []
    for module in project.modules:
        for func in module.all_functions:
            for stmt, held in func.iter_with_held(project):
                for path, node in _mutation_paths(stmt):
                    owner, attr, receiver = _owner_for(path, func, project)
                    if owner is None:
                        continue
                    if receiver == "self" and func.cls is owner \
                            and func.name == "__init__":
                        continue  # construction, pre-sharing
                    spec = owner.guarded_attrs[attr]
                    required_expr = spec.required_for(receiver)
                    required, _ = project.resolve_lock(required_expr, func)
                    if required in held:
                        continue
                    findings.append(Finding(
                        rule=RULE, path=module.path, line=node.lineno,
                        message=(f"mutation of {owner.name}.{attr} outside "
                                 f"`with {required_expr}` (guarded-by "
                                 f"{spec.lock_expr}, in {func.qualname})"),
                        symbol=f"{func.qualname}:{path}"))
            # Calls into methods whose def-line contract says the caller
            # must already hold the lock.
            for call, held, _stmt in func.call_sites(project):
                for callee in project.resolve_call(call, func):
                    if callee.contract is None or callee is func:
                        continue
                    receiver = None
                    if isinstance(call.func, ast.Attribute):
                        receiver = expr_text(call.func.value)
                    required_expr = callee.contract.required_for(receiver)
                    # Resolve in the frame where the spelling makes
                    # sense: the caller's when receiver-rewritten, the
                    # callee's for its own self-relative spelling.
                    frame = func if receiver not in (None, "self") else callee
                    if receiver == "self" and func.cls is callee.cls:
                        frame = func
                    required, _ = project.resolve_lock(required_expr, frame)
                    if required in held:
                        continue
                    findings.append(Finding(
                        rule=RULE, path=module.path, line=call.lineno,
                        message=(f"call to {callee.qualname} requires "
                                 f"{required_expr} held (guarded-by contract"
                                 f" on its def), in {func.qualname}"),
                        symbol=f"{func.qualname}:call:{callee.qualname}"))
    return findings
