"""Rule engine: findings, annotations, suppressions, baseline.

The engine is deliberately small.  A *rule* is a callable
``rule(project) -> list[Finding]``; the engine owns everything around the
rules — parsing files once into a shared :class:`~repro.lint.analysis.Project`,
extracting comments with ``tokenize`` (so a ``#`` inside a string never
reads as an annotation), matching ``# lint: disable=RULE(reason)``
suppressions, and diffing surviving findings against the committed
baseline file.
"""
from __future__ import annotations

import io
import json
import re
import tokenize
from dataclasses import dataclass, field

# --- comment + annotation extraction -----------------------------------

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=(?P<items>.+)$")
_SUPPRESS_ITEM_RE = re.compile(r"(?P<rule>[\w-]+)\s*(?:\((?P<reason>[^)]*)\))?")
_GUARD_RE = re.compile(r"#\s*guarded-by:\s*(?P<lock>[A-Za-z_][\w.]*)")
_PUBLISH_RE = re.compile(r"#\s*publishes:\s*(?P<names>[A-Za-z_][\w.]*(?:\s*,\s*[A-Za-z_][\w.]*)*)")
_EVENT_LOOP_RE = re.compile(r"#\s*lint:\s*event-loop\b")


def extract_comments(source: str) -> dict[int, str]:
    """Map line number -> comment text for every ``#`` comment.

    Uses ``tokenize`` rather than string scanning so ``#`` characters
    inside string literals are never mistaken for comments.  Returns an
    empty map on tokenize errors (the caller reports syntax errors via
    ``ast.parse`` instead).
    """
    comments: dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                comments[tok.start[0]] = tok.string
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return {}
    return comments


@dataclass
class Suppression:
    rule: str
    reason: str
    line: int
    used: bool = False


def parse_suppressions(comments: dict[int, str]) -> dict[int, list[Suppression]]:
    out: dict[int, list[Suppression]] = {}
    for line, text in comments.items():
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        items = []
        for im in _SUPPRESS_ITEM_RE.finditer(m.group("items")):
            items.append(Suppression(rule=im.group("rule"),
                                     reason=(im.group("reason") or "").strip(),
                                     line=line))
        if items:
            out[line] = items
    return out


def guard_annotation(comments: dict[int, str], line: int) -> str | None:
    text = comments.get(line)
    if not text:
        return None
    m = _GUARD_RE.search(text)
    return m.group("lock") if m else None


def publish_annotation(comments: dict[int, str], line: int) -> list[str] | None:
    text = comments.get(line)
    if not text:
        return None
    m = _PUBLISH_RE.search(text)
    if not m:
        return None
    return [n.strip() for n in m.group("names").split(",")]


def is_event_loop_annotation(comments: dict[int, str], line: int) -> bool:
    text = comments.get(line)
    return bool(text and _EVENT_LOOP_RE.search(text))


# --- findings ----------------------------------------------------------


@dataclass
class Finding:
    """One rule violation.

    ``symbol`` is a line-number-free identity (``qualname:detail``) used
    for baseline fingerprints so entries survive unrelated line drift.
    """

    rule: str
    path: str
    line: int
    message: str
    symbol: str
    suppressed_by: str | None = None  # reason text when suppressed/baselined

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "message": self.message, "fingerprint": self.fingerprint}
        if self.suppressed_by is not None:
            d["reason"] = self.suppressed_by
        return d


# --- baseline ----------------------------------------------------------


class Baseline:
    """Committed ledger of accepted findings, each with a justification.

    Format (``lint-baseline.json``)::

        {"version": 1,
         "entries": {"<rule>:<path>:<symbol>": "<why this is acceptable>"}}

    A baseline entry that no longer matches any finding is *stale* and
    fails the run: either the underlying issue was fixed (delete the
    entry) or the code moved in a way that needs a fresh look.
    """

    VERSION = 1

    def __init__(self, entries: dict[str, str] | None = None):
        self.entries = dict(entries or {})
        self.matched: set[str] = set()

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, "r", encoding="utf-8") as f:
            data = json.load(f)
        if data.get("version") != cls.VERSION:
            raise ValueError(f"unsupported baseline version in {path}: "
                             f"{data.get('version')!r}")
        entries = data.get("entries", {})
        if not all(isinstance(k, str) and isinstance(v, str)
                   for k, v in entries.items()):
            raise ValueError(f"malformed baseline entries in {path}")
        return cls(entries)

    def match(self, finding: Finding) -> str | None:
        reason = self.entries.get(finding.fingerprint)
        if reason is not None:
            self.matched.add(finding.fingerprint)
        return reason

    @property
    def stale(self) -> list[str]:
        return sorted(set(self.entries) - self.matched)

    @staticmethod
    def write(path: str, findings: list[Finding], reason: str) -> None:
        entries = {f.fingerprint: (f.suppressed_by or reason)
                   for f in findings}
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"version": Baseline.VERSION,
                       "entries": dict(sorted(entries.items()))},
                      f, indent=2, sort_keys=False)
            f.write("\n")


# --- orchestration -----------------------------------------------------


@dataclass
class LintResult:
    findings: list[Finding] = field(default_factory=list)      # live
    suppressed: list[Finding] = field(default_factory=list)    # inline-disabled
    baselined: list[Finding] = field(default_factory=list)
    stale_baseline: list[str] = field(default_factory=list)
    errors: list[str] = field(default_factory=list)            # parse failures

    @property
    def ok(self) -> bool:
        return not self.findings and not self.stale_baseline and not self.errors

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [f.to_json() for f in self.suppressed],
            "baselined": [f.to_json() for f in self.baselined],
            "stale_baseline": self.stale_baseline,
            "errors": self.errors,
        }


def _suppression_for(finding: Finding, module) -> Suppression | None:
    """Inline suppression lookup: any line of the offending statement's
    span, or the signature lines of the enclosing ``def``."""
    spans = module.suppress_spans_for_line(finding.line)
    for line in spans:
        for sup in module.suppressions.get(line, ()):  # pragma: no branch
            if sup.rule == finding.rule:
                return sup
    return None


def run_lint(paths: list[str], baseline: Baseline | None = None,
             rules=None) -> LintResult:
    """Parse ``paths`` once, run every rule, fold suppressions + baseline."""
    from repro.lint import analysis
    from repro.lint.blocking import check_loop_blocking
    from repro.lint.guarded import check_guarded_by
    from repro.lint.lockorder import check_lock_order
    from repro.lint.publication import check_publication_order

    if rules is None:
        rules = (check_guarded_by, check_lock_order, check_loop_blocking,
                 check_publication_order)

    project = analysis.Project.load(paths)
    result = LintResult(errors=list(project.errors))

    raw: list[Finding] = []
    for rule in rules:
        raw.extend(rule(project))
    raw.sort(key=lambda f: (f.path, f.line, f.rule, f.message))

    for finding in raw:
        module = project.by_path.get(finding.path)
        sup = _suppression_for(finding, module) if module is not None else None
        if sup is not None:
            sup.used = True
            if not sup.reason:
                # An excuse without a justification is itself a finding.
                result.findings.append(Finding(
                    rule=finding.rule, path=finding.path, line=sup.line,
                    message=(f"suppression of [{finding.rule}] has no reason "
                             f"— use # lint: disable={finding.rule}(why)"),
                    symbol=finding.symbol + ":no-reason"))
                continue
            finding.suppressed_by = sup.reason
            result.suppressed.append(finding)
            continue
        if baseline is not None:
            reason = baseline.match(finding)
            if reason is not None:
                finding.suppressed_by = reason
                result.baselined.append(finding)
                continue
        result.findings.append(finding)

    if baseline is not None:
        result.stale_baseline = baseline.stale
    return result
