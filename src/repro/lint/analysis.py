"""Shared AST analysis: modules, classes, locks, call graph, held-sets.

Everything the four rules have in common lives here, computed once per
``run_lint``:

* per-module ASTs with comments, suppressions, and annotation bindings;
* per-class *lock attributes* (``self.x = threading.Lock()`` and
  friends), with ``Condition(self.other)`` resolved as an alias of the
  underlying lock — acquiring the condition *is* acquiring the lock;
* *guarded attributes* (``self.x = ... # guarded-by: <lock>``) and
  *method contracts* (``# guarded-by:`` on a ``def`` line — the body
  runs with the lock held, so callers must hold it);
* a canonical lock-naming scheme (:meth:`Project.resolve_lock`) that
  lets ``with self._lock:`` in one method and ``with st.lock:`` in
  another agree on identity without type inference;
* a best-effort call graph (:meth:`Project.resolve_call`) over
  module-local names, ``self.``/``Class.`` receivers, project imports,
  and project-unique method names;
* a held-set walker (:meth:`FunctionInfo.iter_with_held`) that streams
  ``(statement, frozenset_of_held_locks)`` pairs in source order.

The resolution is heuristic by design — no inference, no stubs — but it
is *symmetric*: the same resolver names the lock in a ``guarded-by``
contract and the lock in a ``with`` statement, so matching spellings
always agree even when neither resolves to a known lock object.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field

from repro.lint import engine

#: Callables in ``threading`` whose result we treat as a lock for both
#: acquisition tracking and lock-order nodes.
LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}

#: Method names that mutate a container in place (used by guarded-by).
MUTATOR_METHODS = {
    "append", "appendleft", "extend", "extendleft", "insert",
    "pop", "popleft", "popitem", "remove", "discard", "clear",
    "add", "update", "setdefault", "sort", "reverse",
}


def expr_text(node: ast.AST) -> str | None:
    """Dotted text for a Name/Attribute chain (``self.source.cond``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = expr_text(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _signature_lines(node: ast.FunctionDef | ast.AsyncFunctionDef) -> range:
    first_body = node.body[0].lineno if node.body else node.lineno + 1
    return range(node.lineno, max(node.lineno, first_body - 1) + 1)


@dataclass
class GuardSpec:
    """A ``# guarded-by: <lock>`` binding on an attribute or a def."""

    lock_expr: str   # as written: "self._lock", "FleetSource.cond", ...
    line: int

    def required_for(self, receiver: str | None) -> str:
        """Rewrite a ``self.``-relative lock to the mutation site's
        receiver: spec ``self.lock`` at site ``st.next_seq`` requires
        ``st.lock``."""
        if receiver and receiver != "self" and self.lock_expr.startswith("self."):
            return receiver + self.lock_expr[4:]
        return self.lock_expr


@dataclass(eq=False)
class ClassInfo:
    name: str
    node: ast.ClassDef
    module: "Module"
    lock_attrs: dict[str, str] = field(default_factory=dict)   # attr -> root attr
    lock_kinds: dict[str, str] = field(default_factory=dict)   # root attr -> factory
    guarded_attrs: dict[str, GuardSpec] = field(default_factory=dict)
    methods: dict[str, "FunctionInfo"] = field(default_factory=dict)

    def lock_root(self, attr: str) -> str | None:
        seen = set()
        while attr in self.lock_attrs and attr not in seen:
            seen.add(attr)
            nxt = self.lock_attrs[attr]
            if nxt == attr:
                return attr
            attr = nxt
        return attr if attr in self.lock_attrs.values() or attr in self.lock_attrs else None


@dataclass(eq=False)
class FunctionInfo:
    name: str
    qualname: str
    node: ast.FunctionDef | ast.AsyncFunctionDef
    module: "Module"
    cls: ClassInfo | None
    contract: GuardSpec | None = None   # guarded-by on the def line
    is_loop_root: bool = False          # lint: event-loop on the def line

    def iter_with_held(self, project: "Project"):
        """Yield ``(stmt, held)`` for every statement in source order.

        ``held`` is the frozenset of canonical lock names lexically held
        at that statement: enclosing ``with <lock>:`` blocks plus this
        function's own contract.  Nested ``def``s are *not* descended
        into — they are separate :class:`FunctionInfo` entries with
        their own (empty) base held-set.
        """
        base: frozenset[str] = frozenset()
        if self.contract is not None:
            canon, _ = project.resolve_lock(self.contract.lock_expr, self)
            base = frozenset({canon})

        def walk(stmts, held):
            for st in stmts:
                yield st, held
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if isinstance(st, (ast.With, ast.AsyncWith)):
                    inner = held
                    for item in st.items:
                        text = expr_text(item.context_expr)
                        if text is None:
                            continue
                        canon, known = project.resolve_lock(text, self)
                        if known:
                            inner = inner | {canon}
                    yield from walk(st.body, inner)
                    continue
                for body in _sub_bodies(st):
                    yield from walk(body, held)

        yield from walk(self.node.body, base)

    def with_acquisitions(self, project: "Project"):
        """Yield ``(lock, held_before, line)`` for each ``with``-acquired
        known lock, in source order (used by lock-order)."""
        for st, held in self.iter_with_held(project):
            if not isinstance(st, (ast.With, ast.AsyncWith)):
                continue
            inner = set(held)
            for item in st.items:
                text = expr_text(item.context_expr)
                if text is None:
                    continue
                canon, known = project.resolve_lock(text, self)
                if known:
                    yield canon, frozenset(inner), st.lineno
                    inner.add(canon)

    def call_sites(self, project: "Project"):
        """Yield ``(call_node, held, stmt)`` for every Call expression.

        Compound statements contribute only their *header* expressions
        (test/iter/with-items); their bodies arrive as their own
        statements, so no call is yielded twice.
        """
        for st, held in self.iter_with_held(project):
            for root in _header_exprs(st):
                for sub in ast.walk(root):
                    if isinstance(sub, ast.Call):
                        yield sub, held, st


def _header_exprs(st: ast.stmt) -> list[ast.AST]:
    """The expressions owned by the statement itself — a simple statement
    in full, a compound statement's header only, a def's nothing."""
    if not hasattr(st, "body"):
        return [st]
    if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return []
    out: list[ast.AST] = []
    for name in ("test", "iter", "target", "subject"):
        value = getattr(st, name, None)
        if value is not None:
            out.append(value)
    for item in getattr(st, "items", ()) or ():
        out.append(item.context_expr)
    return out


def _direct_nested_defs(node):
    """First-level nested ``def``s only; deeper nesting is handled by the
    recursive _make_function call on each of these."""
    out, stack = [], list(node.body)
    while stack:
        st = stack.pop(0)
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append(st)
            continue
        stack.extend(c for c in ast.iter_child_nodes(st)
                     if isinstance(c, ast.stmt) or hasattr(c, "body"))
    return out


def _sub_bodies(st: ast.stmt):
    for name in ("body", "orelse", "finalbody"):
        body = getattr(st, name, None)
        if body:
            yield body
    for handler in getattr(st, "handlers", ()) or ():
        yield handler.body


@dataclass(eq=False)
class Module:
    path: str                      # as passed to the linter (relative)
    dotted: str                    # best-effort import name
    tree: ast.Module
    source: str
    comments: dict[int, str]
    suppressions: dict[int, list[engine.Suppression]]
    classes: dict[str, ClassInfo] = field(default_factory=dict)
    functions: dict[str, FunctionInfo] = field(default_factory=dict)  # module level
    all_functions: list[FunctionInfo] = field(default_factory=list)   # incl. methods + nested
    imports: dict[str, str] = field(default_factory=dict)             # alias -> dotted
    lock_vars: dict[str, str] = field(default_factory=dict)           # module-level locks
    lock_var_kinds: dict[str, str] = field(default_factory=dict)
    _stmt_spans: list[tuple[int, int]] = field(default_factory=list)
    _def_spans: list[tuple[int, int, int, int]] = field(default_factory=list)

    def suppress_spans_for_line(self, line: int) -> list[int]:
        """Lines whose ``# lint: disable=`` comments govern ``line``:
        the offending statement's own span plus every enclosing ``def``
        signature."""
        lines = {line}
        for start, end in self._stmt_spans:
            if start <= line <= end and end - start <= 20:
                # the statement's own lines, plus the line directly above
                # it (a full-line disable comment with a long reason)
                lines.update(range(start - 1, end + 1))
        for start, end, sig_start, sig_end in self._def_spans:
            if start <= line <= end:
                # signature lines plus the line above the def (where a
                # function-wide disable sits, decorator-style)
                lines.update(range(sig_start - 1, sig_end + 1))
        return sorted(lines)


def _dotted_name(path: str) -> str:
    parts = path.replace(os.sep, "/").split("/")
    if parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    elif "src" in parts:
        parts = parts[parts.index("src") + 1:]
    return ".".join(p for p in parts if p)


def _lock_factory(call: ast.AST, imports: dict[str, str]) -> str | None:
    """Return the factory name if ``call`` constructs a threading lock."""
    if not isinstance(call, ast.Call):
        return None
    text = expr_text(call.func)
    if text is None:
        return None
    head, _, rest = text.partition(".")
    full = imports.get(head, head) + (("." + rest) if rest else "")
    if full.startswith("threading.") and full.split(".", 1)[1] in LOCK_FACTORIES:
        return full.split(".", 1)[1]
    if full in LOCK_FACTORIES:  # `from threading import Lock`
        return full
    return None


class Project:
    """All parsed modules plus the cross-module indexes."""

    def __init__(self):
        self.modules: list[Module] = []
        self.by_path: dict[str, Module] = {}
        self.by_dotted: dict[str, Module] = {}
        self.errors: list[str] = []
        # attr name -> {class info} across the whole project
        self.lock_attr_owners: dict[str, list[ClassInfo]] = {}
        self.guarded_attr_owners: dict[str, list[ClassInfo]] = {}
        # attr name -> every class that assigns self.<attr> anywhere; used
        # to keep unique-owner resolution honest (a name also defined by an
        # unrelated class cannot be enforced on foreign receivers).
        self.attr_definers: dict[str, set[str]] = {}
        self.class_index: dict[str, list[ClassInfo]] = {}
        self.method_index: dict[str, list[FunctionInfo]] = {}
        self.class_by_dotted: dict[str, ClassInfo] = {}

    # -- loading --------------------------------------------------------

    @classmethod
    def load(cls, paths: list[str]) -> "Project":
        project = cls()
        for path in paths:
            norm = path.replace(os.sep, "/")
            try:
                with open(path, "r", encoding="utf-8") as f:
                    source = f.read()
                tree = ast.parse(source, filename=path)
            except (OSError, SyntaxError) as exc:
                project.errors.append(f"{norm}: {exc}")
                continue
            project._add_module(norm, source, tree)
        project._index()
        return project

    def _add_module(self, path: str, source: str, tree: ast.Module) -> None:
        comments = engine.extract_comments(source)
        module = Module(path=path, dotted=_dotted_name(path), tree=tree,
                        source=source, comments=comments,
                        suppressions=engine.parse_suppressions(comments))
        self._collect_imports(module)
        self._collect_spans(module)
        self._collect_toplevel(module)
        self.modules.append(module)
        self.by_path[path] = module
        self.by_dotted[module.dotted] = module

    def _collect_imports(self, module: Module) -> None:
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    target = alias.name if alias.asname else alias.name.split(".")[0]
                    module.imports[local] = target
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:
                    pkg = module.dotted.split(".")[:-node.level]
                    base = ".".join(pkg + ([node.module] if node.module else []))
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    local = alias.asname or alias.name
                    module.imports[local] = f"{base}.{alias.name}" if base else alias.name

    def _collect_spans(self, module: Module) -> None:
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sig = _signature_lines(node)
                module._def_spans.append(
                    (node.lineno, node.end_lineno or node.lineno,
                     sig.start, sig.stop - 1))
            elif isinstance(node, ast.stmt) and not hasattr(node, "body"):
                module._stmt_spans.append(
                    (node.lineno, node.end_lineno or node.lineno))

    def _collect_toplevel(self, module: Module) -> None:
        for node in module.tree.body:
            if isinstance(node, ast.ClassDef):
                self._collect_class(module, node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = self._make_function(module, node, None, node.name)
                module.functions[node.name] = info
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                kind = _lock_factory(node.value, module.imports)
                if kind:
                    name = node.targets[0].id
                    module.lock_vars[name] = name
                    module.lock_var_kinds[name] = kind

    def _collect_class(self, module: Module, node: ast.ClassDef) -> None:
        info = ClassInfo(name=node.name, node=node, module=module)
        module.classes[node.name] = info
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = self._make_function(module, item, info,
                                         f"{node.name}.{item.name}")
                info.methods[item.name] = fn
        # Class-body declarations (dataclass fields): contract + definer.
        for item in node.body:
            if isinstance(item, ast.AnnAssign) and isinstance(item.target, ast.Name):
                name = item.target.id
            elif isinstance(item, ast.Assign) and len(item.targets) == 1 \
                    and isinstance(item.targets[0], ast.Name):
                name = item.targets[0].id
            else:
                continue
            self.attr_definers.setdefault(name, set()).add(info.name)
            lock = engine.guard_annotation(module.comments, item.lineno)
            if lock:
                info.guarded_attrs[name] = GuardSpec(lock, item.lineno)
        # Attribute contracts + lock attributes from any `self.X = ...`.
        for method in info.methods.values():
            for sub in ast.walk(method.node):
                if isinstance(sub, ast.Assign):
                    targets, value = sub.targets, sub.value
                elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                    targets, value = [sub.target], sub.value
                else:
                    continue
                for target in targets:
                    text = expr_text(target)
                    if not (text and text.startswith("self.")
                            and text.count(".") == 1):
                        continue
                    attr = text.split(".", 1)[1]
                    self.attr_definers.setdefault(attr, set()).add(info.name)
                    kind = _lock_factory(value, module.imports)
                    if kind:
                        root = attr
                        if kind == "Condition" and isinstance(value, ast.Call) \
                                and value.args:
                            underlying = expr_text(value.args[0])
                            if underlying and underlying.startswith("self."):
                                root = underlying.split(".", 1)[1]
                        info.lock_attrs[attr] = root
                        info.lock_kinds.setdefault(root, kind)
                    lock = engine.guard_annotation(module.comments, sub.lineno)
                    if lock:
                        info.guarded_attrs[attr] = GuardSpec(lock, sub.lineno)

    def _make_function(self, module: Module, node, cls, qualname) -> FunctionInfo:
        info = FunctionInfo(name=node.name, qualname=qualname, node=node,
                            module=module, cls=cls)
        for line in _signature_lines(node):
            lock = engine.guard_annotation(module.comments, line)
            if lock and info.contract is None:
                info.contract = GuardSpec(lock, line)
            if engine.is_event_loop_annotation(module.comments, line):
                info.is_loop_root = True
        module.all_functions.append(info)
        # Nested defs become their own FunctionInfo (publication points
        # live inside the tracer's hot-path closures) but are not
        # indexed as callable methods.
        for inner in _direct_nested_defs(node):
            self._make_function(module, inner, cls,
                                f"{qualname}.<locals>.{inner.name}")
        return info

    def _index(self) -> None:
        for module in self.modules:
            for cls in module.classes.values():
                self.class_index.setdefault(cls.name, []).append(cls)
                self.class_by_dotted[f"{module.dotted}.{cls.name}"] = cls
                for attr in cls.lock_attrs:
                    self.lock_attr_owners.setdefault(attr, []).append(cls)
                for attr in cls.guarded_attrs:
                    self.guarded_attr_owners.setdefault(attr, []).append(cls)
                for name, fn in cls.methods.items():
                    self.method_index.setdefault(name, []).append(fn)

    # -- resolution -----------------------------------------------------

    def _class_named(self, name: str, module: Module) -> ClassInfo | None:
        if name in module.classes:
            return module.classes[name]
        target = module.imports.get(name)
        if target and target in self.class_by_dotted:
            return self.class_by_dotted[target]
        owners = self.class_index.get(name, [])
        return owners[0] if len(owners) == 1 else None

    def resolve_lock(self, text: str, func: FunctionInfo | None) -> tuple[str, bool]:
        """Canonical name for a lock expression, plus whether it resolved
        to a *known* lock object.  Canonical forms: ``Class.attr`` for
        class locks, ``module.py::name`` otherwise (the fallback is still
        deterministic, so two identical spellings always agree)."""
        module = func.module if func else None
        parts = text.split(".")
        # self._lock inside a class that defines it
        if func and func.cls and parts[0] == "self" and len(parts) == 2 \
                and parts[1] in func.cls.lock_attrs:
            root = func.cls.lock_attrs[parts[1]]
            return f"{func.cls.name}.{root}", True
        # ClassName.attr (class-qualified contract spelling)
        if len(parts) == 2 and module is not None:
            cls = self._class_named(parts[0], module)
            if cls is not None and parts[1] in cls.lock_attrs:
                return f"{cls.name}.{cls.lock_attrs[parts[1]]}", True
        # receiver.attr where attr names a lock in exactly one class
        if len(parts) >= 2:
            owners = self.lock_attr_owners.get(parts[-1], [])
            if len(owners) == 1:
                cls = owners[0]
                return f"{cls.name}.{cls.lock_attrs[parts[-1]]}", True
        # module-level lock variable
        if len(parts) == 1 and module is not None and text in module.lock_vars:
            return f"{module.path}::{text}", True
        where = module.path if module is not None else "?"
        return f"{where}::{text}", False

    def lock_kind(self, canonical: str) -> str | None:
        """Factory kind ('Lock', 'RLock', ...) for a canonical lock name."""
        if "::" in canonical:
            path, name = canonical.split("::", 1)
            mod = self.by_path.get(path)
            return mod.lock_var_kinds.get(name) if mod else None
        if "." in canonical:
            cname, attr = canonical.rsplit(".", 1)
            for cls in self.class_index.get(cname, []):
                if attr in cls.lock_kinds:
                    return cls.lock_kinds[attr]
        return None

    def canonical_call_text(self, call: ast.Call, module: Module) -> str | None:
        """Dotted call target with the first component resolved through
        the module's imports (``from time import sleep`` → ``time.sleep``)."""
        text = expr_text(call.func)
        if text is None:
            return None
        head, _, rest = text.partition(".")
        full_head = module.imports.get(head, head)
        return full_head + (("." + rest) if rest else "")

    def resolve_call(self, call: ast.Call, func: FunctionInfo) -> list[FunctionInfo]:
        """Best-effort callee resolution; empty list when ambiguous."""
        f = call.func
        module = func.module
        if isinstance(f, ast.Name):
            name = f.id
            if name in module.functions:
                return [module.functions[name]]
            cls = module.classes.get(name)
            if cls is None:
                target = module.imports.get(name)
                if target:
                    owner_dotted, _, leaf = target.rpartition(".")
                    owner = self.by_dotted.get(owner_dotted)
                    if owner is not None:
                        if leaf in owner.functions:
                            return [owner.functions[leaf]]
                        cls = owner.classes.get(leaf)
            if cls is not None and "__init__" in cls.methods:
                return [cls.methods["__init__"]]
            return []
        if isinstance(f, ast.Attribute):
            recv = expr_text(f.value)
            name = f.attr
            if recv == "self" and func.cls is not None:
                if name in func.cls.methods:
                    return [func.cls.methods[name]]
            if recv is not None and "." not in recv:
                cls = self._class_named(recv, module)
                if cls is not None and name in cls.methods:
                    return [cls.methods[name]]
                target = module.imports.get(recv)
                if target is not None:
                    owner = self.by_dotted.get(target)
                    if owner is not None and name in owner.functions:
                        return [owner.functions[name]]
            owners = self.method_index.get(name, [])
            if len(owners) == 1:
                return owners
        return []
