"""loop-blocking rule: nothing slow may run on the selector thread.

Roots are functions whose ``def`` line carries ``# lint: event-loop``
(``IngestServer._loop``).  The rule walks the resolvable call graph from
each root and flags, in any reachable function, calls that can stall the
event loop:

* ``time.sleep``;
* ``os.fsync`` and the journal-compaction file ops (``os.replace``,
  ``os.rename``, ``os.remove``, ``os.unlink``) — a rotation seal or
  prune is milliseconds of disk latency every connected host pays;
* blocking socket setup: ``socket.create_connection`` without a
  ``timeout``, ``sock.setblocking(True)``, ``sock.settimeout(None)``,
  ``sock.makefile`` (returns a *blocking* file wrapper);
* unbounded waits: zero-argument ``.join()`` / ``.wait()``, and
  ``select.select`` / ``selector.select()`` with no timeout.

Each finding carries the call chain from the root, so "why is this on
the loop thread" is answerable from the report alone.  Intentional
exceptions (the opt-in journal fsync) are suppressed inline with a
reason or carried in the baseline with a written justification.
"""
from __future__ import annotations

import ast

from repro.lint import analysis
from repro.lint.engine import Finding

RULE = "loop-blocking"

#: Canonical call targets that block unconditionally.
_DENY_CALLS = {
    "time.sleep": "time.sleep() stalls the event loop",
    "os.fsync": "os.fsync() is a synchronous disk barrier",
    "os.replace": "os.replace() is synchronous disk metadata I/O",
    "os.rename": "os.rename() is synchronous disk metadata I/O",
    "os.remove": "os.remove() is synchronous disk metadata I/O",
    "os.unlink": "os.unlink() is synchronous disk metadata I/O",
}


def _is_none(node: ast.AST | None) -> bool:
    return isinstance(node, ast.Constant) and node.value is None


def _is_true(node: ast.AST | None) -> bool:
    return isinstance(node, ast.Constant) and node.value is True


def _blocking_reason(call: ast.Call, canonical: str | None) -> str | None:
    """Why this call blocks, or None if it is loop-safe."""
    if canonical in _DENY_CALLS:
        return _DENY_CALLS[canonical]
    func = call.func
    attr = func.attr if isinstance(func, ast.Attribute) else None
    kwargs = {kw.arg: kw.value for kw in call.keywords}
    if canonical == "socket.create_connection":
        if len(call.args) < 2 and "timeout" not in kwargs:
            return "socket.create_connection() without a timeout blocks"
        return None
    if attr == "setblocking" and call.args and _is_true(call.args[0]):
        return "setblocking(True) makes the socket block the loop"
    if attr == "settimeout" and call.args and _is_none(call.args[0]):
        return "settimeout(None) makes the socket block the loop"
    if attr == "makefile":
        return "makefile() returns a blocking file wrapper"
    if attr == "join" and not call.args and not call.keywords:
        return "join() without a timeout waits unboundedly"
    if attr == "wait" and not call.args and "timeout" not in kwargs:
        return "wait() without a timeout waits unboundedly"
    if canonical == "select.select" and len(call.args) < 4 \
            and "timeout" not in kwargs:
        return "select.select() without a timeout blocks"
    if attr == "select" and canonical != "select.select" \
            and not call.args and "timeout" not in kwargs:
        return "selector.select() without a timeout blocks"
    return None


def _reachable_from_roots(project: analysis.Project):
    """BFS over the call graph; returns func -> chain-of-qualnames from
    its nearest root (roots map to a one-element chain)."""
    chains: dict[analysis.FunctionInfo, list[str]] = {}
    queue: list[analysis.FunctionInfo] = []
    for module in project.modules:
        for func in module.all_functions:
            if func.is_loop_root:
                chains[func] = [func.qualname]
                queue.append(func)
    while queue:
        func = queue.pop(0)
        for call, _held, _stmt in func.call_sites(project):
            for callee in project.resolve_call(call, func):
                if callee in chains:
                    continue
                chains[callee] = chains[func] + [callee.qualname]
                queue.append(callee)
    return chains


def check_loop_blocking(project: analysis.Project) -> list[Finding]:
    findings: list[Finding] = []
    for func, chain in _reachable_from_roots(project).items():
        module = func.module
        via = " -> ".join(chain)
        for call, _held, _stmt in func.call_sites(project):
            canonical = project.canonical_call_text(call, module)
            reason = _blocking_reason(call, canonical)
            if reason is None:
                continue
            label = canonical or "call"
            findings.append(Finding(
                rule=RULE, path=module.path, line=call.lineno,
                message=f"{reason}; reachable from event loop via {via}",
                symbol=f"{func.qualname}:{label}"))
    return findings
