"""Entry point: ``python -m repro.lint``."""
import sys

from repro.lint.runner import main

sys.exit(main())
