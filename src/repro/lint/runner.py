"""CLI for the concurrency lint: ``python -m repro.lint [paths...]``.

Exit codes: 0 clean, 1 findings (or stale baseline entries), 2 usage or
parse errors.  ``--json`` emits the machine-readable report CI archives;
the default text output is one ``path:line: [rule] message`` per finding.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.lint.engine import Baseline, run_lint

DEFAULT_BASELINE = "lint-baseline.json"


def collect_files(paths: list[str],
                  exclude: list[str] | None = None) -> list[str]:
    skip = [os.path.normpath(e) for e in (exclude or [])]

    def excluded(p: str) -> bool:
        q = os.path.normpath(p)
        return any(q == e or q.startswith(e + os.sep) for e in skip)

    files: list[str] = []
    for path in paths:
        if os.path.isdir(path):
            for root, dirs, names in os.walk(path):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git")
                                 and not excluded(os.path.join(root, d)))
                for name in sorted(names):
                    if name.endswith(".py") \
                            and not excluded(os.path.join(root, name)):
                        files.append(os.path.join(root, name))
        elif not excluded(path):
            files.append(path)
    return files


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="concurrency lint: guarded-by, lock-order, "
                    "loop-blocking, publication-order")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files or directories to lint (default: src)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit the JSON report instead of text")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help=f"baseline file (default {DEFAULT_BASELINE})")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore any baseline file")
    parser.add_argument("--exclude", action="append", default=[],
                        metavar="PATH",
                        help="path prefix to skip (repeatable; e.g. "
                             "tests/lint_fixtures, whose bad_*.py must "
                             "keep flagging in the fixture self-check)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept current findings into the baseline "
                             "(reasons default to TODO and must be edited)")
    args = parser.parse_args(argv)

    paths = args.paths or ["src"]
    files = collect_files(paths, exclude=args.exclude)
    if not files:
        print(f"repro.lint: no python files under {paths}", file=sys.stderr)
        return 2

    baseline = None
    if not args.no_baseline and not args.write_baseline \
            and os.path.exists(args.baseline):
        try:
            baseline = Baseline.load(args.baseline)
        except (ValueError, OSError, json.JSONDecodeError) as exc:
            print(f"repro.lint: bad baseline: {exc}", file=sys.stderr)
            return 2

    result = run_lint(files, baseline=baseline)

    if args.write_baseline:
        for f in result.findings:
            f.suppressed_by = None
        Baseline.write(args.baseline, result.findings,
                       reason="TODO: justify this accepted finding")
        print(f"wrote {len(result.findings)} entr"
              f"{'y' if len(result.findings) == 1 else 'ies'} to "
              f"{args.baseline}; edit the reasons before committing")
        return 0

    if args.as_json:
        print(json.dumps(result.to_json(), indent=2))
    else:
        for err in result.errors:
            print(f"error: {err}")
        for f in result.findings:
            print(f.render())
        for fp in result.stale_baseline:
            print(f"stale baseline entry (fixed? delete it): {fp}")
        bits = [f"{len(result.findings)} finding"
                f"{'' if len(result.findings) == 1 else 's'}"]
        if result.suppressed:
            bits.append(f"{len(result.suppressed)} suppressed inline")
        if result.baselined:
            bits.append(f"{len(result.baselined)} baselined")
        if result.stale_baseline:
            bits.append(f"{len(result.stale_baseline)} stale baseline entries")
        print(f"repro.lint: {', '.join(bits)} across {len(files)} files")

    if result.errors:
        return 2
    return 0 if result.ok else 1


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
