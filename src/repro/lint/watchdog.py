"""Runtime lock-order sanitizer: the dynamic half of the lock-order rule.

While installed, ``threading.Lock``/``threading.RLock`` return proxies
that record, per thread, which lock was acquired while which others were
held.  Locks are identified by *creation site* (``file:line``), the same
granularity the static pass reasons at — every ``_HostState.lock`` is
one node, exactly like the AST rule's ``_HostState.lock``.  At the end
of a test session the recorded edges are checked for cycles; a cycle
means two code paths disagreed about acquisition order *in an actual
run*, cross-validating the static rule's graph with ground truth.

``threading.Condition`` needs no patching: a bare ``Condition()``
allocates its lock via the (patched) module-global ``RLock``, and
``Condition(existing_lock)`` wraps whatever proxy it is handed, so
condition acquires are recorded through the underlying lock either way.

Deliberate limits:

* re-entrant acquires of the *same proxy* record no edge (RLock
  re-entrancy is legal);
* nesting two locks from the *same* creation site (e.g. two different
  hosts' ``_HostState.lock``) records no edge either — a site-level
  graph cannot express per-instance ordering disciplines, and a false
  self-edge would fail CI on correct code;
* edge recording uses an *unpatched* lock internally, so the watchdog
  never feeds back into its own graph.

Opt out with ``GAPP_LOCK_WATCHDOG=0`` (see ``tests/conftest.py``).
"""
from __future__ import annotations

import os
import sys
import threading


def _creation_site(depth: int = 2) -> str:
    """file:line of the frame that called the lock factory, skipping
    frames inside this module and inside ``threading`` itself."""
    frame = sys._getframe(depth)
    here = os.path.normcase(__file__)
    while frame is not None:
        fname = os.path.normcase(frame.f_code.co_filename)
        if fname != here and not fname.endswith(os.sep + "threading.py"):
            return f"{os.path.basename(frame.f_code.co_filename)}:{frame.f_lineno}"
        frame = frame.f_back
    return "<unknown>"


class _LockProxy:
    """Wraps a real lock; records acquisition order through its watchdog."""

    __slots__ = ("_wd", "_inner", "site")

    def __init__(self, wd: "LockWatchdog", inner, site: str):
        self._wd = wd
        self._inner = inner
        self.site = site

    def acquire(self, blocking=True, timeout=-1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._wd._note_acquire(self)
        return got

    def release(self):
        self._wd._note_release(self)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._inner.locked()

    def __getattr__(self, name):
        # _is_owned/_release_save/_acquire_restore for Condition, etc.
        return getattr(self._inner, name)


class LockWatchdog:
    """Install/uninstall the factory patches and hold the edge graph."""

    def __init__(self):
        self._orig_lock = threading.Lock
        self._orig_rlock = threading.RLock
        # Internal state is protected by an *unpatched* lock so the
        # watchdog's own synchronization never records edges.
        self._mu = self._orig_lock()
        self._tls = threading.local()
        self._active = False
        # (site_a, site_b) -> example "thread: a -> b" description
        self.edges: dict[tuple[str, str], str] = {}

    # -- patching -------------------------------------------------------

    def install(self) -> None:
        wd = self

        def make_lock():
            return _LockProxy(wd, wd._orig_lock(), _creation_site())

        def make_rlock():
            return _LockProxy(wd, wd._orig_rlock(), _creation_site())

        self._active = True
        threading.Lock = make_lock
        threading.RLock = make_rlock

    def uninstall(self) -> None:
        self._active = False
        threading.Lock = self._orig_lock
        threading.RLock = self._orig_rlock

    # -- recording ------------------------------------------------------

    def _held(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _note_acquire(self, proxy: _LockProxy) -> None:
        if not self._active:
            return
        stack = self._held()
        if not any(p is proxy for p in stack):
            # NOT threading.current_thread(): in a freshly-bootstrapped
            # thread (3.10 sets Thread._started before registering in
            # threading._active) it would fabricate a _DummyThread whose
            # own Event acquires another proxied lock — and recurse here
            # forever, killing the bootstrap before _started.set() and
            # hanging Thread.start() in the parent.
            ident = threading.get_ident()
            reg = threading._active.get(ident)
            tname = reg.name if reg is not None else f"thread-{ident}"
            new_edges = []
            for held in stack:
                if held.site != proxy.site:
                    new_edges.append((held.site, proxy.site, tname))
            if new_edges:
                with self._mu:
                    for a, b, t in new_edges:
                        self.edges.setdefault(
                            (a, b), f"{t}: {a} then {b}")
        stack.append(proxy)

    def _note_release(self, proxy: _LockProxy) -> None:
        stack = getattr(self._tls, "stack", None)
        if not stack:
            return
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is proxy:
                del stack[i]
                return

    # -- checking -------------------------------------------------------

    def cycles(self) -> list[str]:
        """Human-readable description of every cycle in the site graph."""
        with self._mu:
            edges = dict(self.edges)
        adj: dict[str, list[str]] = {}
        for a, b in edges:
            adj.setdefault(a, []).append(b)

        out: list[str] = []
        seen: set[frozenset] = set()
        # DFS cycle search; the graphs here are tiny (dozens of sites).
        for start in sorted(adj):
            path: list[str] = []
            on_path: set[str] = set()

            def dfs(node):
                path.append(node)
                on_path.add(node)
                for nxt in sorted(adj.get(node, ())):
                    if nxt in on_path:
                        cyc = path[path.index(nxt):] + [nxt]
                        key = frozenset(cyc)
                        if key not in seen:
                            seen.add(key)
                            detail = "; ".join(
                                edges.get((a, b), f"{a} then {b}")
                                for a, b in zip(cyc, cyc[1:]))
                            out.append(" -> ".join(cyc) + f" ({detail})")
                    elif nxt in adj:
                        dfs(nxt)
                path.pop()
                on_path.discard(node)

            dfs(start)
        return out
