"""Concurrency lint — static enforcement of this repo's locking invariants.

GAPP's premise is that serialization bugs surface too late, at runtime;
this repo is itself a heavily threaded system (the lock-free tracer, the
selector ``IngestServer``, ``SpillStore`` journals, the session fold
worker), and every concurrency invariant so far was caught only by chaos
testing after the fact.  ``python -m repro.lint`` closes that loop: an
AST-based rule engine proves the documented invariants *before* the code
runs, and CI gates it next to tier-1.

Rules (see each module for exact semantics; README "Concurrency
invariants" documents the annotation grammar):

* ``guarded-by`` (:mod:`repro.lint.guarded`) — ``# guarded-by: <lock>``
  contracts on shared attributes; every mutation must happen with the
  named lock held (lexically inside ``with <lock>:`` or in a method whose
  ``def`` line carries the same contract, meaning "caller holds it").
* ``lock-order`` (:mod:`repro.lint.lockorder`) — builds the
  interprocedural lock-acquisition graph per module and reports any
  cycle (the PR 4 ABBA shape: ``self._lock`` → ``st.lock`` in one path,
  ``st.lock`` → ``self._lock`` in another).
* ``loop-blocking`` (:mod:`repro.lint.blocking`) — no ``time.sleep``,
  ``os.fsync``, journal compaction, or unbounded waits reachable from a
  ``# lint: event-loop`` root (the ``IngestServer._loop`` selector
  callbacks).
* ``publication-order`` (:mod:`repro.lint.publication`) —
  ``# publishes: <fields>`` marks a publication point (the shard
  ``deque.append``); every listed row field must be written before it,
  never after.

Suppress a finding with ``# lint: disable=<rule>(<reason>)`` on the
offending line (or the enclosing ``def`` line for the whole function); a
reason is mandatory.  Accepted legacy findings live in the committed
baseline file (``lint-baseline.json``), each with a written
justification; ``--write-baseline`` regenerates it.
"""
from repro.lint.engine import (Baseline, Finding, LintResult,  # noqa: F401
                               run_lint)
from repro.lint.runner import main  # noqa: F401

RULES = ("guarded-by", "lock-order", "loop-blocking", "publication-order")
