"""publication-order rule: row fields are complete before publication.

The lock-free tracer publishes a row by appending to a deque (the
CPython-atomic publication point); readers may observe the row the
instant the append lands, so every field must already be written.  The
contract is spelled at the publication statement::

    self.head = i + 1  # publishes: self.times, self.workers, self.deltas

For each listed field the rule checks, within the enclosing function's
statement order, that

* at least one statement *before* the publication writes the field, and
* no statement *after* it writes the field (a late write is exactly the
  torn-row bug the deque ordering exists to prevent).

A "write" of field ``F`` is an assignment/augassign whose target is
``F``, ``F[...]`` or ``F.<sub>``, an in-place mutator call
(``F.append(...)``), or — for bare names — a call ``F(...)`` (the hot
path binds ``times.append`` to a local, so calling it *is* the write).
"""
from __future__ import annotations

import ast

from repro.lint import analysis
from repro.lint.analysis import MUTATOR_METHODS, expr_text
from repro.lint.engine import Finding, publish_annotation

RULE = "publication-order"


def _flat_statements(func: analysis.FunctionInfo):
    """All statements of the function body in source order, without
    descending into nested defs, each with its *position chain* — the
    ``(body_id, index)`` path from the function body down to the
    statement.  Chains order statements control-flow-sensibly: two
    statements in sibling branches of one ``if`` share no body at their
    divergence point and are mutually unordered."""
    out: list[tuple[ast.stmt, tuple]] = []

    def walk(stmts, chain):
        body_key = id(stmts)
        for idx, st in enumerate(stmts):
            here = chain + ((body_key, idx),)
            out.append((st, here))
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for body in analysis._sub_bodies(st):
                walk(body, here)

    walk(func.node.body, ())
    return out


def _compare(chain_a: tuple, chain_b: tuple) -> int | None:
    """-1 if a executes before b, 1 if after, None if unordered
    (sibling branches) or identical."""
    for (key_a, idx_a), (key_b, idx_b) in zip(chain_a, chain_b):
        if key_a != key_b:
            return None
        if idx_a != idx_b:
            return -1 if idx_a < idx_b else 1
    return None  # one is an ancestor of the other, or the same statement


def _writes_field(stmt: ast.stmt, field: str) -> bool:
    dotted = "." in field

    def target_matches(text: str | None) -> bool:
        return text is not None and (text == field
                                     or text.startswith(field + "."))

    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
        stack = list(targets)
        while stack:
            t = stack.pop()
            if isinstance(t, (ast.Tuple, ast.List)):
                stack.extend(t.elts)
            elif isinstance(t, ast.Starred):
                stack.append(t.value)
            elif isinstance(t, ast.Subscript):
                if target_matches(expr_text(t.value)):
                    return True
            elif target_matches(expr_text(t)):
                return True
        return False
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        func = stmt.value.func
        if isinstance(func, ast.Attribute) and func.attr in MUTATOR_METHODS \
                and target_matches(expr_text(func.value)):
            return True
        if not dotted and isinstance(func, ast.Name) and func.id == field:
            return True  # bound-method local: ta(...) IS the append
    return False


def check_publication_order(project: analysis.Project) -> list[Finding]:
    findings: list[Finding] = []
    for module in project.modules:
        annotated_lines = {line for line in module.comments
                           if publish_annotation(module.comments, line)}
        if not annotated_lines:
            continue
        for func in module.all_functions:
            stmts = _flat_statements(func)
            for stmt, chain in stmts:
                if hasattr(stmt, "body"):
                    continue
                span = range(stmt.lineno, (stmt.end_lineno or stmt.lineno) + 1)
                fields = None
                for line in span:
                    if line in annotated_lines:
                        fields = publish_annotation(module.comments, line)
                        break
                if not fields:
                    continue
                before = [s for s, c in stmts if _compare(c, chain) == -1]
                after = [s for s, c in stmts if _compare(c, chain) == 1]
                for field in fields:
                    if not any(_writes_field(s, field) for s in before):
                        findings.append(Finding(
                            rule=RULE, path=module.path, line=stmt.lineno,
                            message=(f"publication point declares {field} "
                                     "but nothing writes it beforehand "
                                     f"(in {func.qualname})"),
                            symbol=f"{func.qualname}:{field}:unwritten"))
                    late = next((s for s in after if _writes_field(s, field)),
                                None)
                    if late is not None:
                        findings.append(Finding(
                            rule=RULE, path=module.path, line=late.lineno,
                            message=(f"{field} written after its publication "
                                     f"point at line {stmt.lineno} — readers "
                                     "can observe a torn row (in "
                                     f"{func.qualname})"),
                            symbol=f"{func.qualname}:{field}:late-write"))
    return findings
