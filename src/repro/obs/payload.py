"""The shared live-payload builder behind watch callbacks and the wire.

``session.watch(cb, payload=True)``, ``GET /api/stream`` and the
dashboard's poll loop all consume the same JSON-ready dict built here —
one builder, so the callback surface and the HTTP surface cannot drift
(the ISSUE-9 satellite: watch payloads gain ``worker_hosts`` /
``per_host`` host lanes by reusing exactly this).
"""
from __future__ import annotations

from repro.core.report import path_entries

#: Version of the payload layout (independent of the report JSON schema;
#: bump on breaking changes).
PAYLOAD_SCHEMA_VERSION = 1

# Capture-health counters surfaced under ``health`` — session-level keys
# first, then fleet-source keys (present only when the session reads a
# FleetSource).  Missing keys are simply absent, so single-host sessions
# get the slim form.
_SESSION_HEALTH_KEYS = ("events_pending", "ring_dropped",
                        "tolerance_dropped", "sanitize_dropped",
                        "watch_errors")
_SOURCE_HEALTH_KEYS = ("hosts", "buffered_rows", "shed_chunks",
                       "shed_rows", "clock_clamped", "idle_hosts",
                       "accepting")


def build_watch_payload(session, rep=None, top_n: int | None = None) -> dict:
    """One JSON-ready frame of live profile state.

    ``rep`` is the report to summarise (computed via
    ``session.snapshot(top_n)`` when not given — pass it when the caller
    already has this tick's snapshot, e.g. the watch firing loop, so the
    fold is not paid twice).
    """
    if rep is None:
        rep = session.snapshot(top_n)
    stats = session.stats()
    fleet = rep.worker_hosts is not None and len(rep.worker_hosts) > 0
    health = {k: stats[k] for k in _SESSION_HEALTH_KEYS if k in stats}
    source = stats.get("source")
    if isinstance(source, dict):
        for k in _SOURCE_HEALTH_KEYS:
            if k in source:
                health[k] = source[k]
    return {
        "schema_version": PAYLOAD_SCHEMA_VERSION,
        "mode": stats.get("mode"),
        "events_folded": stats.get("events_folded", 0),
        "total_time_s": rep.total_time,
        "total_slices": rep.total_slices,
        "total_critical": rep.total_critical,
        "critical_ratio": rep.critical_ratio,
        "top": path_entries(rep, top_n),
        "worker_hosts": list(rep.worker_hosts) if fleet else [],
        "per_host": rep.per_host() if fleet else {},
        "health": health,
    }
