"""Minimal HTTP/1.1 request/response framing for the profiler service.

Pure functions over byte buffers — parsing never does I/O, so the
service's selector event loop stays non-blocking by construction (the
loop-blocking lint walks through here).  Deliberately tiny rather than
general: the service is GET-only, bodies are ignored, responses close
the connection (except ``/api/stream``, which switches to chunked
transfer and stays open until the client hangs up).
"""
from __future__ import annotations

import dataclasses
import json
from urllib.parse import parse_qsl, unquote, urlsplit

#: Upper bound on one request head; a client that sends more without a
#: blank line is broken or hostile and gets a 400.
MAX_REQUEST_BYTES = 64 * 1024

_REASONS = {
    200: "OK",
    400: "Bad Request",
    404: "Not Found",
    405: "Method Not Allowed",
    500: "Internal Server Error",
    503: "Service Unavailable",
}


class HttpError(Exception):
    """Malformed request; carries the status the server should answer."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = int(status)
        self.message = message


@dataclasses.dataclass
class Request:
    """One parsed request head (GET has no body we care about)."""
    method: str
    path: str
    query: dict[str, str]
    headers: dict[str, str]    # keys lower-cased

    def query_int(self, key: str, default: int | None = None,
                  lo: int | None = None,
                  hi: int | None = None) -> int | None:
        raw = self.query.get(key)
        if raw is None or raw == "":
            return default
        try:
            v = int(raw)
        except ValueError:
            raise HttpError(400, f"query parameter {key!r} must be an "
                            f"integer (got {raw!r})") from None
        if lo is not None:
            v = max(v, lo)
        if hi is not None:
            v = min(v, hi)
        return v

    def query_float(self, key: str,
                    default: float | None = None) -> float | None:
        raw = self.query.get(key)
        if raw is None or raw == "":
            return default
        try:
            return float(raw)
        except ValueError:
            raise HttpError(400, f"query parameter {key!r} must be a "
                            f"number (got {raw!r})") from None


def parse_request(buf: bytes) -> tuple[Request, int] | None:
    """Parse one request head out of ``buf``.

    Returns ``(request, consumed_bytes)`` once the blank line has
    arrived, ``None`` while the head is still incomplete, and raises
    :class:`HttpError` on garbage (malformed request line, non-HTTP/1.x,
    or a head exceeding :data:`MAX_REQUEST_BYTES`).
    """
    end = buf.find(b"\r\n\r\n")
    if end < 0:
        if len(buf) > MAX_REQUEST_BYTES:
            raise HttpError(400, "request head too large")
        return None
    try:
        head = buf[:end].decode("latin-1")
    except UnicodeDecodeError:      # pragma: no cover - latin-1 total
        raise HttpError(400, "undecodable request head") from None
    lines = head.split("\r\n")
    parts = lines[0].split()
    if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
        raise HttpError(400, f"malformed request line {lines[0]!r}")
    method, target, _version = parts
    sp = urlsplit(target)
    query = dict(parse_qsl(sp.query, keep_blank_values=True))
    headers: dict[str, str] = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    path = unquote(sp.path) or "/"
    return Request(method.upper(), path, query, headers), end + 4


def response(status: int, body: bytes | str = b"",
             content_type: str = "application/json; charset=utf-8",
             extra_headers: tuple[str, ...] = ()) -> bytes:
    """Frame one complete ``Connection: close`` response."""
    if isinstance(body, str):
        body = body.encode("utf-8")
    reason = _REASONS.get(status, "Unknown")
    head = [
        f"HTTP/1.1 {status} {reason}",
        f"Content-Type: {content_type}",
        f"Content-Length: {len(body)}",
        "Cache-Control: no-store",
        "Connection: close",
        *extra_headers,
    ]
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1") + body


def json_response(status: int, doc) -> bytes:
    return response(status, json.dumps(doc, indent=2))


def error_response(status: int, message: str) -> bytes:
    return json_response(status, {"status": status, "error": message})


def stream_head(content_type: str = "application/x-ndjson") -> bytes:
    """Response head opening a chunked (unbounded) body — the
    ``/api/stream`` framing; follow with :func:`chunk` payloads."""
    head = [
        "HTTP/1.1 200 OK",
        f"Content-Type: {content_type}",
        "Transfer-Encoding: chunked",
        "Cache-Control: no-store",
        "Connection: close",
    ]
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1")


def chunk(data: bytes | str) -> bytes:
    """One chunked-transfer frame (empty input frames the terminator)."""
    if isinstance(data, str):
        data = data.encode("utf-8")
    return f"{len(data):x}\r\n".encode("latin-1") + data + b"\r\n"
