"""Observability primitives for the continuous-profiling service.

Small, dependency-free building blocks the serving layer
(:mod:`repro.fleet.service`) composes:

* :mod:`repro.obs.http` — just enough HTTP/1.1 to parse a GET and frame
  a response (plus chunked transfer for ``/api/stream``), all pure
  functions over byte buffers so the selector event loop never blocks;
* :mod:`repro.obs.prom` — Prometheus text exposition over the profiler's
  own stats dicts (no client library);
* :mod:`repro.obs.payload` — the shared top-N/host-lanes payload builder
  behind ``session.watch(..., payload=True)`` and ``GET /api/stream``;
* :mod:`repro.obs.dashboard` — the inline no-dependency HTML dashboard
  served at ``GET /``.
"""
from repro.obs.http import (HttpError, Request, chunk, parse_request,
                            response, stream_head)
from repro.obs.payload import build_watch_payload
from repro.obs.prom import flatten_stats, render_metrics

__all__ = [
    "HttpError", "Request", "build_watch_payload", "chunk",
    "flatten_stats", "parse_request", "render_metrics", "response",
    "stream_head",
]
