"""The ``GET /`` dashboard — one self-contained HTML page, zero deps.

Inline CSS + vanilla JS polling ``/api/top`` (ranked bottlenecks with
window deltas) and ``/api/hosts`` (per-host lanes + capture-health
strip).  No build step, no external assets, works from ``curl`` dumped
to a file — the "point a browser at a running fleet" product shape with
nothing to install on the aggregator.
"""

DASHBOARD_HTML = """<!doctype html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>GAPP fleet profiler</title>
<style>
  :root { color-scheme: dark; }
  body { background:#14161a; color:#d8dce2; font:14px/1.45 ui-monospace,
         SFMono-Regular,Menlo,Consolas,monospace; margin:1.2rem; }
  h1 { font-size:1.15rem; margin:0 0 .2rem; color:#fff; }
  .sub { color:#8b93a1; margin-bottom:1rem; }
  .strip { display:flex; flex-wrap:wrap; gap:.6rem; margin:.8rem 0; }
  .pill { background:#1e2128; border:1px solid #2c313a; border-radius:6px;
          padding:.25rem .6rem; }
  .pill b { color:#fff; }
  .pill.bad { border-color:#a33; color:#f2a0a0; }
  table { border-collapse:collapse; width:100%; margin:.4rem 0 1.2rem; }
  th, td { text-align:left; padding:.3rem .6rem;
           border-bottom:1px solid #262a32; }
  th { color:#8b93a1; font-weight:normal; }
  td.num, th.num { text-align:right; font-variant-numeric:tabular-nums; }
  .up { color:#ff8f8f; } .down { color:#8fe3a0; } .flat { color:#8b93a1; }
  .lane { display:flex; align-items:center; gap:.6rem; margin:.2rem 0; }
  .lane .name { width:14rem; overflow:hidden; text-overflow:ellipsis;
                white-space:nowrap; }
  .bar { height:.8rem; background:#3a6ea5; border-radius:2px;
         min-width:2px; }
  .lane .val { color:#8b93a1; }
  h2 { font-size:.95rem; color:#aeb6c2; margin:1.2rem 0 .3rem; }
  #err { color:#f2a0a0; }
</style>
</head>
<body>
<h1>GAPP fleet profiler</h1>
<div class="sub">live serialization bottlenecks —
  <a href="/api/report" style="color:#7aa2d6">report</a> ·
  <a href="/api/top" style="color:#7aa2d6">top</a> ·
  <a href="/api/hosts" style="color:#7aa2d6">hosts</a> ·
  <a href="/metrics" style="color:#7aa2d6">metrics</a>
  <span id="err"></span></div>
<div class="strip" id="health"></div>
<h2>top bottlenecks <span id="winlabel" class="flat"></span></h2>
<table><thead><tr><th class="num">#</th><th>path</th>
<th class="num">CMetric (ms)</th><th class="num">&Delta; window</th>
<th class="num">slices</th></tr></thead><tbody id="top"></tbody></table>
<h2>what-if <span class="flat">(counterfactual projection)</span></h2>
<form id="wiform">
  <input id="witarget" size="34"
         placeholder="tag &mdash; or host:NAME, worker:NAME, #rank">
  shrink <input id="wishrink" value="0" size="4">
  <button>project</button>
</form>
<div id="wiout"></div>
<h2>per-host lanes</h2>
<div id="lanes"></div>
<script>
"use strict";
const fmtMs = s => (s * 1e3).toFixed(3);
function esc(s) { const d = document.createElement("span");
  d.textContent = String(s); return d.innerHTML; }
async function poll() {
  try {
    const top = await (await fetch("/api/top?n=15")).json();
    const hosts = await (await fetch("/api/hosts")).json();
    document.getElementById("err").textContent = "";
    render(top, hosts);
  } catch (e) {
    document.getElementById("err").textContent = " — poll failed: " + e;
  }
  setTimeout(poll, 2000);
}
function render(top, hosts) {
  const rows = [];
  for (const e of top.entries || []) {
    let d = '<span class="flat">&ndash;</span>';
    if (e.delta_cmetric_s != null && Math.abs(e.delta_cmetric_s) > 1e-9) {
      const up = e.delta_cmetric_s > 0;
      d = `<span class="${up ? "up" : "down"}">${up ? "&#9650;" : "&#9660;"} ` +
          `${fmtMs(Math.abs(e.delta_cmetric_s))}</span>`;
    }
    rows.push(`<tr><td class="num">${e.rank}</td><td>${esc(e.path)}</td>` +
      `<td class="num">${fmtMs(e.cmetric_s)}</td><td class="num">${d}</td>` +
      `<td class="num">${e.slices}</td></tr>`);
  }
  document.getElementById("top").innerHTML = rows.join("");
  document.getElementById("winlabel").textContent =
    top.window_s ? `(last ${top.window_s}s, vs previous poll)`
                 : "(whole capture, vs previous poll)";
  const lanes = [];
  const ph = hosts.hosts || {};
  const max = Math.max(1e-12,
    ...Object.values(ph).map(h => h.cmetric_s || 0));
  for (const [name, h] of Object.entries(ph)
         .sort((a, b) => (b[1].cmetric_s || 0) - (a[1].cmetric_s || 0))) {
    const w = Math.max(1, Math.round(420 * (h.cmetric_s || 0) / max));
    lanes.push(`<div class="lane"><span class="name">${esc(name)}</span>` +
      `<span class="bar" style="width:${w}px"></span>` +
      `<span class="val">${fmtMs(h.cmetric_s || 0)} ms · ` +
      `${h.workers} worker(s) · ${h.critical} critical</span></div>`);
  }
  document.getElementById("lanes").innerHTML =
    lanes.join("") || '<span class="flat">no host lanes ' +
    '(single-host session)</span>';
  const strip = [];
  const H = hosts.health || {};
  const bad = k => ["shed_chunks", "shed_rows", "ring_dropped",
                    "lost_chunks", "watch_errors"].includes(k) && H[k] > 0;
  strip.push(`<span class="pill">mode <b>${esc(hosts.mode || "?")}</b></span>`);
  strip.push(`<span class="pill">events folded ` +
             `<b>${hosts.events_folded ?? 0}</b></span>`);
  for (const [k, v] of Object.entries(H)) {
    strip.push(`<span class="pill${bad(k) ? " bad" : ""}">` +
               `${esc(k)} <b>${esc(v)}</b></span>`);
  }
  document.getElementById("health").innerHTML = strip.join("");
}
async function whatif(ev) {
  ev.preventDefault();
  const raw = document.getElementById("witarget").value.trim();
  const shrink = document.getElementById("wishrink").value.trim() || "0";
  const out = document.getElementById("wiout");
  if (!raw) { out.innerHTML = ""; return; }
  let q;
  if (raw.startsWith("#")) q = "path=" + encodeURIComponent(raw.slice(1));
  else if (raw.startsWith("host:"))
    q = "host=" + encodeURIComponent(raw.slice(5));
  else if (raw.startsWith("worker:"))
    q = "worker=" + encodeURIComponent(raw.slice(7));
  else q = "tag=" + encodeURIComponent(raw);
  try {
    const r = await fetch(
      `/api/whatif?${q}&shrink=${encodeURIComponent(shrink)}`);
    const d = await r.json();
    if (!r.ok) {
      out.innerHTML =
        `<span class="pill bad">${esc(d.error || r.status)}</span>`;
      return;
    }
    const sp = d.speedup == null ? "&infin;" : d.speedup.toFixed(3) + "x";
    const rows = (d.ranking || []).slice(0, 8).map(e => {
      let mv = '<span class="flat">&ndash;</span>';
      if (e.baseline_rank == null) mv = '<span class="up">new</span>';
      else if (e.rank_delta) {
        const up = e.rank_delta > 0;  // prev - new: positive moved up
        mv = `<span class="${up ? "up" : "down"}">` +
             `${up ? "&#9650;" : "&#9660;"}${Math.abs(e.rank_delta)}</span>`;
      }
      return `<tr><td class="num">${e.rank}</td><td>${esc(e.path)}</td>` +
        `<td class="num">${fmtMs(e.cmetric_s)}</td>` +
        `<td class="num">${mv}</td></tr>`;
    });
    out.innerHTML =
      `<div class="strip">` +
      `<span class="pill">projected speedup <b>${sp}</b></span>` +
      `<span class="pill">saves <b>${fmtMs(d.saved_s)} ms</b></span>` +
      `<span class="pill">matched <b>${d.matched_slices}</b> ` +
      `critical slice(s)</span></div>` +
      `<table><thead><tr><th class="num">#</th>` +
      `<th>counterfactual ranking</th><th class="num">CMetric (ms)</th>` +
      `<th class="num">move</th></tr></thead>` +
      `<tbody>${rows.join("")}</tbody></table>`;
  } catch (e) {
    out.innerHTML = `<span class="pill bad">what-if failed: ${esc(e)}</span>`;
  }
}
document.getElementById("wiform").addEventListener("submit", whatif);
poll();
</script>
</body>
</html>
"""
