"""Prometheus text exposition (format 0.0.4) without a client library.

The profiler's self-telemetry already lives in plain stats dicts
(``session.stats()``, ``IngestServer.stats()``, ``RemoteSink.stats()``,
``ProfilerService.stats()``); :func:`flatten_stats` turns any of them
into metric samples and :func:`render_metrics` prints the exposition.
Every sample is exported as a gauge: most of the underlying values are
monotonic counters, but the stats dicts are snapshots with no reset
protocol, and gauges keep ``rate()``-style queries working without
lying about counter semantics.

Metric names are ``<prefix>_<key>`` with nested dicts joined by ``_``;
the key set is pinned by ``tests/test_stats_schema.py``, so a renamed
counter fails CI before it silently breaks someone's dashboards.
"""
from __future__ import annotations

import re
from typing import Iterable, Iterator

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_]")

#: One exported sample: (metric_name, labels-or-None, float value).
Sample = tuple  # (str, dict | None, float)


def sanitize_name(name: str) -> str:
    """Coerce a stats key into a legal metric-name component."""
    out = _NAME_BAD.sub("_", str(name))
    if not out or out[0].isdigit():
        out = "_" + out
    return out


def escape_label(value) -> str:
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(value: float) -> str:
    f = float(value)
    if f.is_integer() and abs(f) < 2 ** 53:
        return str(int(f))
    return repr(f)


def flatten_stats(prefix: str, stats: dict,
                  labels: dict | None = None) -> Iterator[Sample]:
    """Yield one gauge sample per numeric/bool leaf of ``stats``.

    Nested dicts extend the metric name (``a: {b: 1}`` ->
    ``<prefix>_a_b``); strings, lists and ``None`` leaves are skipped —
    they are identity/config, not telemetry.  ``labels`` (e.g.
    ``{"host": hid}``) is attached to every yielded sample.
    """
    for key, value in stats.items():
        name = f"{prefix}_{sanitize_name(key)}"
        if isinstance(value, bool):
            yield (name, labels, 1.0 if value else 0.0)
        elif isinstance(value, (int, float)):
            yield (name, labels, float(value))
        elif isinstance(value, dict):
            yield from flatten_stats(name, value, labels)


def render_metrics(samples: Iterable[Sample],
                   help_text: dict[str, str] | None = None) -> str:
    """Render samples as the Prometheus text format, grouped and sorted
    by metric name (a stable exposition diffs cleanly in tests)."""
    by_name: dict[str, list] = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    lines: list[str] = []
    for name in sorted(by_name):
        if help_text and name in help_text:
            lines.append(f"# HELP {name} {help_text[name]}")
        lines.append(f"# TYPE {name} gauge")
        for labels, value in by_name[name]:
            if labels:
                lab = ",".join(f'{sanitize_name(k)}="{escape_label(v)}"'
                               for k, v in sorted(labels.items()))
                lines.append(f"{name}{{{lab}}} {_fmt(value)}")
            else:
                lines.append(f"{name} {_fmt(value)}")
    return "\n".join(lines) + "\n"
