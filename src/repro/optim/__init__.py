"""Optimizers: AdamW + gradient compression (error feedback)."""
from repro.optim import adamw, compression  # noqa: F401
