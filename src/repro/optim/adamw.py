"""AdamW with global-norm clipping and schedules (pure pytree functions).

Optimizer state mirrors param sharding; ``zero1=True`` additionally shards
the moments over the data-parallel axes (ZeRO-1) via sharding constraints —
at 512 chips the moment memory per chip drops by the DP degree.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup, cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                   p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm > 0 else 1.0
    lr = schedule(cfg, step)
    c1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v,
                                                 flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, metrics
