"""Gradient compression for the DP all-reduce, with error feedback.

At multi-pod scale the data-parallel gradient all-reduce crosses the
inter-pod links (the slowest hop).  Two standard compressors:

* ``int8``  — per-tensor symmetric quantisation: 4× fewer bytes on the wire;
  the quantisation residual is carried in an error-feedback buffer so the
  scheme stays unbiased over time (Seide et al. / EF-SGD).
* ``topk``  — keep the largest-|g| fraction per tensor (sparsification),
  remainder into the error buffer.

``wrap_grad_fn`` composes either around any grad function with error
feedback.  Honesty note on the SPMD path: under ``jax.jit`` the partitioner
places the DP gradient reduction inside the backward pass, *before* the
wrapper runs — so in the pjit train step the compressor preserves the
algorithmic semantics (quantised gradients + error feedback, convergence
verified in tests) but does not shrink the wire bytes.  Realising the wire
saving needs the reduction under explicit control (shard_map the grad
aggregation, quantise per shard, psum the int8/scale pairs) — the
``topk``/int8 kernels here are reduction-placement agnostic and reusable
for that; tracked as future work in DESIGN.md.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def _quant_int8(x):
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_mask(g, frac: float):
    k = max(1, int(g.size * frac))
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_topk(grads, err, frac: float = 0.05):
    def one(g, e):
        g = g.astype(jnp.float32) + e
        m = topk_mask(g, frac)
        return g * m, g * (1 - m)
    pairs = [(one(g, e)) for g, e in zip(jax.tree.leaves(grads),
                                         jax.tree.leaves(err))]
    treedef = jax.tree.structure(grads)
    sel = lambda i: jax.tree.unflatten(treedef, [p[i] for p in pairs])
    return sel(0), sel(1)


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wrap_grad_fn(grad_fn: Callable, mode: str = "none",
                 topk_frac: float = 0.05) -> Callable:
    """grad_fn(params, batch) -> (grads, aux).  Returns a function
    f(params, batch, err) -> (grads, aux, new_err) applying compression +
    error feedback around the gradient computation."""
    if mode == "none":
        def f_none(params, batch, err):
            g, aux = grad_fn(params, batch)
            return g, aux, err
        return f_none
    if mode == "int8":
        def f_int8(params, batch, err):
            g, aux = grad_fn(params, batch)
            flat_g, treedef = jax.tree.flatten(g)
            flat_e = treedef.flatten_up_to(err)
            outs = []
            for gi, ei in zip(flat_g, flat_e):
                gi = gi.astype(jnp.float32) + ei
                q, s = _quant_int8(gi)
                outs.append((_dequant_int8(q, s), gi - _dequant_int8(q, s)))
            g2 = treedef.unflatten([o[0] for o in outs])
            e2 = treedef.unflatten([o[1] for o in outs])
            return g2, aux, e2
        return f_int8
    if mode == "topk":
        def f_topk(params, batch, err):
            g, aux = grad_fn(params, batch)
            g2, e2 = compress_topk(g, err, topk_frac)
            return g2, aux, e2
        return f_topk
    raise ValueError(mode)
