"""deepseek-7b: dense llama-arch decoder [arXiv:2401.02954]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", family="dense",
        num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=11008, vocab_size=102400, block_pattern=("dense",),
        rope_theta=10_000.0,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-tiny", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=256, block_pattern=("dense",),
    )
