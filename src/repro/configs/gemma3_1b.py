"""gemma3-1b: 5:1 local:global attention, 262k vocab [hf:google/gemma-3]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
        head_dim=256, d_ff=6912, vocab_size=262144,
        block_pattern=("local",) * 5 + ("dense",), window=512,
        tie_embeddings=True, rope_theta=1_000_000.0,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-tiny", family="dense",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=160, vocab_size=256,
        block_pattern=("local",) * 5 + ("dense",), window=8,
        tie_embeddings=True,
    )
