"""arctic-480b: 128-expert top-2 MoE with a parallel dense residual MLP
[hf:Snowflake/snowflake-arctic-base]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=4864, vocab_size=32000,
        block_pattern=("moe",), num_experts=128, top_k=2,
        dense_residual=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="arctic-tiny", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, block_pattern=("moe",),
        num_experts=8, top_k=2, dense_residual=True,
    )
