"""Assigned architecture configs and the input-shape grid.

Each module defines ``config()`` (the exact published configuration) and
``tiny()`` (a reduced same-family config for CPU smoke tests).  The dry-run
grid is ``ARCHS`` × each arch's applicable ``SHAPES`` cells.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ModelConfig

ARCHS = [
    "deepseek-7b",
    "qwen1.5-4b",
    "qwen3-32b",
    "gemma3-1b",
    "recurrentgemma-2b",
    "seamless-m4t-large-v2",
    "internvl2-2b",
    "grok-1-314b",
    "arctic-480b",
    "rwkv6-1.6b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k requires sub-quadratic sequence handling: run for SSM / hybrid /
# mostly-local archs, skip for pure full-attention archs (see DESIGN.md §4).
SUBQUADRATIC = {"recurrentgemma-2b", "rwkv6-1.6b", "gemma3-1b"}


def _module(name: str):
    return importlib.import_module("repro.configs." + name.replace("-", "_")
                                   .replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_tiny(name: str) -> ModelConfig:
    return _module(name).tiny()


def applicable_shapes(arch: str) -> list[str]:
    out = []
    for s in SHAPES:
        if s == "long_500k" and arch not in SUBQUADRATIC:
            continue
        out.append(s)
    return out


def grid() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, including the documented skips as
    absent rows (see EXPERIMENTS.md for the skip table)."""
    return [(a, s) for a in ARCHS for s in applicable_shapes(a)]
