"""grok-1-314b: 8-expert top-2 MoE decoder [hf:xai-org/grok-1]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=32768, vocab_size=131072,
        block_pattern=("moe",), num_experts=8, top_k=2,
        logits_softcap=30.0,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="grok-tiny", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, block_pattern=("moe",),
        num_experts=4, top_k=2, logits_softcap=30.0,
    )
