"""qwen3-32b: dense decoder with qk_norm and GQA kv=8 [hf:Qwen/Qwen3]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=25600, vocab_size=151936,
        block_pattern=("dense",), qk_norm=True, rope_theta=1_000_000.0,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-tiny", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=256, block_pattern=("dense",), qk_norm=True,
    )
