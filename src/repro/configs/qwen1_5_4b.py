"""qwen1.5-4b: dense decoder with QKV bias [hf:Qwen/Qwen1.5 family]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
        d_ff=6912, vocab_size=151936, block_pattern=("dense",),
        qkv_bias=True, rope_theta=1_000_000.0,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-tiny", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=256, block_pattern=("dense",), qkv_bias=True,
    )
