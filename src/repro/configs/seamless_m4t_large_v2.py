"""seamless-m4t-large-v2 backbone: enc-dec transformer; the audio frontend
is a stub per the assignment (input_specs provides precomputed 80-d fbank
frame embeddings) [arXiv:2308.11596]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=256206, block_pattern=("cross",),
        enc_layers=24, frontend_dim=80,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="seamless-tiny", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=256, block_pattern=("cross",),
        enc_layers=2, frontend_dim=16,
    )
