"""recurrentgemma-2b: RG-LRU + local attention hybrid [arXiv:2402.19427]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        block_pattern=("rglru", "rglru", "local"), window=2048,
        lru_width=2560, conv_width=4, tie_embeddings=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-tiny", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=160, vocab_size=256,
        block_pattern=("rglru", "rglru", "local"), window=8,
        lru_width=64, tie_embeddings=True,
    )
