"""internvl2-2b backbone: InternLM2-1.8B decoder; InternViT frontend is a
stub (precomputed 1024-d patch embeddings, 256-token prefix)
[arXiv:2404.16821]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        d_ff=8192, vocab_size=92553, block_pattern=("dense",),
        frontend_dim=1024, num_prefix=256, rope_theta=1_000_000.0,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="internvl2-tiny", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=256, block_pattern=("dense",),
        frontend_dim=32, num_prefix=8,
    )
