"""rwkv6-1.6b "Finch": attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=7168, vocab_size=65536, block_pattern=("rwkv",),
        rwkv_head_dim=64, chunk_size=128,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-tiny", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=256, block_pattern=("rwkv",),
        rwkv_head_dim=16, chunk_size=8,
    )
