"""Fault tolerance: straggler detection + checkpoint/restart driver.

The straggler monitor closes the loop between the paper's profiler and the
fleet: per-host step heartbeats are ingested as worker spans, per-host
CMetric is maintained online, and a host whose criticality share exceeds
``zmax`` standard deviations is flagged (the DP all-reduce makes every other
host wait for it, which is precisely the low-parallelism signature CMetric
amplifies).  ``run_with_restarts`` provides crash-looping around the train
loop with restore-from-latest-checkpoint — node failures at scale become a
resume, not a lost run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.report import imbalance_stats
from repro.core.session import ProfileSession


@dataclasses.dataclass
class StragglerVerdict:
    host: int
    cv: float
    max_over_mean: float
    is_straggler: bool


class StragglerMonitor:
    """Consumes per-host step busy intervals; flags criticality outliers."""

    def __init__(self, num_hosts: int, zmax: float = 3.0,
                 n_min: float | None = None):
        self.num_hosts = num_hosts
        self.zmax = zmax
        self.session = ProfileSession(
            n_min=n_min if n_min is not None else num_hosts / 2)
        # Back-compat alias: pre-session call sites read ``monitor.gapp``.
        self.gapp = self.session
        self.wids = [self.session.register_worker(f"host{i}", "host")
                     for i in range(num_hosts)]

    def record_step(self, host: int, t_start_ns: int, t_end_ns: int,
                    tag: str = "train_step") -> None:
        self.session.ingest(t_start_ns, self.wids[host], +1, tag)
        self.session.ingest(t_end_ns, self.wids[host], -1, tag)

    def verdict(self) -> StragglerVerdict:
        pw = self.session.tracer.per_worker_cm()
        stats = imbalance_stats(pw)
        mean, std = stats["mean"], stats["std"]
        worst = int(np.argmax(pw))
        z = (pw[worst] - mean) / std if std > 0 else 0.0
        return StragglerVerdict(
            host=worst, cv=stats["cv"],
            max_over_mean=stats["max_over_mean"],
            is_straggler=bool(z > self.zmax and stats["max_over_mean"] > 1.2))


def run_with_restarts(train_fn: Callable[[int], int], max_restarts: int = 3,
                      on_restart: Callable[[int, BaseException], None]
                      | None = None) -> int:
    """``train_fn(start_step) -> final_step`` with crash-restart semantics.

    ``train_fn`` is responsible for restoring from the latest checkpoint
    when ``start_step`` > 0 (see launch/train.py).  Returns the final step.
    """
    attempt = 0
    step = 0
    while True:
        try:
            return train_fn(step)
        except KeyboardInterrupt:
            raise
        except Exception as e:          # noqa: BLE001 — restart scope
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
            time.sleep(0.01)
            # next attempt resumes from whatever checkpoint exists
            step = -1                    # sentinel: restore latest
