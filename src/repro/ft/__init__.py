"""Fault tolerance: straggler monitor + crash-restart driver."""
from repro.ft.monitor import StragglerMonitor, run_with_restarts  # noqa: F401
