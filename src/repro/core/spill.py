"""Disk-spill event store — bounded resident memory for unbounded captures.

The live tracer accumulates every drained+folded chunk into its store so
``freeze()`` can hand the whole run to the offline pipeline.  For long
captures that store is the one unbounded allocation left in the profiler
(ROADMAP: "spill the accumulated EventStore to disk so freeze() is also
bounded").  :class:`SpillStore` is a drop-in replacement for
:class:`~repro.core.events.EventStore` that pages full blocks of
``chunk_events`` rows to an append-only file: the resident buffer never
holds more than one block, so profiler-side event memory is O(chunk_events)
no matter how many events stream through.

File format (append-only, block-framed)::

    [u64 nrows][times i64*n][workers i32*n][deltas i8*n][tags i32*n]
    [stacks i32*n]  ...repeated per block...

Blocks are written in drain order, which is time order (the tracer's flush
clamps cross-chunk monotonicity), so reading the blocks back in sequence
yields a time-sorted stream with no re-sort:

* :meth:`iter_chunks` streams the file back one :class:`EventLog` block at
  a time — what :class:`~repro.core.session.SpillSource` replays through a
  new session in bounded memory;
* :meth:`freeze` materialises the whole stream as one log (the legacy
  whole-log path; unbounded by definition — prefer the streaming reader).

Single-consumer like the stores it replaces: appends come from the
tracer's flush (under its fold lock) or the offline session's fold loop.
Readers never observe a torn block: blocks are append-only and flushed
whole, and every read bounds itself to the flushed-byte watermark taken
under the store lock.  A writer store *owns* its file for one capture
(an existing file at the path is truncated at construction); use
:meth:`SpillStore.open_readonly` to replay a finished capture.

The same block framing doubles as the fleet **journal** format
(:mod:`repro.fleet.transport`): :meth:`SpillStore.open_append` re-opens
an existing file *without* truncating history (a torn tail block — a
crash mid-append — is cut back to the last complete block, so the resume
floor is exact), and :meth:`append_block` writes one caller-framed block
per call with no re-blocking, which pins the invariant journals rely on:
**block index == append order == chunk seq**.
"""
from __future__ import annotations

import os
import struct
import threading
from typing import Iterator

import numpy as np

from repro.core.events import EventLog

# Column order and dtypes of one spilled block (matches EventStore/EventLog).
_COL_DTYPES = (np.int64, np.int32, np.int8, np.int32, np.int32)
_HEADER = struct.Struct("<Q")
_ROW_BYTES = sum(np.dtype(dt).itemsize for dt in _COL_DTYPES)


class SpillStore:
    """Append-only on-disk event store with an O(chunk_events) resident buffer.

    Duck-compatible with :class:`~repro.core.events.EventStore`
    (``append_columns`` / ``__len__`` / ``freeze`` / ``nbytes``), so it plugs
    straight into ``Tracer(store=...)`` / ``ProfileSession(spill_path=...)``.
    """

    def __init__(self, path: str, chunk_events: int = 1 << 16, *,
                 _readonly: bool = False, _append: bool = False):
        self.path = str(path)
        self.chunk_events = max(int(chunk_events), 1)
        self._buf = [np.zeros(self.chunk_events, dt) for dt in _COL_DTYPES]
        self._buf_len = 0
        self._rows_on_disk = 0
        self._blocks = 0
        self._bytes_written = 0
        self._file = None           # lazily opened write handle
        self._closed = _readonly
        self.max_resident_rows = 0  # high-water mark of the RAM buffer
        self._lock = threading.Lock()
        if _readonly:
            self._scan_existing()
        elif _append:
            # journal mode: keep existing complete blocks, cut a torn tail
            # back to the last block boundary so the next append starts at
            # a clean frame (and the block count is an exact resume floor)
            self._scan_existing()
            if os.path.exists(self.path) \
                    and os.path.getsize(self.path) > self._bytes_written:
                with open(self.path, "r+b") as f:
                    f.truncate(self._bytes_written)
        elif os.path.exists(self.path):
            # a writer store owns its file for exactly one capture: a stale
            # file from a previous run at the same path must not leak into
            # this run's freeze()/iter_chunks()
            os.remove(self.path)

    @classmethod
    def open_readonly(cls, path: str,
                      chunk_events: int = 1 << 16) -> "SpillStore":
        """Open an existing spill file for replay (appends disabled; the
        file is NOT truncated — the writer-mode constructor is)."""
        return cls(path, chunk_events, _readonly=True)

    @classmethod
    def open_append(cls, path: str,
                    chunk_events: int = 1 << 16) -> "SpillStore":
        """Open a journal: existing complete blocks are kept (a torn tail
        from a crash mid-append is truncated away), and new
        :meth:`append_block` calls extend the file — resuming the
        block-index sequence exactly where the complete history ends."""
        return cls(path, chunk_events, _append=True)

    def _scan_existing(self) -> None:
        """Index an existing file (read-only open): block/row counts come
        from walking the headers, without reading column payloads.

        A truncated tail — a capture cut mid-write (partial header or a
        header whose payload runs past EOF) — is ignored: the watermark
        stops at the last *complete* block, so readers never decode a torn
        payload."""
        if not os.path.exists(self.path):
            return
        size = os.path.getsize(self.path)
        with open(self.path, "rb") as f:
            while True:
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    break
                (n,) = _HEADER.unpack(hdr)
                end = f.tell() + n * _ROW_BYTES
                if end > size:
                    break           # torn tail block: exclude from watermark
                f.seek(end)
                self._rows_on_disk += n
                self._blocks += 1
                self._bytes_written += _HEADER.size + n * _ROW_BYTES

    # -- write side ----------------------------------------------------------
    def _write_cols(self, cols, n: int) -> None:
        """Frame ``n`` rows of ``cols`` as one block (caller holds the
        lock)."""
        if self._file is None:
            self._file = open(self.path, "ab")
        self._file.write(_HEADER.pack(n))
        for col in cols:
            self._file.write(col[:n].tobytes())
        self._file.flush()          # readers bound themselves to flushed bytes
        self._rows_on_disk += n
        self._blocks += 1
        self._bytes_written += _HEADER.size + n * _ROW_BYTES

    def _write_block(self, n: int) -> None:
        """Flush the first ``n`` buffered rows as one framed block."""
        if n == 0:
            return
        self._write_cols(self._buf, n)
        self._buf_len = 0

    def append_block(self, times, workers, deltas, tags, stacks,
                     sync: bool = False) -> int:
        """Journal append: write the given rows as exactly ONE block (no
        re-blocking through the resident buffer), flushed before return so
        the block survives a PROCESS crash when the caller hands the chunk
        onward.  ``sync=True`` additionally fsyncs, extending the guarantee
        to power loss — at a per-block fsync cost the hot ingest path
        usually cannot afford (the fleet transports expose this as an
        opt-in).  Returns the block index — with every append routed
        through here, block index == append order, which the fleet
        journals equate with the chunk ``seq``."""
        if self._closed:
            raise ValueError(f"SpillStore({self.path}) is closed")
        cols = tuple(np.ascontiguousarray(c, dt) for c, dt in
                     zip((times, workers, deltas, tags, stacks),
                         _COL_DTYPES))
        n = len(cols[0])
        with self._lock:
            # keep disk order == append order if buffered rows exist (a
            # pure journal never mixes the two paths)
            self._write_block(self._buf_len)
            self._write_cols(cols, n)
            if sync:
                os.fsync(self._file.fileno())
            return self._blocks - 1

    def append_columns(self, times, workers, deltas, tags, stacks) -> None:
        e = len(times)
        if e == 0:
            return
        if self._closed:
            raise ValueError(f"SpillStore({self.path}) is closed")
        cols = (times, workers, deltas, tags, stacks)
        with self._lock:
            lo = 0
            while lo < e:
                take = min(self.chunk_events - self._buf_len, e - lo)
                for buf, arr in zip(self._buf, cols):
                    buf[self._buf_len:self._buf_len + take] = arr[lo:lo + take]
                self._buf_len += take
                lo += take
                self.max_resident_rows = max(self.max_resident_rows,
                                             self._buf_len)
                if self._buf_len == self.chunk_events:
                    self._write_block(self._buf_len)

    def spill(self) -> None:
        """Force the resident buffer to disk (a partial block is fine)."""
        with self._lock:
            self._write_block(self._buf_len)
            if self._file is not None:
                self._file.flush()

    def close(self) -> None:
        """Flush and close the write handle; reads remain available.  A
        closed file is fsynced once, so a cleanly sealed capture/journal
        survives power loss even without per-block ``sync``."""
        self.spill()
        with self._lock:
            if self._file is not None:
                os.fsync(self._file.fileno())
                self._file.close()
                self._file = None
            self._closed = True

    # -- stats ---------------------------------------------------------------
    def __len__(self) -> int:
        return self._rows_on_disk + self._buf_len

    @property
    def rows_on_disk(self) -> int:
        return self._rows_on_disk

    @property
    def blocks(self) -> int:
        """Complete blocks on disk (== the next append_block index)."""
        return self._blocks

    @property
    def resident_rows(self) -> int:
        return self._buf_len

    @property
    def resident_nbytes(self) -> int:
        """RAM held by the store — the fixed one-block buffer."""
        return sum(c.nbytes for c in self._buf)

    # EventStore compat: ``nbytes`` feeds Tracer.memory_bytes, which reports
    # *profiler-side* memory — for a spill store that is the resident buffer,
    # not the file.
    @property
    def nbytes(self) -> int:
        return self.resident_nbytes

    @property
    def spilled_nbytes(self) -> int:
        return self._rows_on_disk * _ROW_BYTES + self._blocks * _HEADER.size

    # -- read side -----------------------------------------------------------
    def _read_limit(self) -> int:
        """Flush the buffer and snapshot the complete-byte boundary: blocks
        are append-only, so reading ``[0, limit)`` is safe against a
        concurrent writer without holding the lock through the read."""
        self.spill()
        with self._lock:
            return self._bytes_written

    def _read_blocks(self, limit: int,
                     skip: int = 0) -> Iterator[tuple[np.ndarray, ...]]:
        if limit <= 0 or not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            while skip > 0 and f.tell() < limit:
                # skipped blocks are seeked over, not decoded: a journal
                # replay of a long capture's tail must not re-read (and
                # re-allocate) gigabytes of acked prefix on every reconnect
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    return
                (n,) = _HEADER.unpack(hdr)
                f.seek(n * _ROW_BYTES, os.SEEK_CUR)
                skip -= 1
            while f.tell() < limit:
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    return
                (n,) = _HEADER.unpack(hdr)
                cols = []
                for dt in _COL_DTYPES:
                    raw = f.read(n * np.dtype(dt).itemsize)
                    if len(raw) < n * np.dtype(dt).itemsize:
                        return      # torn tail beyond the watermark: stop
                    cols.append(np.frombuffer(raw, dt).copy())
                yield tuple(cols)

    def iter_block_columns(self, skip: int = 0) \
            -> Iterator[tuple[np.ndarray, ...]]:
        """Raw column tuples, one per complete block, skipping the first
        ``skip`` blocks — the journal replay reader (block index == chunk
        seq, so ``skip=ack_seq`` yields exactly the unacked tail; the
        acked prefix is seeked over, not decoded).  Safe against a
        concurrent :meth:`append_block` writer: bounded to the
        flushed-byte watermark at call time."""
        yield from self._read_blocks(self._read_limit(), skip)

    def iter_chunks(self, num_workers: int) -> Iterator[EventLog]:
        """Stream the store back as :class:`EventLog` blocks, oldest first.

        Flushes the resident buffer first so the on-disk stream is complete;
        memory per step is one block.  Safe against a concurrent writer:
        only blocks fully written at call time are yielded.
        """
        for cols in self._read_blocks(self._read_limit()):
            yield EventLog(*cols, num_workers=num_workers)

    def freeze(self, num_workers: int) -> EventLog:
        """Materialise the whole spilled stream as one log (legacy path;
        resident memory is O(total events) here by definition)."""
        parts = list(self._read_blocks(self._read_limit()))
        if not parts:
            return EventLog(*[np.zeros(0, dt) for dt in _COL_DTYPES],
                            num_workers=num_workers)
        return EventLog(*[np.concatenate(c) for c in zip(*parts)],
                        num_workers=num_workers)

    def __del__(self):  # pragma: no cover - interpreter-shutdown best effort
        try:
            if self._file is not None:
                self._file.close()
        except Exception:
            pass
