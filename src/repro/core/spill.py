"""Disk-spill event store — bounded resident memory for unbounded captures.

The live tracer accumulates every drained+folded chunk into its store so
``freeze()`` can hand the whole run to the offline pipeline.  For long
captures that store is the one unbounded allocation left in the profiler
(ROADMAP: "spill the accumulated EventStore to disk so freeze() is also
bounded").  :class:`SpillStore` is a drop-in replacement for
:class:`~repro.core.events.EventStore` that pages full blocks of
``chunk_events`` rows to an append-only file: the resident buffer never
holds more than one block, so profiler-side event memory is O(chunk_events)
no matter how many events stream through.

File format (append-only, block-framed)::

    [u64 nrows][times i64*n][workers i32*n][deltas i8*n][tags i32*n]
    [stacks i32*n]  ...repeated per block...

Blocks are written in drain order, which is time order (the tracer's flush
clamps cross-chunk monotonicity), so reading the blocks back in sequence
yields a time-sorted stream with no re-sort:

* :meth:`iter_chunks` streams the file back one :class:`EventLog` block at
  a time — what :class:`~repro.core.session.SpillSource` replays through a
  new session in bounded memory;
* :meth:`freeze` materialises the whole stream as one log (the legacy
  whole-log path; unbounded by definition — prefer the streaming reader).

Single-consumer like the stores it replaces: appends come from the
tracer's flush (under its fold lock) or the offline session's fold loop.
Readers never observe a torn block: blocks are append-only and flushed
whole, and every read bounds itself to the flushed-byte watermark taken
under the store lock.  A writer store *owns* its file for one capture
(an existing file at the path is truncated at construction); use
:meth:`SpillStore.open_readonly` to replay a finished capture.

The same block framing doubles as the fleet **journal** format
(:mod:`repro.fleet.transport`): :meth:`SpillStore.open_append` re-opens
an existing file *without* truncating history (a torn tail block — a
crash mid-append — is cut back to the last complete block, so the resume
floor is exact), and :meth:`append_block` writes one caller-framed block
per call with no re-blocking, which pins the invariant journals rely on:
**block index == append order == chunk seq**.

**Rotation + retention** (week-long captures must not grow one unbounded
file): with ``rotate_bytes=``/``rotate_age_s=`` the active file rolls
over once it exceeds the size/age threshold — it is sealed (fsync) and
renamed to ``<path>.g<first_block>.seg``, and appends continue in a fresh
``<path>``.  Block indices are GLOBAL across segments (the filename
records each segment's first block), so *seq == block index* survives any
number of rollovers, and every reader (:meth:`iter_block_columns`,
:meth:`iter_chunks`, :meth:`freeze`) spans the whole segment chain
transparently — including :meth:`open_readonly`/:meth:`open_append` on a
rotated journal.  ``retain_blocks=`` enables pruning: whole segments are
deleted once they fall entirely below BOTH the retention horizon
(``blocks - retain_blocks``) and the **ack floor**
(:meth:`set_ack_floor` — the consumer's durable receive watermark), so
retention can never drop a block a replay might still need.  The default
(``retain_blocks=None``) keeps everything.

**Capture-time block index** (time-windowed queries must not re-read a
week of history): every complete block's first/last event timestamp is
indexed in memory — recovered on open by reading exactly two i64s per
block (the payload's first and last ``times`` entry; payload bodies are
still seeked over, not decoded) and maintained on every append.  Blocks
are written in time order, so a window ``[t_lo, t_hi]`` maps to one
contiguous global block range: :meth:`iter_block_columns_window` seeks
straight to it and decodes only intersecting blocks, and
:meth:`prune_before_time` turns a wall-clock age budget into the same
whole-segment pruning as ``retain_blocks`` (still honouring the ack
floor unless explicitly told the journal has no acking consumer).
"""
from __future__ import annotations

import os
import re
import struct
import threading
import time
from typing import Iterator

import numpy as np

from repro.core.events import EventLog

# Column order and dtypes of one spilled block (matches EventStore/EventLog).
_COL_DTYPES = (np.int64, np.int32, np.int8, np.int32, np.int32)
_HEADER = struct.Struct("<Q")
_ROW_BYTES = sum(np.dtype(dt).itemsize for dt in _COL_DTYPES)

# Sealed rotation segments live next to the active file as
# ``<path>.g<first_block>.seg`` — the name IS the index metadata.
_SEG_RE = re.compile(r"\.g(\d+)\.seg$")


class SpillStore:
    """Append-only on-disk event store with an O(chunk_events) resident buffer.

    Duck-compatible with :class:`~repro.core.events.EventStore`
    (``append_columns`` / ``__len__`` / ``freeze`` / ``nbytes``), so it plugs
    straight into ``Tracer(store=...)`` / ``ProfileSession(spill_path=...)``.
    """

    def __init__(self, path: str, chunk_events: int = 1 << 16, *,
                 rotate_bytes: int | None = None,
                 rotate_age_s: float | None = None,
                 retain_blocks: int | None = None,
                 _readonly: bool = False, _append: bool = False):
        self.path = str(path)
        self.chunk_events = max(int(chunk_events), 1)
        self.rotate_bytes = rotate_bytes
        self.rotate_age_s = rotate_age_s
        self.retain_blocks = retain_blocks
        self._buf = [np.zeros(self.chunk_events, dt) for dt in _COL_DTYPES]
        self._buf_len = 0           # guarded-by: self._lock
        self._rows_on_disk = 0      # guarded-by: self._lock
        # sealed segments, oldest first: [path, first_block, nblocks, nrows]
        self._segments: list[list] = []     # guarded-by: self._lock
        self._active_first = 0      # guarded-by: self._lock -- global index of the active file's block 0
        self._active_rows = 0       # guarded-by: self._lock
        self._active_opened = time.monotonic()  # guarded-by: self._lock
        self._ack_floor = 0         # guarded-by: self._lock
        # capture-time bounds per complete on-disk block, oldest first:
        # (t_first, t_last) or None for an empty (gap-filler) block.  Entry
        # i covers global block ``_index_first + i``.
        self._time_index: list[tuple[int, int] | None] = []  # guarded-by: self._lock
        self._index_first = 0       # guarded-by: self._lock -- global index of _time_index[0]
        self.pruned_blocks = 0      # guarded-by: self._lock -- blocks dropped by retention (exact)
        self._blocks = 0            # guarded-by: self._lock -- complete blocks in the ACTIVE file
        self._bytes_written = 0     # guarded-by: self._lock -- complete bytes in the ACTIVE file
        self._file = None           # guarded-by: self._lock -- lazily opened write handle
        self._closed = _readonly    # guarded-by: self._lock
        self.max_resident_rows = 0  # guarded-by: self._lock -- high-water mark of the RAM buffer
        self._lock = threading.Lock()
        if _readonly:
            self._scan_existing()
        elif _append:
            # journal mode: keep existing complete blocks, cut a torn tail
            # back to the last block boundary so the next append starts at
            # a clean frame (and the block count is an exact resume floor)
            self._scan_existing()
            if os.path.exists(self.path) \
                    and os.path.getsize(self.path) > self._bytes_written:
                with open(self.path, "r+b") as f:
                    f.truncate(self._bytes_written)
        else:
            # a writer store owns its file for exactly one capture: a stale
            # file (or rotated segments) from a previous run at the same
            # path must not leak into this run's freeze()/iter_chunks()
            if os.path.exists(self.path):
                os.remove(self.path)
            for _first, seg_path in self._segment_paths():
                try:
                    os.remove(seg_path)
                except OSError:
                    pass

    @classmethod
    def open_readonly(cls, path: str,
                      chunk_events: int = 1 << 16) -> "SpillStore":
        """Open an existing spill file for replay (appends disabled; the
        file is NOT truncated — the writer-mode constructor is)."""
        return cls(path, chunk_events, _readonly=True)

    @classmethod
    def open_append(cls, path: str, chunk_events: int = 1 << 16, *,
                    rotate_bytes: int | None = None,
                    rotate_age_s: float | None = None,
                    retain_blocks: int | None = None) -> "SpillStore":
        """Open a journal: existing complete blocks are kept (a torn tail
        from a crash mid-append is truncated away), and new
        :meth:`append_block` calls extend the file — resuming the
        block-index sequence exactly where the complete history ends,
        across any sealed rotation segments."""
        return cls(path, chunk_events, _append=True,
                   rotate_bytes=rotate_bytes, rotate_age_s=rotate_age_s,
                   retain_blocks=retain_blocks)

    def _segment_paths(self) -> list[tuple[int, str]]:
        """Sealed segments on disk next to ``self.path``, oldest first, as
        ``(first_block, path)``.  listdir + exact-name match (not glob):
        capture paths may contain glob metacharacters."""
        d = os.path.dirname(self.path) or "."
        base = os.path.basename(self.path)
        out: list[tuple[int, str]] = []
        if not os.path.isdir(d):
            return out
        for name in os.listdir(d):
            m = _SEG_RE.search(name)
            if m and name == f"{base}.g{m.group(1)}.seg":
                out.append((int(m.group(1)), os.path.join(d, name)))
        out.sort()
        return out

    @staticmethod
    def _scan_file(path: str) -> tuple[int, int, int, list]:
        """Walk one file's block headers (payload bodies are seeked over,
        not read) -> ``(complete_blocks, rows, complete_bytes, bounds)``.
        ``bounds`` holds one ``(t_first, t_last)`` per complete block
        (``None`` for empty blocks), recovered by reading exactly two i64s
        from each ``times`` column — the capture-time index costs O(blocks)
        seeks, never a payload decode.  A truncated tail — a capture cut
        mid-write (partial header or a header whose payload runs past EOF)
        — is excluded, so readers never decode a torn payload."""
        if not os.path.exists(path):
            return 0, 0, 0, []
        size = os.path.getsize(path)
        blocks = rows = nbytes = 0
        bounds: list[tuple[int, int] | None] = []
        t_size = np.dtype(np.int64).itemsize
        with open(path, "rb") as f:
            while True:
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    break
                (n,) = _HEADER.unpack(hdr)
                start = f.tell()
                end = start + n * _ROW_BYTES
                if end > size:
                    break           # torn tail block: exclude from watermark
                if n:
                    t0 = int(np.frombuffer(f.read(t_size), np.int64)[0])
                    f.seek(start + (n - 1) * t_size)
                    t1 = int(np.frombuffer(f.read(t_size), np.int64)[0])
                    bounds.append((t0, t1))
                else:
                    bounds.append(None)
                f.seek(end)
                rows += n
                blocks += 1
                nbytes += _HEADER.size + n * _ROW_BYTES
        return blocks, rows, nbytes, bounds

    # lint: disable=guarded-by(construction-time: called from __init__ only, before the store is shared with any other thread)
    def _scan_existing(self) -> None:
        """Index an existing capture: sealed rotation segments first (their
        filenames carry the global first-block index), then the active
        file.  Block indices resume exactly where the history ends."""
        for first, seg_path in self._segment_paths():
            nblocks, nrows, _, bounds = self._scan_file(seg_path)
            if nblocks == 0:
                continue
            self._segments.append([seg_path, first, nblocks, nrows])
            self._time_index.extend(bounds)
            self._rows_on_disk += nrows
            self._active_first = first + nblocks
        nblocks, nrows, nbytes, bounds = self._scan_file(self.path)
        self._blocks = nblocks
        self._time_index.extend(bounds)
        self._rows_on_disk += nrows
        self._bytes_written = nbytes
        self._index_first = (self._segments[0][1] if self._segments
                             else self._active_first)

    # -- write side ----------------------------------------------------------
    def _write_cols(self, cols, n: int) -> None:  # guarded-by: self._lock
        """Frame ``n`` rows of ``cols`` as one block (caller holds the
        lock).  Failure-atomic: if the write raises mid-frame (disk full),
        the partial frame is truncated away so the file still ends on a
        block boundary — a failed append consumes no block index, which
        the fleet journals' seq == block-index invariant depends on."""
        if self._file is None:
            self._file = open(self.path, "ab")
            self._active_opened = time.monotonic()
        start = self._bytes_written
        try:
            self._file.write(_HEADER.pack(n))
            for col in cols:
                self._file.write(col[:n].tobytes())
            self._file.flush()      # readers bound themselves to flushed bytes
        except OSError:
            try:
                self._file.close()
            except OSError:
                pass
            self._file = None
            try:
                with open(self.path, "r+b") as f:
                    f.truncate(start)
            except OSError:         # pragma: no cover - fs fully wedged
                pass
            raise
        self._rows_on_disk += n
        self._active_rows += n
        self._blocks += 1
        self._bytes_written += _HEADER.size + n * _ROW_BYTES
        self._time_index.append((int(cols[0][0]), int(cols[0][n - 1]))
                                if n else None)

    def _write_block(self, n: int) -> None:  # guarded-by: self._lock
        """Flush the first ``n`` buffered rows as one framed block."""
        if n == 0:
            return
        self._write_cols(self._buf, n)
        self._buf_len = 0

    def append_block(self, times, workers, deltas, tags, stacks,
                     sync: bool = False) -> int:
        """Journal append: write the given rows as exactly ONE block (no
        re-blocking through the resident buffer), flushed before return so
        the block survives a PROCESS crash when the caller hands the chunk
        onward.  ``sync=True`` additionally fsyncs, extending the guarantee
        to power loss — at a per-block fsync cost the hot ingest path
        usually cannot afford (the fleet transports expose this as an
        opt-in).  Returns the block index — with every append routed
        through here, block index == append order, which the fleet
        journals equate with the chunk ``seq``.  Indices are global across
        rotated segments, and the rotation check runs after each append
        (the journal path is the only rotating writer)."""
        if self._closed:
            raise ValueError(f"SpillStore({self.path}) is closed")
        cols = tuple(np.ascontiguousarray(c, dt) for c, dt in
                     zip((times, workers, deltas, tags, stacks),
                         _COL_DTYPES))
        n = len(cols[0])
        with self._lock:
            # keep disk order == append order if buffered rows exist (a
            # pure journal never mixes the two paths)
            self._write_block(self._buf_len)
            self._write_cols(cols, n)
            if sync:
                os.fsync(self._file.fileno())
            idx = self._active_first + self._blocks - 1
            self._maybe_roll_locked()
            return idx

    def _maybe_roll_locked(self) -> None:  # guarded-by: self._lock
        """Seal the active file into a ``.g<first_block>.seg`` segment when
        it exceeds the size/age threshold (caller holds the lock).  The
        seal fsyncs before the rename, so a sealed segment is always a
        complete, power-loss-durable unit."""
        if self._blocks == 0:
            return
        due = (self.rotate_bytes is not None
               and self._bytes_written >= self.rotate_bytes) \
            or (self.rotate_age_s is not None
                and time.monotonic() - self._active_opened
                >= self.rotate_age_s)
        if not due:
            return
        if self._file is None:      # pragma: no cover - blocks>0 implies open
            self._file = open(self.path, "ab")
        self._file.flush()
        os.fsync(self._file.fileno())
        self._file.close()
        self._file = None
        seg = f"{self.path}.g{self._active_first:010d}.seg"
        os.replace(self.path, seg)
        self._segments.append([seg, self._active_first, self._blocks,
                               self._active_rows])
        self._active_first += self._blocks
        self._blocks = 0
        self._bytes_written = 0
        self._active_rows = 0
        self._active_opened = time.monotonic()
        self._prune_locked()

    def set_ack_floor(self, seq: int) -> None:
        """Raise the consumer-durability watermark: every block below
        ``seq`` is known journaled on the receiving side, so retention may
        prune it.  Monotonic; triggers a prune sweep."""
        with self._lock:
            if int(seq) > self._ack_floor:
                self._ack_floor = int(seq)
            self._prune_locked()

    def _prune_locked(self) -> None:  # guarded-by: self._lock
        """Apply the ``retain_blocks`` count policy: prune below BOTH the
        ack floor and the retention horizon (``blocks - retain_blocks``).
        With ``retain_blocks=None`` (the default) never deletes anything."""
        if self.retain_blocks is None:
            return
        total = self._active_first + self._blocks
        keep_from = min(self._ack_floor, total - int(self.retain_blocks))
        self._drop_segments_below(keep_from)

    def _drop_segments_below(self, keep_from: int) -> int:  # guarded-by: self._lock
        """Delete whole sealed segments whose every block index is below
        ``keep_from``; returns the number of blocks dropped.  Never touches
        the active file and never splits a segment — the shared pruning
        primitive beneath both the block-count policy (:meth:`set_ack_floor`
        / rotation) and the wall-clock age policy
        (:meth:`prune_before_time`)."""
        dropped = 0
        while self._segments:
            seg_path, first, nblocks, nrows = self._segments[0]
            if first + nblocks > keep_from:
                break
            self._segments.pop(0)
            self._rows_on_disk -= nrows
            self.pruned_blocks += nblocks
            dropped += nblocks
            cut = (first + nblocks) - self._index_first
            if cut > 0:
                del self._time_index[:cut]
                self._index_first = first + nblocks
            try:
                os.remove(seg_path)
            except OSError:         # pragma: no cover - best-effort unlink
                pass
        return dropped

    def prune_before_time(self, t_ns: int, *,
                          respect_ack: bool = True) -> int:
        """Age-based retention: drop whole sealed segments in which every
        block's events end before ``t_ns`` (capture-time ns).  Returns the
        number of blocks pruned.

        ``respect_ack=True`` (default) additionally holds the ack floor:
        a block the consumer has not durably acknowledged survives any age
        budget — the producer-journal contract.  Server-side ``fleet_dir``
        journals have no acking consumer (the server IS the consumer), so
        their retention driver passes ``respect_ack=False``.  Works with or
        without ``retain_blocks``; the active file is never touched, so
        pair an age budget with ``rotate_bytes``/``rotate_age_s`` to bound
        disk."""
        with self._lock:
            horizon = self._index_first
            for b in self._time_index:
                if b is not None and b[1] >= int(t_ns):
                    break
                horizon += 1
            keep_from = min(horizon, self._ack_floor) if respect_ack \
                else horizon
            return self._drop_segments_below(keep_from)

    def append_columns(self, times, workers, deltas, tags, stacks) -> None:
        e = len(times)
        if e == 0:
            return
        if self._closed:
            raise ValueError(f"SpillStore({self.path}) is closed")
        cols = (times, workers, deltas, tags, stacks)
        with self._lock:
            lo = 0
            while lo < e:
                take = min(self.chunk_events - self._buf_len, e - lo)
                for buf, arr in zip(self._buf, cols):
                    buf[self._buf_len:self._buf_len + take] = arr[lo:lo + take]
                self._buf_len += take
                lo += take
                self.max_resident_rows = max(self.max_resident_rows,
                                             self._buf_len)
                if self._buf_len == self.chunk_events:
                    self._write_block(self._buf_len)

    def spill(self) -> None:
        """Force the resident buffer to disk (a partial block is fine)."""
        with self._lock:
            self._write_block(self._buf_len)
            if self._file is not None:
                self._file.flush()

    def close(self) -> None:
        """Flush and close the write handle; reads remain available.  A
        closed file is fsynced once, so a cleanly sealed capture/journal
        survives power loss even without per-block ``sync``."""
        self.spill()
        with self._lock:
            if self._file is not None:
                os.fsync(self._file.fileno())
                self._file.close()
                self._file = None
            self._closed = True

    # -- stats ---------------------------------------------------------------
    def __len__(self) -> int:
        return self._rows_on_disk + self._buf_len

    @property
    def rows_on_disk(self) -> int:
        return self._rows_on_disk

    @property
    def blocks(self) -> int:
        """Complete blocks ever written (== the next append_block index).
        Global across rotated segments; pruning does NOT lower it — block
        indices are stable forever."""
        return self._active_first + self._blocks

    @property
    def first_block(self) -> int:
        """Global index of the oldest block still on disk (0 until
        retention pruning removes a segment)."""
        return self._segments[0][1] if self._segments else self._active_first

    @property
    def segments(self) -> int:
        """Sealed rotation segments currently on disk (excludes the active
        file)."""
        return len(self._segments)

    @property
    def resident_rows(self) -> int:
        return self._buf_len

    @property
    def resident_nbytes(self) -> int:
        """RAM held by the store — the fixed one-block buffer."""
        return sum(c.nbytes for c in self._buf)

    # EventStore compat: ``nbytes`` feeds Tracer.memory_bytes, which reports
    # *profiler-side* memory — for a spill store that is the resident buffer,
    # not the file.
    @property
    def nbytes(self) -> int:
        return self.resident_nbytes

    @property
    def spilled_nbytes(self) -> int:
        on_disk_blocks = (self._active_first + self._blocks
                          - self.first_block)
        return self._rows_on_disk * _ROW_BYTES + on_disk_blocks * _HEADER.size

    # -- read side -----------------------------------------------------------
    def _read_limit(self) -> int:
        """Flush the buffer and snapshot the complete-byte boundary: blocks
        are append-only, so reading ``[0, limit)`` is safe against a
        concurrent writer without holding the lock through the read."""
        self.spill()
        with self._lock:
            return self._bytes_written

    def _read_blocks(self, limit: int,
                     skip: int = 0) -> Iterator[tuple[np.ndarray, ...]]:
        """Stream complete blocks across the whole segment chain, then the
        active file (bounded to ``limit`` active-file bytes).  ``skip`` is
        a GLOBAL block index: blocks below it — and any prefix already
        removed by retention pruning — are seeked over, not decoded."""
        segments = list(self._segments)     # snapshot vs concurrent prune
        first_kept = segments[0][1] if segments else self._active_first
        skip = max(0, skip - first_kept)    # pruned prefix needs no seeking
        for seg_path, _first, nblocks, _nrows in segments:
            if skip >= nblocks:
                skip -= nblocks
                continue
            try:
                seg_limit = os.path.getsize(seg_path)
            except OSError:
                continue                    # pruned between snapshot and read
            yield from self._read_file(seg_path, seg_limit, skip)
            skip = 0
        yield from self._read_file(self.path, limit, skip)

    def _read_file(self, path: str, limit: int,
                   skip: int = 0) -> Iterator[tuple[np.ndarray, ...]]:
        if limit <= 0 or not os.path.exists(path):
            return
        with open(path, "rb") as f:
            while skip > 0 and f.tell() < limit:
                # skipped blocks are seeked over, not decoded: a journal
                # replay of a long capture's tail must not re-read (and
                # re-allocate) gigabytes of acked prefix on every reconnect
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    return
                (n,) = _HEADER.unpack(hdr)
                f.seek(n * _ROW_BYTES, os.SEEK_CUR)
                skip -= 1
            while f.tell() < limit:
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    return
                (n,) = _HEADER.unpack(hdr)
                cols = []
                for dt in _COL_DTYPES:
                    raw = f.read(n * np.dtype(dt).itemsize)
                    if len(raw) < n * np.dtype(dt).itemsize:
                        return      # torn tail beyond the watermark: stop
                    cols.append(np.frombuffer(raw, dt).copy())
                yield tuple(cols)

    def iter_block_columns(self, skip: int = 0) \
            -> Iterator[tuple[np.ndarray, ...]]:
        """Raw column tuples, one per complete block, skipping the first
        ``skip`` blocks — the journal replay reader (block index == chunk
        seq, so ``skip=ack_seq`` yields exactly the unacked tail; the
        acked prefix is seeked over, not decoded).  Safe against a
        concurrent :meth:`append_block` writer: bounded to the
        flushed-byte watermark at call time."""
        yield from self._read_blocks(self._read_limit(), skip)

    def time_bounds(self) -> tuple[int, int] | None:
        """Capture-time span ``(t_first, t_last)`` over all complete
        on-disk blocks (the resident buffer is flushed first), or ``None``
        if nothing non-empty is on disk.  O(1) off the in-memory index —
        no file I/O."""
        self.spill()
        with self._lock:
            lo = hi = None
            for b in self._time_index:
                if b is not None:
                    lo = b[0]
                    break
            for b in reversed(self._time_index):
                if b is not None:
                    hi = b[1]
                    break
            return None if lo is None else (lo, hi)

    def iter_block_columns_window(self, t_lo: int, t_hi: int) \
            -> Iterator[tuple[np.ndarray, ...]]:
        """Stream only the complete blocks whose capture-time bounds
        intersect ``[t_lo, t_hi]`` (inclusive, ns).  Blocks are written in
        time order, so the intersecting set is one contiguous global range:
        the in-memory index locates it and everything outside is seeked
        over, never decoded — a windowed query over a week-long journal
        reads only the window's blocks.  Boundary blocks may carry rows
        outside the window; callers trim rows (the fleet feed does)."""
        limit = self._read_limit()  # flushes the buffer -> index complete
        with self._lock:
            first = last = None
            idx = self._index_first
            for b in self._time_index:
                if b is not None and b[1] >= t_lo and b[0] <= t_hi:
                    if first is None:
                        first = idx
                    last = idx
                idx += 1
        if first is None:
            return
        remaining = last - first + 1
        for cols in self._read_blocks(limit, skip=first):
            if remaining <= 0:
                return
            remaining -= 1
            yield cols

    def iter_chunks(self, num_workers: int) -> Iterator[EventLog]:
        """Stream the store back as :class:`EventLog` blocks, oldest first.

        Flushes the resident buffer first so the on-disk stream is complete;
        memory per step is one block.  Safe against a concurrent writer:
        only blocks fully written at call time are yielded.
        """
        for cols in self._read_blocks(self._read_limit()):
            yield EventLog(*cols, num_workers=num_workers)

    def freeze(self, num_workers: int) -> EventLog:
        """Materialise the whole spilled stream as one log (legacy path;
        resident memory is O(total events) here by definition)."""
        parts = list(self._read_blocks(self._read_limit()))
        if not parts:
            return EventLog(*[np.zeros(0, dt) for dt in _COL_DTYPES],
                            num_workers=num_workers)
        return EventLog(*[np.concatenate(c) for c in zip(*parts)],
                        num_workers=num_workers)

    def __del__(self):  # pragma: no cover - interpreter-shutdown best effort
        try:
            if self._file is not None:
                self._file.close()
        except Exception:
            pass
