"""Chrome-trace export: drop the event log into chrome://tracing / Perfetto.

Each worker becomes a track; spans become complete ('X') events; critical
slices are emitted on a separate "critical" track with the CMetric attached
as an argument, so the eye goes straight to what the ranking found.

Registered as the ``"chrome"`` exporter in :mod:`repro.core.exporters` —
``session.export("chrome", path=...)`` is the session-first spelling.  The
trace is a pure function of the frozen log, so it is invariant to *when*
the sharded tracer's drains ran during capture (covered by test).
"""
from __future__ import annotations

import json


from repro.core.events import EventLog
from repro.core.tracer import Tracer


def to_chrome_trace(log: EventLog, tag_names: list[str] | None = None,
                    worker_names: list[str] | None = None,
                    critical=None,
                    worker_hosts: list[str] | None = None) -> str:
    """Serialize an EventLog as a Chrome trace JSON string.

    ``critical``: optional critical slices to overlay — any iterable of
    CriticalSlice rows (a list, a live ``CriticalBuffer`` or a columnar
    ``SliceTable`` / ``CriticalTable``).

    ``worker_hosts`` (fleet reports) renders *host lanes*: each host
    becomes its own process (pid) named after it, with that host's worker
    tracks inside; the critical overlay moves to the lane after the hosts.
    Without it the layout is the single-host one (everything in pid 0).
    """
    hosts: list[str] = []
    pid_of_worker: dict[int, int] = {}
    if worker_hosts:
        hosts = list(dict.fromkeys(worker_hosts))
        pid_of_worker = {w: hosts.index(h)
                         for w, h in enumerate(worker_hosts)}
    crit_pid = len(hosts) if hosts else 1

    def _pid(w: int) -> int:
        return pid_of_worker.get(int(w), 0)

    events = []
    open_spans: dict[int, tuple[int, int]] = {}
    for t, w, d, tag in zip(log.times, log.workers, log.deltas, log.tags):
        if d == 1:
            open_spans[int(w)] = (int(t), int(tag))
        else:
            start = open_spans.pop(int(w), None)
            if start is None:
                continue
            t0, tag0 = start
            name = tag_names[tag0] if tag_names and 0 <= tag0 < len(tag_names) \
                else f"tag{tag0}"
            events.append({
                "name": name, "ph": "X", "pid": _pid(w), "tid": int(w),
                "ts": t0 / 1e3, "dur": (int(t) - t0) / 1e3,
            })
    meta = []
    for pid, host in enumerate(hosts):
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "args": {"name": host}})
    if worker_names:
        for wid, name in enumerate(worker_names):
            meta.append({"name": "thread_name", "ph": "M", "pid": _pid(wid),
                         "tid": wid, "args": {"name": name}})
    for cs in critical or []:
        events.append({
            "name": "CRITICAL", "ph": "X", "pid": crit_pid, "tid": cs.worker,
            "ts": cs.start_ns / 1e3, "dur": (cs.end_ns - cs.start_ns) / 1e3,
            "args": {"cmetric_ms": cs.cm * 1e3,
                     "threads_av": cs.threads_av},
        })
    if critical:
        meta.append({"name": "process_name", "ph": "M", "pid": crit_pid,
                     "args": {"name": "critical slices"}})
    return json.dumps({"traceEvents": meta + events,
                       "displayTimeUnit": "ms"})


def dump_chrome_trace(tracer: Tracer, path: str) -> None:
    """Write a tracer's (or ProfileSession's) full trace to ``path``."""
    if hasattr(tracer, "export"):                 # ProfileSession (any source)
        tracer.export("chrome", path=path)
        return
    log = tracer.freeze()
    data = to_chrome_trace(log, tag_names=list(tracer.tags.names),
                           worker_names=tracer.worker_names(),
                           critical=tracer.critical)
    with open(path, "w") as f:
        f.write(data)
