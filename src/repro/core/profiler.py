"""Deprecated facades over :class:`~repro.core.session.ProfileSession`.

``Gapp`` and ``profile_log`` were the original batch-shaped API (capture
everything, ``freeze()``, detect once).  The profiler is now streaming-first:
use :class:`ProfileSession` directly —

=====================================  =====================================
old                                    new
=====================================  =====================================
``g = Gapp(...)``                      ``s = ProfileSession(...)``
``with g.running(): ...``              ``with s.running(): ...`` (or ``with s:``)
``g.report()``                         ``s.snapshot()`` (any time, live) /
                                       ``s.result()`` (final, on close)
``g.render()``                         ``s.export("text")``
``g.freeze()``                         ``s.freeze()``
``g.offline_report(backend=...)``      ``s.offline_report(backend=...)``
``profile_log(log, ...)``              ``ProfileSession.offline(log, ...).result()``
=====================================  =====================================

Both wrappers keep working (they delegate everything to a session and stay
bit-compatible on the ``numpy`` fold backend) but new call sites should
speak session: it adds the background drain+fold worker, ``watch()`` live
updates, the exporter registry and disk spill (``spill_path=``).
"""
from __future__ import annotations

import warnings

from repro.core import detector as detector_lib
from repro.core.events import EventLog
from repro.core.session import ProfileSession
from repro.core.tracer import StackRegistry, TagRegistry


class Gapp:
    """Deprecated live facade (tracer + probe + detection) — now a thin
    wrapper over one :class:`ProfileSession`; see the module docstring for
    the migration table.  ``.session`` exposes the underlying session;
    ``.tracer``/``.probe`` remain for existing call sites."""

    def __init__(self, n_min: float | None = None, dt: float = 0.003,
                 top_m: int = 8, top_n: int = 10, capacity: int = 1 << 16,
                 clock=None, fold_backend: str = "numpy",
                 autoflush: bool = True, spill_path: str | None = None,
                 chunk_events: int = 1 << 16):
        warnings.warn("Gapp is deprecated; use repro.core.ProfileSession",
                      DeprecationWarning, stacklevel=2)
        self.session = ProfileSession(
            n_min=n_min, dt=dt, top_m=top_m, top_n=top_n, capacity=capacity,
            clock=clock, fold_backend=fold_backend, autoflush=autoflush,
            spill_path=spill_path, chunk_events=chunk_events)
        self.tracer = self.session.tracer
        self.probe = self.session.probe
        self.top_n = top_n

    # --- worker / span API (delegates) ------------------------------------
    def register_worker(self, name: str, kind: str = "thread") -> int:
        return self.session.register_worker(name, kind)

    def handle(self, wid: int):
        """The worker's lock-free probe endpoint (hot-path begin/end)."""
        return self.session.handle(wid)

    def span(self, wid: int, tag: str):
        return self.session.span(wid, tag)

    def frame(self, wid: int, tag: str):
        return self.session.frame(wid, tag)

    def begin(self, wid: int, tag: str, loc: str | None = None) -> int:
        # Hot-path fix: the seed walked sys._getframe and built a location
        # string on EVERY begin; the callsite is now resolved once per
        # distinct tag inside the tracer (or passed explicitly via loc=).
        return self.session.begin(wid, tag, loc)

    def end(self, wid: int) -> None:
        return self.session.end(wid)

    def ingest(self, *a, **k):
        return self.session.ingest(*a, **k)

    # --- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        self.session.start()

    def stop(self) -> None:
        self.session.stop()

    def running(self):
        return self.session.running()

    # --- results -------------------------------------------------------------
    def report(self, top_n: int | None = None):
        return self.session.snapshot(top_n or self.top_n)

    def render(self, **kw) -> str:
        return self.session.export("text", **kw)

    def freeze(self) -> EventLog:
        return self.session.freeze()

    def offline_report(self, backend: str = "vector",
                       sample_dt_ns: int | None = None,
                       top_n: int | None = None,
                       chunk_events: int | None = None):
        return self.session.offline_report(
            backend=backend, sample_dt_ns=sample_dt_ns,
            top_n=top_n or self.top_n, chunk_events=chunk_events)


def profile_log(
    log: EventLog,
    tags: TagRegistry,
    stacks: StackRegistry,
    n_min: float,
    sample_dt_ns: int | None = 3_000_000,
    backend: str = "numpy",
    top_n: int = 10,
    worker_names: list[str] | None = None,
    chunk_events: int | None = None,
) -> "detector_lib.BottleneckReport":
    """Deprecated one-call offline pipeline — now
    ``ProfileSession.offline(...).result()``; ``chunk_events`` streams the
    replay in bounded memory."""
    warnings.warn("profile_log is deprecated; use "
                  "repro.core.ProfileSession.offline(log, ...).result()",
                  DeprecationWarning, stacklevel=2)
    return ProfileSession.offline(
        log, tags, stacks, n_min=n_min, backend=backend,
        chunk_events=chunk_events, sample_dt_ns=sample_dt_ns, top_n=top_n,
        worker_names=worker_names).result()
