"""GAPP facade: tracer + sampling probe + detection, one object.

Typical live use::

    gapp = Gapp(n_min=None, dt=0.003)       # n_min=None => total_workers/2
    w = gapp.register_worker("data_loader", kind="thread")
    with gapp.running():
        with gapp.span(w, "load_batch"):
            ...
    print(gapp.render())

Offline use (fleet traces / simulations)::

    rep = profile_log(log, tags, stacks, n_min=32, sample_dt_ns=3_000_000)
"""
from __future__ import annotations

import contextlib

from repro.core import detector as detector_lib
from repro.core import report as report_lib
from repro.core.events import EventLog
from repro.core.sampler import SamplingProbe
from repro.core.tracer import StackRegistry, TagRegistry, Tracer


class Gapp:
    def __init__(self, n_min: float | None = None, dt: float = 0.003,
                 top_m: int = 8, top_n: int = 10, capacity: int = 1 << 16,
                 clock=None, fold_backend: str = "numpy",
                 autoflush: bool = True):
        # capacity is per worker shard (see Tracer)
        kwargs = {} if clock is None else {"clock": clock}
        self.tracer = Tracer(n_min=n_min, top_m=top_m, capacity=capacity,
                             fold_backend=fold_backend, autoflush=autoflush,
                             **kwargs)
        self.probe = SamplingProbe(self.tracer, dt=dt, n_min=n_min)
        self.top_n = top_n

    # --- worker / span API (delegates) ------------------------------------
    def register_worker(self, name: str, kind: str = "thread") -> int:
        return self.tracer.register_worker(name, kind)

    def handle(self, wid: int):
        """The worker's lock-free probe endpoint (hot-path begin/end)."""
        return self.tracer.handle(wid)

    def span(self, wid: int, tag: str):
        return self.tracer.span(wid, tag)

    def frame(self, wid: int, tag: str):
        return self.tracer.frame(wid, tag)

    def begin(self, wid: int, tag: str):
        import sys
        f = sys._getframe(1)
        return self.tracer.begin(
            wid, tag, f"{f.f_globals.get('__name__', '?')}:{f.f_lineno}")

    def end(self, wid: int):
        return self.tracer.end(wid)

    def ingest(self, *a, **k):
        return self.tracer.ingest(*a, **k)

    # --- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        self.probe.start()

    def stop(self) -> None:
        self.probe.stop()

    @contextlib.contextmanager
    def running(self):
        self.start()
        try:
            yield self
        finally:
            self.stop()

    # --- results -------------------------------------------------------------
    def report(self, top_n: int | None = None) -> detector_lib.BottleneckReport:
        return detector_lib.detect(self.tracer, self.probe.buffer,
                                   top_n=top_n or self.top_n)

    def render(self, **kw) -> str:
        return report_lib.render_text(self.report(), **kw)

    def freeze(self) -> EventLog:
        return self.tracer.freeze()

    def offline_report(self, backend: str = "vector",
                       sample_dt_ns: int | None = None,
                       top_n: int | None = None,
                       chunk_events: int | None = None
                       ) -> detector_lib.BottleneckReport:
        """Recompute the profile offline from the accumulated log with any
        registered backend (cross-validates the online numbers; the vector/
        pallas paths are the fleet-scale post-processing route).
        ``chunk_events`` streams the fold in bounded memory via the
        carry-resumable ``fold_chunk``."""
        return detector_lib.detect_offline(
            self.freeze(), self.tracer.tags, self.tracer.stacks,
            self.tracer._resolved_n_min(), samples=self.probe.buffer
            if len(self.probe.buffer) else None, sample_dt_ns=sample_dt_ns,
            backend=backend, top_n=top_n or self.top_n,
            worker_names=self.tracer.worker_names(),
            chunk_events=chunk_events)


def profile_log(
    log: EventLog,
    tags: TagRegistry,
    stacks: StackRegistry,
    n_min: float,
    sample_dt_ns: int | None = 3_000_000,
    backend: str = "numpy",
    top_n: int = 10,
    worker_names: list[str] | None = None,
) -> detector_lib.BottleneckReport:
    """One-call offline pipeline over a raw event log."""
    return detector_lib.detect_offline(
        log, tags, stacks, n_min, sample_dt_ns=sample_dt_ns, backend=backend,
        top_n=top_n, worker_names=worker_names)
