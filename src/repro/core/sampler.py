"""Periodic sampling probe (paper §4.3).

A daemon thread fires every ``dt`` seconds; **iff** the instantaneous active
worker count is below ``n_min`` it records, for every active worker, the
current top-of-stack tag — the TPU-framework analogue of reading the
instruction pointer.  Samples go to a struct-of-arrays buffer shared with the
detector (the paper's single eBPF circular buffer).  A live
:class:`~repro.core.session.ProfileSession` owns one probe and starts/stops
it with the session; the incremental ``snapshot()`` reads the buffer
concurrently with appends (prefix reads are safe — rows publish before the
head moves).

The conditional is what keeps overhead negligible: during healthy, fully
parallel execution the probe wakes, reads one int, and goes back to sleep.
Both reads are lock-free against the sharded tracer: ``thread_count`` is
derived from each shard's last published event and ``active_tags`` peeks the
workers' immutable cons-chain tag stacks, so a probe firing never blocks —
and never delays — a worker's span hot path (the seed took the tracer's
global lock here, serializing the sampler against every begin/end).
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core.tracer import Tracer


class SampleBuffer:
    def __init__(self, capacity: int = 1 << 18):
        self.capacity = capacity
        self.times = np.zeros(capacity, np.int64)
        self.workers = np.zeros(capacity, np.int32)
        self.tags = np.zeros(capacity, np.int32)
        self.head = 0
        self.dropped = 0
        self._sorted = None
        self._sorted_head = -1

    def append(self, t: int, worker: int, tag: int) -> None:
        i = self.head
        if i >= self.capacity:
            self.dropped += 1
            return
        self.times[i] = t
        self.workers[i] = worker
        self.tags[i] = tag
        self.head = i + 1

    def frozen(self):
        n = self.head
        return self.times[:n], self.workers[:n], self.tags[:n]

    def frozen_sorted(self):
        """(times, workers, tags) lexsorted by (worker, time) — the layout
        the vectorised detector attaches with one searchsorted per worker
        group.  Cached until the next append."""
        n = self.head
        if self._sorted is None or self._sorted_head != n:
            t, w, g = self.frozen()
            order = np.lexsort((t, w))
            self._sorted = (t[order], w[order], g[order])
            self._sorted_head = n
        return self._sorted

    def __len__(self) -> int:
        return self.head


class SamplingProbe:
    """Δt-periodic conditional sampler (runs as a daemon thread)."""

    def __init__(self, tracer: Tracer, dt: float = 0.003,
                 n_min: float | None = None, capacity: int = 1 << 18):
        self.tracer = tracer
        self.dt = dt
        self.n_min = n_min
        self.buffer = SampleBuffer(capacity)
        self.ticks = 0
        self.hits = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _resolved_n_min(self) -> float:
        if self.n_min is not None:
            return self.n_min
        return self.tracer._resolved_n_min()

    def tick(self, t: int | None = None) -> int:
        """One probe firing; separated out so tests/simulations can drive it
        deterministically.  Returns number of samples taken."""
        self.ticks += 1
        if self.tracer.thread_count >= self._resolved_n_min():
            return 0
        t = self.tracer.clock() if t is None else t
        taken = 0
        for wid, tag in self.tracer.active_tags():
            self.buffer.append(t, wid, tag)
            taken += 1
        self.hits += taken
        return taken

    def _run(self) -> None:
        while not self._stop.wait(self.dt):
            self.tick()

    def start(self) -> None:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="gapp-sampler")
            self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()

    @property
    def running(self) -> bool:
        return self._thread is not None

    def stats(self) -> dict:
        """Probe counters for :meth:`ProfileSession.stats` / dashboards."""
        return {"ticks": self.ticks, "hits": self.hits,
                "stored": len(self.buffer), "dropped": self.buffer.dropped}


def simulate_samples(log, dt_ns: int, n_min: float,
                     buffer: SampleBuffer | None = None) -> SampleBuffer:
    """Offline replay of the sampling probe over a pre-timestamped
    :class:`~repro.core.events.EventLog` (simulated fleet traces, device-side
    timing streams) — produces exactly the samples the live probe would have
    taken had it run at ``dt_ns`` period against those events.

    Vectorised: for each tick we binary-search the event index, recover the
    active count from the running cumsum of deltas, and each worker's current
    tag from its most recent ACTIVATE.
    """
    buffer = buffer or SampleBuffer(max(1 << 12, 2 * len(log)))
    if len(log) == 0:
        return buffer
    t0, t1 = int(log.times[0]), int(log.times[-1])
    ticks = np.arange(t0 + dt_ns, t1, dt_ns, dtype=np.int64)
    if ticks.size == 0:
        return buffer
    counts = np.cumsum(log.deltas.astype(np.int64))
    # event index whose effect is live at tick time (rightmost event <= tick)
    ei = np.searchsorted(log.times, ticks, side="right") - 1
    low = counts[ei] < n_min
    if not np.any(low):
        return buffer
    # per-worker open-span tag via per-worker replay (W small, E moderate)
    for w in range(log.num_workers):
        sel = log.workers == w
        wt = log.times[sel]
        wd = log.deltas[sel]
        wtag = log.tags[sel]
        if wt.size == 0:
            continue
        j = np.searchsorted(wt, ticks[low], side="right") - 1
        openmask = (j >= 0) & (wd[np.maximum(j, 0)] == 1)
        tick_sel = ticks[low][openmask]
        tag_sel = wtag[np.maximum(j, 0)][openmask]
        for t, tag in zip(tick_sel, tag_sel):
            buffer.append(int(t), w, int(tag))
    return buffer
