"""Profile rendering — the Figure 7 / Table 2 / Figure 4-5 analogues.

These functions are registered as the ``"text"`` and ``"json"`` exporters
in :mod:`repro.core.exporters`; prefer ``session.export(fmt)`` /
``export(report, fmt)`` so new formats stay pluggable.  The JSON schema is
versioned via ``schema_version`` (bump on breaking layout changes).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.detector import BottleneckReport


def render_text(rep: BottleneckReport, max_paths: int | None = None,
                max_tags: int = 5, bar_width: int = 40,
                what_if: int | None = None,
                what_if_shrink: float = 0.0) -> str:
    """Human-readable profile: ranked call paths with sampled-tag frequency
    tables (Figure 7) followed by the per-worker CMetric chart (Figure 4/5).
    ``what_if=N`` appends counterfactual projections for the top-N paths
    (what removing each path's critical work would be worth)."""
    lines = []
    lines.append("=" * 72)
    lines.append("GAPP bottleneck profile")
    lines.append(f"  wall time        : {rep.total_time * 1e3:10.3f} ms")
    lines.append(f"  idle (n=0) time  : {rep.idle_time * 1e3:10.3f} ms")
    lines.append(f"  timeslices       : {rep.total_slices}")
    lines.append(f"  critical slices  : {rep.total_critical} "
                 f"(CR {100.0 * rep.critical_ratio:.2f}%)")
    ct = rep.critical_table
    if ct is not None and len(ct):
        lines.append("  critical av par  : "
                     f"{float(np.mean(ct.threads_av)):10.2f} "
                     f"(mean over {len(ct)} slices)")
    lines.append("=" * 72)
    paths = rep.paths if max_paths is None else rep.paths[:max_paths]
    for rank, p in enumerate(paths, 1):
        lines.append(f"#{rank}  CMetric {p.cmetric * 1e3:.3f} ms over "
                     f"{p.slices} slice(s)")
        lines.append(f"    path: {rep.path_str(p)}")
        total = sum(p.tag_counts.values())
        for tid, cnt in p.top_tags(max_tags):
            loc = rep.tag_locations[tid] if tid < len(rep.tag_locations) else "?"
            lines.append(f"      {cnt:6d} ({100.0 * cnt / max(total, 1):5.1f}%) "
                         f"{rep.tag_name(tid)}  [{loc}]")
        for tid, cnt in p.stack_top_counts.most_common(max_tags):
            lines.append(f"      {cnt:6d} (stack_top) {rep.tag_name(tid)}")
        lines.append("")
    # bottleneck classification (paper §7 extension)
    from repro.core.wakers import classify_report
    classes = classify_report(rep)
    if classes:
        total_cm = sum(classes.values())
        parts = ", ".join(f"{k} {v / total_cm * 100:.0f}%" for k, v in
                          sorted(classes.items(), key=lambda kv: -kv[1]))
        lines.append(f"critical CMetric by class: {parts}")
        lines.append("")
    # host lanes (fleet reports): fleet-wide roll-up, then one worker lane
    # block per host; single-host reports keep the flat chart
    if rep.worker_hosts:
        lines.append("per-host CMetric")
        per_host = rep.per_host()
        top_h = max((h["cmetric_s"] for h in per_host.values()), default=0.0)
        for host, row in sorted(per_host.items(),
                                key=lambda kv: -kv[1]["cmetric_s"]):
            n = int(bar_width * row["cmetric_s"] / top_h) if top_h > 0 else 0
            av = (f"  av par {row['threads_av_mean']:.2f}"
                  if row["threads_av_mean"] is not None else "")
            lines.append(f"  {host:>24s} {row['cmetric_s'] * 1e3:12.3f} ms "
                         f"|{'#' * n}")
            lines.append(f"  {'':>24s} {row['workers']} worker(s), "
                         f"{row['critical']} critical "
                         f"({row['critical_cm_s'] * 1e3:.3f} ms){av}")
        lines.append("")
    lines.append("per-worker CMetric")
    top = float(np.max(rep.per_worker)) if rep.per_worker.size else 0.0
    for wid in np.argsort(-rep.per_worker):
        v = float(rep.per_worker[wid])
        n = int(bar_width * v / top) if top > 0 else 0
        name = rep.worker_names[wid] if wid < len(rep.worker_names) else str(wid)
        lines.append(f"  {name:>24s} {v * 1e3:12.3f} ms |{'#' * n}")
    if what_if:
        lines.append("")
        lines.append(f"what-if projections (shrink={what_if_shrink:g})")
        for e in what_if_entries(rep, what_if, what_if_shrink):
            sp = (f"{e['speedup']:.3f}x" if e["speedup"] is not None
                  else "inf")
            lines.append(f"  fix #{e['rank']} {e['path']}: {sp} "
                         f"end-to-end (saves {e['saved_s'] * 1e3:.3f} ms)")
    return "\n".join(lines)


# Version of the to_json layout; parsers should check it before relying on
# key positions.  2 == schema_version introduced (layout otherwise as v1);
# 3 == additive host-provenance keys (worker_hosts / per_host, present only
# for fleet reports — v2 parsers keep working);
# 4 == additive "what_if" key (counterfactual projections, present only
# when the export is asked for them via ``what_if=N`` — v3 parsers keep
# working).
JSON_SCHEMA_VERSION = 4


def path_entries(rep: BottleneckReport,
                 max_paths: int | None = None) -> list[dict]:
    """JSON-ready ranked bottleneck entries — the single builder behind
    ``to_json``'s ``paths`` array, the watch/stream payloads and the
    service's ``/api/top``, so the three surfaces cannot drift apart."""
    paths = rep.paths if max_paths is None else rep.paths[:max_paths]
    return [
        {
            "rank": i + 1,
            "path": rep.path_str(p),
            "cmetric_s": p.cmetric,
            "slices": p.slices,
            "samples": {rep.tag_name(t): c for t, c in
                        p.tag_counts.most_common()},
            "stack_top": {rep.tag_name(t): c for t, c in
                          p.stack_top_counts.most_common()},
        }
        for i, p in enumerate(paths)
    ]


def what_if_entries(rep: BottleneckReport, top_n: int,
                    shrink: float = 0.0) -> list[dict]:
    """Counterfactual projections for the top-N ranked paths — the
    ``what_if=N`` sections of the text/json exporters.  Needs the
    report's replay handle (raises ``RuntimeError`` without one)."""
    out = []
    for rank in range(1, min(int(top_n), len(rep.paths)) + 1):
        wi = rep.what_if(path=rank, shrink=shrink)
        out.append({
            "rank": rank,
            "path": wi.selection["value"],
            "shrink": wi.shrink,
            "speedup": wi.to_doc()["speedup"],
            "saved_s": wi.saved_s,
            "projected_total_s": wi.projected_total_s,
        })
    return out


def to_json(rep: BottleneckReport, what_if: int | None = None,
            what_if_shrink: float = 0.0) -> str:
    ct = rep.critical_table
    host_fields = {}
    if rep.worker_hosts:
        host_fields = {"worker_hosts": list(rep.worker_hosts),
                       "per_host": rep.per_host()}
    extra = {}
    if what_if:
        extra["what_if"] = {"shrink": what_if_shrink,
                            "projections": what_if_entries(
                                rep, what_if, what_if_shrink)}
    return json.dumps({
        "schema_version": JSON_SCHEMA_VERSION,
        **host_fields,
        "total_time_s": rep.total_time,
        "idle_time_s": rep.idle_time,
        "total_slices": rep.total_slices,
        "total_critical": rep.total_critical,
        "critical_ratio": rep.critical_ratio,
        "critical_threads_av_mean": (float(np.mean(ct.threads_av))
                                     if ct is not None and len(ct) else None),
        "critical_cm_s": (float(np.sum(ct.cm))
                          if ct is not None and len(ct) else 0.0),
        "per_worker_cmetric_s": rep.per_worker.tolist(),
        "worker_names": rep.worker_names,
        "paths": path_entries(rep),
        **extra,
    }, indent=2)


def imbalance_stats(per_worker: np.ndarray) -> dict:
    """Summary statistics used by the load-balance experiments (Fig. 4/5):
    coefficient of variation and max/mean ratio of per-worker CMetric."""
    pw = np.asarray(per_worker, np.float64)
    mean = float(pw.mean()) if pw.size else 0.0
    return {
        "mean": mean,
        "std": float(pw.std()) if pw.size else 0.0,
        "cv": float(pw.std() / mean) if mean > 0 else 0.0,
        "max_over_mean": float(pw.max() / mean) if mean > 0 else 0.0,
        "argmax": int(pw.argmax()) if pw.size else -1,
    }
