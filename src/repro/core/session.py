"""Streaming ``ProfileSession`` — the profiler's public API.

GAPP is a *live* profiler: the paper streams context-switch events out of
per-CPU kernel ring buffers continuously and reports bottlenecks while the
workload runs.  :class:`ProfileSession` is that shape end-to-end: a session
wires an **event source** into the carry-resumable fold pipeline
(:func:`~repro.core.cmetric.fold_chunk` / ``FoldCarry``), runs a
**background drain+fold worker** so analysis overlaps capture, and exposes

* :meth:`snapshot` — an incremental :class:`BottleneckReport` available at
  any time, without stopping the workload (bit-equal on the ``numpy``
  backend to an offline recompute of the same prefix);
* :meth:`result` — the final report on close (quiesce + last drain);
* :meth:`watch` — live push: the drain worker delivers a fresh top-N
  report to a callback every ``every`` seconds;
* :meth:`export` — any registered exporter (:mod:`repro.core.exporters`:
  ``text`` / ``json`` / ``chrome`` / ``callback`` / ``watch``).

Sources are pluggable (:class:`EventSource`):

* :class:`TracerSource` — the live sharded tracer (default; created
  implicitly, spans via :meth:`ProfileSession.span` etc.);
* :class:`LogSource` — offline replay of an :class:`~repro.core.events.EventLog`
  in ``chunk_events`` batches (what :func:`repro.core.profiler.profile_log`
  wraps);
* :class:`SpillSource` — replay of a :class:`~repro.core.spill.SpillStore`
  file, one block at a time, so a spilled capture re-analyses in bounded
  memory.

Memory is bounded on the capture side too: ``ProfileSession(spill_path=...)``
gives the tracer a :class:`~repro.core.spill.SpillStore`, which pages every
drained chunk to an append-only file — resident event memory stays
O(``chunk_events``) for arbitrarily long runs (the two streaming items on
the ROADMAP: overlap drain/fold with capture, bound ``freeze()`` memory).

Fleet wiring rides the same shapes: ``session.export("remote",
addr=(host, port), journal=path)`` attaches a durable
:class:`~repro.fleet.transport.RemoteSink` (the journal makes producer
restarts resumable — see :mod:`repro.fleet.transport`), a
:class:`~repro.fleet.aggregate.FleetSource` — live from an
``IngestServer``, or replayed via ``FleetSource.from_files`` /
``FleetSource.from_fleet_dir`` — plugs in as this session's source, and
:meth:`stats` surfaces per-sink transport counters for dashboards.

Typical live use::

    with ProfileSession(n_min=None, dt=0.003) as s:
        w = s.register_worker("data_loader")
        s.watch(lambda rep: print(rep.paths[:1]), every=1.0)
        with s.span(w, "load_batch"):
            ...
        mid = s.snapshot()           # incremental, workload keeps running
    final = s.result()
    print(s.export("text", max_paths=3))

Offline replay::

    rep = ProfileSession.offline(log, tags, stacks, n_min=32,
                                 chunk_events=65536).result()
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Callable, Iterator


from repro.core import backends as backends_lib
from repro.core import detector as detector_lib
from repro.core import exporters as exporters_lib
from repro.core.cmetric import FoldCarry
from repro.core.events import EventLog, sanitize_chunk
from repro.core.sampler import SampleBuffer, SamplingProbe, simulate_samples
from repro.core.slices import CriticalBuffer
from repro.core.spill import SpillStore
from repro.core.tracer import StackRegistry, TagRegistry, Tracer


# ---------------------------------------------------------------------------
# pluggable event sources
# ---------------------------------------------------------------------------

class EventSource:
    """Where a session's events come from.

    Live sources (``live = True``) expose a :class:`Tracer` whose shards the
    background worker drains; offline sources yield time-sorted
    :class:`EventLog` chunks that the session folds through the same
    carry-resumable pipeline.  Offline sources carry their own tag/stack
    registries (empty ones by default) so reports can resolve names.
    """

    live = False
    num_workers: int = 0

    def worker_names(self) -> list[str]:
        return [f"w{i}" for i in range(self.num_workers)]

    def worker_hosts(self) -> list[str] | None:
        """Host provenance per worker (fleet sources); None == single-host."""
        return None

    def chunks(self) -> Iterator[EventLog]:
        raise NotImplementedError

    def request_stop(self) -> None:
        """Ask an open-ended source (e.g. a fleet ingest stream) to flush
        and end its chunk iterator; finite replays ignore it.  Called by
        :meth:`ProfileSession.stop` before joining the worker."""


class TracerSource(EventSource):
    """Live capture: the sharded lock-free tracer (paper's kernel probes)."""

    live = True

    def __init__(self, tracer: Tracer):
        self.tracer = tracer

    @property
    def tags(self) -> TagRegistry:
        return self.tracer.tags

    @property
    def stacks(self) -> StackRegistry:
        return self.tracer.stacks

    @property
    def num_workers(self) -> int:
        return self.tracer.total_count

    def worker_names(self) -> list[str]:
        return self.tracer.worker_names()


class LogSource(EventSource):
    """Offline replay of a finished :class:`EventLog` in bounded chunks."""

    def __init__(self, log: EventLog, tags: TagRegistry | None = None,
                 stacks: StackRegistry | None = None,
                 worker_names: list[str] | None = None,
                 chunk_events: int | None = None):
        self.log = log
        self.tags = tags if tags is not None else TagRegistry()
        self.stacks = stacks if stacks is not None else StackRegistry()
        self.num_workers = log.num_workers
        self.chunk_events = chunk_events
        self._worker_names = worker_names

    def worker_names(self) -> list[str]:
        return self._worker_names or super().worker_names()

    def chunks(self) -> Iterator[EventLog]:
        ce = self.chunk_events or max(len(self.log), 1)
        for lo in range(0, len(self.log), ce):
            yield self.log.chunk(lo, lo + ce)

    def full_log(self) -> EventLog:
        return self.log


class SpillSource(EventSource):
    """Offline replay of a spilled capture, one disk block at a time."""

    def __init__(self, store: SpillStore | str, num_workers: int,
                 tags: TagRegistry | None = None,
                 stacks: StackRegistry | None = None,
                 worker_names: list[str] | None = None,
                 chunk_events: int = 1 << 16):
        # a path means "replay this file": open read-only (the writer-mode
        # SpillStore constructor truncates, which would destroy the capture)
        self.store = store if isinstance(store, SpillStore) \
            else SpillStore.open_readonly(store, chunk_events)
        self.tags = tags if tags is not None else TagRegistry()
        self.stacks = stacks if stacks is not None else StackRegistry()
        self.num_workers = int(num_workers)
        self._worker_names = worker_names

    def worker_names(self) -> list[str]:
        return self._worker_names or super().worker_names()

    def chunks(self) -> Iterator[EventLog]:
        return self.store.iter_chunks(self.num_workers)

    def full_log(self) -> EventLog:
        return self.store.freeze(self.num_workers)


@dataclasses.dataclass
class _Watch:
    callback: Callable
    every: float
    top_n: int | None
    payload: bool = False    # deliver a JSON-ready dict, not the report
    next_due: float = 0.0    # guarded-by: ProfileSession._watch_lock


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class ProfileSession:
    """One profiling run: source → background drain+fold → reports/exports.

    With no ``source`` a live session is created: a sharded
    :class:`Tracer` (optionally spilling to ``spill_path``) plus the
    §4.3 sampling probe, both driven by :meth:`start`/:meth:`stop` (or the
    :meth:`running` context manager / ``with`` block).  ``drain_interval``
    is the background worker's cadence: how often pending shard events are
    k-way-merged and folded while the workload runs.

    Offline sources replay their chunks through the identical pipeline —
    in the background after :meth:`start`, or inline at :meth:`result`.
    """

    def __init__(self, source: EventSource | None = None, *,
                 n_min: float | None = None, dt: float = 0.003,
                 top_m: int = 8, top_n: int = 10, capacity: int = 1 << 16,
                 clock=None, fold_backend: str = "numpy",
                 autoflush: bool = True, drain_interval: float = 0.002,
                 spill_path: str | None = None, chunk_events: int = 1 << 16,
                 sample_dt_ns: int | None = None,
                 samples: SampleBuffer | None = None, store=None,
                 max_rows_per_sync: int | None = None):
        if source is None:
            if store is None and spill_path is not None:
                store = SpillStore(spill_path, chunk_events=chunk_events)
            kwargs = {} if clock is None else {"clock": clock}
            source = TracerSource(Tracer(
                n_min=n_min, top_m=top_m, capacity=capacity,
                fold_backend=fold_backend, autoflush=autoflush, store=store,
                max_rows_per_sync=max_rows_per_sync, **kwargs))
        self.source = source
        self.top_n = top_n
        self.fold_backend = fold_backend
        self.chunk_events = chunk_events
        self.drain_interval = drain_interval
        self._n_min = n_min
        self._watchers: list[_Watch] = []    # guarded-by: self._watch_lock
        self._watch_lock = threading.Lock()
        self.watch_errors: list[Exception] = []
        self._worker: threading.Thread | None = None
        self._stop_evt = threading.Event()
        self._closed = False
        self._final: "detector_lib.BottleneckReport | None" = None
        if source.live:
            self.tracer: Tracer | None = source.tracer
            self.probe: SamplingProbe | None = SamplingProbe(
                self.tracer, dt=dt, n_min=n_min)
            self._folded = 0
            self.tracer.on_drain.append(self._note_drain)
        else:
            self.tracer = None
            self.probe = None
            self._folded = 0
            self._sanitize_dropped = 0           # guarded-by: self._fold_lock
            self._sample_dt_ns = sample_dt_ns
            self._samples = samples
            self._carry = FoldCarry.init(source.num_workers)   # guarded-by: self._fold_lock
            self._crit = CriticalBuffer()        # guarded-by: self._fold_lock
            self._fold_lock = threading.Lock()
            self._chunk_iter: Iterator[EventLog] | None = None
            self._done = threading.Event()

    # -- construction helpers ------------------------------------------------
    @classmethod
    def offline(cls, log: EventLog, tags: TagRegistry | None = None,
                stacks: StackRegistry | None = None, *,
                n_min: float | None = None, backend: str = "numpy",
                chunk_events: int | None = None,
                sample_dt_ns: int | None = None,
                samples: SampleBuffer | None = None, top_n: int = 10,
                worker_names: list[str] | None = None) -> "ProfileSession":
        """Session over a finished log (the `profile_log` shape)."""
        src = LogSource(log, tags, stacks, worker_names, chunk_events)
        return cls(src, n_min=n_min, fold_backend=backend, top_n=top_n,
                   sample_dt_ns=sample_dt_ns, samples=samples,
                   chunk_events=chunk_events or 1 << 16)

    # -- live probe API (delegates; raises for offline sources) -------------
    def _live(self) -> Tracer:
        if self.tracer is None:
            raise RuntimeError("offline session has no live span API")
        return self.tracer

    def register_worker(self, name: str, kind: str = "thread") -> int:
        return self._live().register_worker(name, kind)

    def handle(self, wid: int):
        """The worker's lock-free probe endpoint (hot-path begin/end)."""
        return self._live().handle(wid)

    def span(self, wid: int, tag: str):
        return self._live().span(wid, tag)

    def frame(self, wid: int, tag: str):
        return self._live().frame(wid, tag)

    def begin(self, wid: int, tag: str, loc: str | None = None) -> int:
        """Open a span.  Allocation-free on the hot path: the callsite is
        resolved once per distinct tag (or pass ``loc=`` explicitly)."""
        return self._live().begin(wid, tag, loc)

    def end(self, wid: int) -> None:
        return self._live().end(wid)

    def push(self, wid: int, tag: str) -> None:
        return self._live().push(wid, tag)

    def pop(self, wid: int) -> None:
        return self._live().pop(wid)

    def ingest(self, *a, **k):
        return self._live().ingest(*a, **k)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Start the background machinery: the sampling probe and the
        drain+fold worker (live), or the chunk replay worker (offline)."""
        if self._worker is not None or self._closed:
            return
        self._stop_evt.clear()
        if self.source.live:
            self.probe.start()
            target = self._drain_loop
        else:
            target = self._offline_run
        self._worker = threading.Thread(target=target, daemon=True,
                                        name="gapp-session")
        self._worker.start()

    def stop(self) -> None:
        """Quiesce the background machinery (keeps the session open: spans
        can still be recorded and snapshots taken; ``close()`` finalizes).
        Open-ended sources (fleet ingest) are asked to flush and end their
        stream first, so the worker can't be stuck waiting for data."""
        self._stop_evt.set()
        self.source.request_stop()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None
        if self.probe is not None:
            self.probe.stop()

    @contextlib.contextmanager
    def running(self):
        self.start()
        try:
            yield self
        finally:
            self.stop()

    def __enter__(self) -> "ProfileSession":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Quiesce, run the final drain+fold, cache the final report."""
        if self._closed:
            return
        self.stop()
        if not self.source.live:
            self._offline_drain_inline()
        elif self.tracer.max_rows_per_sync is not None:
            self.tracer.sync()      # final reports are complete: consume
            #                         the backlog budget-wise before sealing
        # seal BEFORE the final snapshot so it takes the unbudgeted path —
        # stragglers appended since the sync above must all be folded
        self._closed = True
        self._final = self.snapshot()
        self._fire_watchers(force=True)
        store = getattr(self.tracer, "store", None) if self.tracer else None
        if store is not None:
            store.spill()
        for sink in getattr(self.tracer, "sinks", None) or []:
            sink.spill()            # flush-barrier attached RemoteSinks

    # -- background workers --------------------------------------------------
    def _note_drain(self, n_events: int) -> None:
        # tracer on_drain hook (under the fold lock): counters only
        self._folded += n_events

    def _drain_loop(self) -> None:
        tracer = self.tracer
        budgeted = tracer.max_rows_per_sync is not None
        backlog = 0
        # with a decode budget the loop bites off max_rows_per_sync rows per
        # shard per step and immediately re-runs while a backlog remains —
        # each step releases the fold lock, and a waiting snapshot()
        # (tracer._reader_waiting) makes the loop pause so the reader is
        # next in line: snapshot latency is one budget's decode, not the
        # whole backlog
        while not self._stop_evt.wait(
                0.0 if backlog and not tracer._reader_waiting
                else self.drain_interval):
            if budgeted:
                backlog = tracer.sync_budgeted()
            else:
                tracer.sync()
            self._fire_watchers()

    def _chunks(self) -> Iterator[EventLog]:
        if self._chunk_iter is None:
            self._chunk_iter = iter(self.source.chunks())
        return self._chunk_iter

    def _fold_one(self, part: EventLog) -> None:
        with self._fold_lock:
            # fleet sources grow their worker space as hosts join the
            # merge; the carry must cover every id before sanitize indexes
            # its open mask
            self._carry.ensure_workers(part.num_workers)
            part, _, keep = sanitize_chunk(part, self._carry.open)
            self._sanitize_dropped += int(keep.size - keep.sum())
            self._carry, tbl = backends_lib.fold_chunk(
                self._carry, part, backend=self.fold_backend)
            self._crit.extend_table(tbl, tbl.threads_av < self._resolved_n_min())
            self._folded += len(part)

    def _offline_run(self) -> None:
        self._ensure_samples()
        try:
            # fold every chunk pulled from the generator BEFORE checking the
            # stop flag: a pulled-but-unfolded chunk would be lost (the
            # iterator is shared with close()'s inline drain)
            for part in self._chunks():
                self._fold_one(part)
                self._fire_watchers()
                if self._stop_evt.is_set():
                    break
        finally:
            self._done.set()

    def _offline_drain_inline(self) -> None:
        """Consume any chunks the background worker did not reach."""
        self._ensure_samples()
        for part in self._chunks():
            self._fold_one(part)
        self._done.set()

    def _ensure_samples(self) -> None:
        if (self._samples is None and self._sample_dt_ns is not None
                and hasattr(self.source, "full_log")):
            self._samples = simulate_samples(
                self.source.full_log().sanitize(), self._sample_dt_ns,
                self._resolved_n_min())

    # -- watchers (live incremental push) ------------------------------------
    def watch(self, callback: Callable, every: float = 0.5,
              top_n: int | None = None,
              payload: bool = False) -> Callable[[], None]:
        """Push an incremental report to ``callback`` every ``every``
        seconds while the session runs (first fire is immediate; a final
        report is always pushed at close).  Returns an unsubscribe handle.
        Callback exceptions are recorded in :attr:`watch_errors`, never
        raised into the drain worker.

        ``payload=True`` delivers the JSON-ready frame built by
        :func:`repro.obs.payload.build_watch_payload` instead of the raw
        report — the same dict (``top`` + ``worker_hosts`` / ``per_host``
        lanes + ``health`` counters) that ``GET /api/stream`` pushes, so
        a watch callback and a stream subscriber can share rendering."""
        w = _Watch(callback, float(every), top_n, payload)
        with self._watch_lock:
            self._watchers.append(w)
        def unsubscribe() -> None:
            with self._watch_lock:
                if w in self._watchers:
                    self._watchers.remove(w)
        return unsubscribe

    def _fire_watchers(self, force: bool = False) -> None:
        now = time.monotonic()
        with self._watch_lock:
            due = [w for w in self._watchers
                   if force or now >= w.next_due]
            for w in due:
                # rescheduling inside the lock is the claim: a concurrent
                # _fire_watchers (drain loop vs. forced close) can no
                # longer select the same watcher and double-fire it
                w.next_due = now + w.every
        for w in due:
            try:
                rep = self.snapshot(w.top_n)
                if w.payload:
                    from repro.obs.payload import build_watch_payload
                    w.callback(build_watch_payload(self, rep, w.top_n))
                else:
                    w.callback(rep)
            except Exception as e:          # noqa: BLE001 — user callback
                self.watch_errors.append(e)

    # -- reports --------------------------------------------------------------
    def _resolved_n_min(self) -> float:
        if self.source.live:
            return self.tracer._resolved_n_min()
        if self._n_min is not None:
            return self._n_min
        return self.source.num_workers / 2

    @property
    def tags(self) -> TagRegistry:
        return self.source.tags

    @property
    def stacks(self) -> StackRegistry:
        return self.source.stacks

    def _use_pallas_hist(self) -> bool:
        caps = backends_lib.get_backend(self.fold_backend).capabilities
        return "fused" in caps and detector_lib._pallas_hist_native()

    def snapshot(self, top_n: int | None = None):
        """Incremental :class:`BottleneckReport` from the state folded so
        far — callable at any time, concurrently with capture (one sync
        point; the workload's probes never block on it)."""
        if self._closed and self._final is not None and top_n is None:
            return self._final
        top_n = top_n or self.top_n
        if self.source.live:
            # under a decode budget a mid-capture snapshot flushes at most
            # one budget (bounded latency); the final close() consumes the
            # whole backlog first, so sealed reports are complete
            budgeted = (not self._closed
                        and self.tracer.max_rows_per_sync is not None)
            return detector_lib.detect(self.tracer, self.probe.buffer,
                                       top_n=top_n, budgeted=budgeted)
        with self._fold_lock:
            crit = self._crit.table()
            st = self._carry.state()
        rep = detector_lib.build_report(
            crit, self._samples, self.stacks, self._resolved_n_min(),
            per_worker=st["per_worker"],
            worker_names=self.source.worker_names(),
            tag_names=list(self.tags.names),
            tag_locations=list(self.tags.locations),
            total_slices=st["slices"],
            idle_time=st["idle_time"],
            total_time=st["total_time"],
            top_n=top_n,
            use_pallas_hist=self._use_pallas_hist(),
            worker_hosts=self.source.worker_hosts(),
        )
        if hasattr(self.source, "full_log"):
            # counterfactual replay handle (lazy: nothing is read until a
            # what_if/sensitivity query actually runs)
            from repro.core.whatif import ReplaySpec
            rep.replay = ReplaySpec(
                log_provider=self.source.full_log, tags=self.tags,
                stacks=self.stacks, n_min=self._resolved_n_min(),
                backend=self.fold_backend, samples=self._samples,
                sample_dt_ns=self._sample_dt_ns,
                worker_names=self.source.worker_names(),
                worker_hosts=self.source.worker_hosts(),
                chunk_events=self.chunk_events)
        return rep

    def result(self, top_n: int | None = None):
        """The final report: quiesce (stop probe + worker), fold everything
        pending, close the session, return the report."""
        self.close()
        return self._final if top_n is None else self.snapshot(top_n)

    def freeze(self) -> EventLog:
        """The accumulated event log (live: store contents after a final
        drain; offline: the source's full log).  For a spill store this
        reads the whole file back — prefer streaming re-analysis via
        :class:`SpillSource` when memory matters."""
        if self.source.live:
            return self.tracer.freeze()
        if hasattr(self.source, "full_log"):
            return self.source.full_log()
        raise RuntimeError(f"{type(self.source).__name__} has no full log")

    def offline_report(self, backend: str = "vector",
                       sample_dt_ns: int | None = None,
                       top_n: int | None = None,
                       chunk_events: int | None = None):
        """Recompute the profile offline from the accumulated log with any
        registered backend (cross-validates the online numbers; the vector/
        pallas paths are the fleet-scale post-processing route)."""
        tr = self._live()
        return detector_lib.detect_offline(
            self.freeze(), tr.tags, tr.stacks, tr._resolved_n_min(),
            samples=self.probe.buffer if len(self.probe.buffer) else None,
            sample_dt_ns=sample_dt_ns, backend=backend,
            top_n=top_n or self.top_n, worker_names=tr.worker_names(),
            chunk_events=chunk_events)

    # -- output side -----------------------------------------------------------
    def export(self, fmt: str = "text", **kw):
        """Run a registered exporter on the current snapshot (see
        :mod:`repro.core.exporters`); the session is passed along so
        exporters like ``chrome`` can pull the event log.  Subscription
        exporters (``watch``) never consume a report, so no snapshot is
        built for them."""
        exp = exporters_lib.get_exporter(fmt)
        rep = None if "subscription" in exp.capabilities else self.snapshot()
        return exp(rep, session=self, **kw)

    def render(self, **kw) -> str:
        return self.export("text", **kw)

    def serve(self, addr: tuple[str, int] = ("127.0.0.1", 0), **kw):
        """Start a :class:`repro.fleet.service.ProfilerService` over this
        session: the live HTTP query API + dashboard (``/``,
        ``/api/report``, ``/api/top``, ``/api/whatif``, ``/api/hosts``,
        ``/api/stream``, ``/metrics``).  Keyword arguments (``server=``, ``fleet_dir=``,
        ``retention=``, ``top_n=``) pass through; returns the started
        service — ``close()`` it when done (the session is untouched)."""
        from repro.fleet.service import ProfilerService
        return ProfilerService(self, addr, **kw).start()

    # -- observability ---------------------------------------------------------
    def stats(self) -> dict:
        """Counters for dashboards/tests: capture, fold and memory state.

        The key sets below are a STABLE schema — ``/metrics`` names
        derive from them mechanically and
        ``tests/test_stats_schema.py`` pins them; removing or renaming a
        key is a breaking change, new keys are additive.  ``mode`` is
        ``"live"`` or ``"offline"`` and selects which set applies.

        Live sessions (``mode == "live"``):

        * ``events_folded`` — events merged+folded so far;
        * ``events_pending`` — ring entries not yet drained;
        * ``ring_dropped`` — events lost to ring overflow (capture loss);
        * ``tolerance_dropped`` — events rejected by the nesting checker;
        * ``store_rows`` / ``store_resident_rows`` — total captured rows
          vs rows still resident in memory (the rest spilled);
        * ``resident_bytes`` — tracer memory footprint;
        * ``samples`` — sampling-probe sub-dict (``ticks``, ``hits``,
          ``stored``, ``dropped``);
        * ``watch_errors`` — callback exceptions swallowed;
        * ``sinks`` — per-transport :meth:`RemoteSink.stats` list, only
          when fleet sinks are attached.

        Offline / fleet sessions (``mode == "offline"``):

        * ``events_folded`` — rows folded from the source;
        * ``sanitize_dropped`` — rows rejected during chunk sanitising;
        * ``slices`` — closed spans folded;
        * ``critical_rows`` — rows in the critical table;
        * ``done`` — source fully drained;
        * ``watch_errors`` — as above;
        * ``source`` — the source's own stats when it has any (a
          :class:`FleetSource` surfaces ``hosts``, ``rows_in``,
          ``chunks_in``, ``buffered_rows``, ``clock_clamped``,
          ``shed_chunks``, ``shed_rows``, ``idle_hosts``,
          ``accepting``), so a consumer can tell whether the fold was
          complete or degraded.
        """
        if self.source.live:
            tr = self.tracer
            store = tr.store
            out = {
                "mode": "live",
                "events_folded": self._folded,
                "events_pending": tr.ring.pending(),
                "ring_dropped": tr.ring.dropped,
                "tolerance_dropped": tr.tolerance_dropped,
                "store_rows": len(store),
                "store_resident_rows": getattr(store, "resident_rows",
                                               len(store)),
                "resident_bytes": tr.memory_bytes(),
                "samples": self.probe.stats(),
                "watch_errors": len(self.watch_errors),
            }
            sinks = [s.stats() for s in getattr(tr, "sinks", None) or []
                     if hasattr(s, "stats")]
            if sinks:       # attached transports (e.g. fleet RemoteSinks)
                out["sinks"] = sinks
            return out
        out = {
            "mode": "offline",
            "events_folded": self._folded,
            "sanitize_dropped": self._sanitize_dropped,
            "slices": self._carry.slices,
            "critical_rows": len(self._crit),
            "done": self._done.is_set(),
            "watch_errors": len(self.watch_errors),
        }
        src_stats = getattr(self.source, "stats", None)
        if callable(src_stats):
            # e.g. a FleetSource: surfaces shed/lost/idle degradation so a
            # report consumer can see whether the fold was complete
            out["source"] = src_stats()
        return out
