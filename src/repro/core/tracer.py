"""Runtime tracer — the software analogue of GAPP's kernel probes.

The probe path is **sharded and lock-free**: every worker owns a private
capture shard (:class:`~repro.core.events.EventShard`) and ``begin``/``end``
append ``(timestamp, meta)`` to it with no cross-worker lock, no numpy row
stores, no dict updates and no stack interning — the per-event cost is a
clock read plus two deque appends.  This mirrors the paper's design rule
that the in-kernel probe body must be O(1) and tiny (§3, Table 2): the seed
implementation serialized every event of every worker through one global
``threading.Lock`` plus Python-dict eBPF-map updates, which made the
profiler itself the serialization bottleneck it is meant to detect (that
probe body is retained below as :class:`LockedTracer`, the measured
baseline and semantic oracle).

The expensive part — maintaining the paper's Table-1 eBPF-map state — is
deferred and batched: a flush drains all shards
(:meth:`~repro.core.events.ShardedEventRing.drain` k-way-merges them by
timestamp), applies the §3.2 tolerance rules vectorised
(:func:`~repro.core.events.tolerance_keep`), and replays the batch through
the carry-resumable vectorised fold
(:func:`~repro.core.cmetric.fold_chunk`), whose
:class:`~repro.core.cmetric.FoldCarry` is exactly the Table-1 state:

    global_cm     running Σ T_i / n_i                      (global scalar)
    local_cm[w]   global_cm snapshot at switch-in          (per-worker)
    thread_count  number of active workers                 (global scalar)
    total_count   number of registered workers             (global scalar)
    cm_hash[w]    cumulative CMetric per worker            (global hash)
    t_switch      timestamp of the previous event          (local scalar)

Flushes run at sync points (``freeze``/``per_worker_cm``/``report``/…)
and opportunistically when a shard fills (``autoflush``); with the
``numpy`` fold backend the online state is *bit-identical* to
``compute_numpy`` over the frozen log.

Call paths are captured as immutable cons chains (``(tag_id, parent)``)
so ``end`` records the whole stack by reference in O(1); they are
unwound and interned **only** when the finished timeslice is critical
(``threads_av < n_min``) — the paper's §4.2 "stacks only for critical
slices" rule, now enforced end-to-end (non-critical ends allocate no
stack ids at all).

Workers are *logical*: host threads, DP hosts, pipeline stages, MoE
experts.  ``register_worker`` mirrors the paper's ``task_newtask`` probe.
Each worker's handle must be driven by one thread at a time (the shard is
single-writer); distinct workers never contend.
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
import threading
import time
from typing import Iterator

import numpy as np

from repro.core import backends as backends_lib
from repro.core.events import (ACTIVATE, DEACTIVATE, NO_STACK, NO_TAG,
                               EventLog, EventRing, EventStore,
                               ShardedEventRing, tolerance_keep)
from repro.core.slices import CriticalBuffer, CriticalSlice  # noqa: F401 (re-export)


@dataclasses.dataclass
class WorkerInfo:
    wid: int
    name: str
    kind: str            # "host" | "thread" | "stage" | "expert" | "device"


class TagRegistry:
    """tag string -> dense id, with code location (the addr2line analogue)."""

    def __init__(self):
        self._ids: dict[str, int] = {}      # guarded-by: self._lock
        self.names: list[str] = []          # guarded-by: self._lock
        self.locations: list[str] = []      # guarded-by: self._lock
        self._lock = threading.Lock()

    def intern(self, tag: str, location: str | None = None) -> int:
        tid = self._ids.get(tag)
        if tid is not None:
            return tid
        with self._lock:
            tid = self._ids.get(tag)
            if tid is None:
                tid = len(self.names)
                self.names.append(tag)
                self.locations.append(location or "<unknown>")
                self._ids[tag] = tid   # publish last: readers skip the lock
        return tid

    def __len__(self) -> int:
        return len(self.names)


class StackRegistry:
    """Interned call paths (tuples of tag ids), truncated to top-M frames."""

    def __init__(self, top_m: int = 8):
        self.top_m = top_m
        self._ids: dict[tuple, int] = {}    # guarded-by: self._lock
        self.paths: list[tuple] = []        # guarded-by: self._lock
        self._lock = threading.Lock()

    def intern(self, stack: tuple) -> int:
        stack = stack[-self.top_m:]
        sid = self._ids.get(stack)
        if sid is not None:
            return sid
        with self._lock:
            sid = self._ids.get(stack)
            if sid is None:
                sid = len(self.paths)
                self.paths.append(stack)
                self._ids[stack] = sid
        return sid

    def intern_cons(self, cons) -> int:
        """Intern a captured cons-chain stack (head = top of stack)."""
        items = []
        while cons is not None:
            items.append(cons[0])
            cons = cons[1]
        items.reverse()                    # caller -> callee, like the seed
        return self.intern(tuple(items))

    def __len__(self) -> int:
        return len(self.paths)


class WorkerHandle:
    """One worker's lock-free probe endpoint.

    ``begin``/``end`` are closures bound to the worker's shard (built in
    :meth:`Tracer.register_worker`); calling them through the handle is the
    hot path — :meth:`Tracer.begin`/:meth:`Tracer.end` are thin compat
    wrappers.  ``stack`` is the live tag stack as an immutable cons chain
    ``(tag_id, parent)`` (``None`` when empty), so the sampler can read the
    top frame and ``end`` can capture the whole path by reference without
    copying.  Single-writer: one thread drives a handle at a time.
    """

    __slots__ = ("wid", "name", "kind", "shard", "stack", "begin", "end")

    def __init__(self, wid: int, name: str, kind: str, shard):
        self.wid = wid
        self.name = name
        self.kind = kind
        self.shard = shard
        self.stack = None

    @contextlib.contextmanager
    def span(self, tag: str) -> Iterator[None]:
        self.begin(tag)
        try:
            yield
        finally:
            self.end()


class Tracer:
    """Sharded low-overhead span tracer with batched online CMetric.

    ``capacity`` is per worker shard.  ``fold_backend`` selects the
    registered chunk fold that maintains the online state (``"numpy"`` is
    the bit-exact float64 default); ``autoflush=False`` disables the
    opportunistic flush when a shard fills, so a full shard drops new
    events (counted) like a BPF ring buffer.

    ``store`` is where drained+folded chunks accumulate — anything with the
    :class:`~repro.core.events.EventStore` interface; pass a
    :class:`~repro.core.spill.SpillStore` to page the stream to disk and
    bound resident memory.  ``on_drain`` hooks (``fn(folded_events)``,
    called under the fold lock after each non-empty flush) let a
    :class:`~repro.core.session.ProfileSession`'s background worker track
    drain progress without polling the store.
    """

    def __init__(self, n_min: float | None = None, top_m: int = 8,
                 capacity: int = 1 << 16, clock=time.perf_counter_ns,
                 fold_backend: str = "numpy", autoflush: bool = True,
                 store=None, max_rows_per_sync: int | None = None):
        self.n_min = n_min              # None => total_count/2, resolved lazily
        self.clock = clock
        self.fold_backend = fold_backend
        self.autoflush = autoflush
        # per-shard decode budget of one flush: caps the Python decode loop
        # a single sync (and therefore a mid-capture snapshot) can run, so a
        # multi-MHz producer can't starve readers.  None == drain fully.
        self.max_rows_per_sync = max_rows_per_sync
        self.tags = TagRegistry()
        self.stacks = StackRegistry(top_m)
        self.ring = ShardedEventRing(capacity)
        self.workers: list[WorkerInfo] = []       # guarded-by: self._reg_lock
        self._handles: list[WorkerHandle] = []    # guarded-by: self._reg_lock
        # Table-1 eBPF-map state lives in the fold carry; it advances only
        # at flush time, by replaying drained batches through fold_chunk.
        from repro.core.cmetric import FoldCarry  # deferred: import cycle
        self._carry = FoldCarry.init(0)           # guarded-by: self._fold_lock
        self._store = store if store is not None else EventStore()
        # extra chunk consumers (e.g. repro.fleet's RemoteSink): every
        # drained+folded chunk is forwarded right after it lands in the
        # store, same columns, same order
        self.sinks: list = []
        self._critical = CriticalBuffer()
        self._total_slices = 0                    # guarded-by: self._fold_lock
        self.on_drain: list = []    # fn(folded_events), under the fold lock
        # events removed by the §3.2 tolerance filter at flush time (e.g.
        # the orphaned end of a span whose begin was ring-dropped): the full
        # accounting is appended == len(freeze()) + ring.dropped + this
        self.tolerance_dropped = 0                # guarded-by: self._fold_lock
        self._fold_lock = threading.Lock()     # flush/drain consumer lock
        # reader-priority hint: while a snapshot() waits on the fold lock,
        # the drain loop and the producers' opportunistic autoflushes back
        # off so the reader is next in line (a plain bool — races only
        # delay the hint by one flush)
        self._reader_waiting = False
        self._reg_lock = threading.Lock()
        self.enabled = True

    # -- task_newtask analogue ----------------------------------------------
    def register_worker(self, name: str, kind: str = "thread") -> int:
        with self._reg_lock:
            wid = len(self.workers)
            shard = self.ring.add_shard()
            h = WorkerHandle(wid, name, kind, shard)
            h.begin, h.end = self._make_hot_path(h, shard)
            self.workers.append(WorkerInfo(wid, name, kind))
            self._handles.append(h)
        return wid

    def handle(self, wid: int) -> WorkerHandle:
        """The worker's lock-free probe endpoint (the actual hot path)."""
        return self._handles[wid]

    def _make_hot_path(self, h: WorkerHandle, shard):
        """Build the two per-event closures.  Everything they touch is a
        local cell: the tag dict, the clock, the shard deques.  No locks,
        no numpy, no interning — decode happens at drain time."""
        ids = self.tags._ids
        clock = self.clock
        ta = shard.times.append
        ma = shard.metas.append
        md = shard.metas
        cap = shard.capacity
        dlen = len
        slow = self._append_slow
        intern_cold = self._intern_at_callsite

        def begin(tag, location=None):
            try:
                tid = ids[tag]
            except KeyError:
                tid = intern_cold(tag, location)
            h.stack = (tid, h.stack)
            if dlen(md) >= cap and not slow(shard):
                return tid
            ta(clock())
            ma(tid)  # publishes: ta -- int meta == ACTIVATE
            return tid

        def end():
            s = h.stack                   # captured path, by reference
            if s is not None:
                h.stack = s[1]
            if dlen(md) >= cap and not slow(shard):
                return
            ta(clock())
            ma(s)  # publishes: ta -- cons/None meta == DEACTIVATE

        return begin, end

    def _intern_at_callsite(self, tag: str, location: str | None) -> int:
        """Cold path of tag interning: runs once per distinct tag, so it can
        afford the frame walk the seed paid on every single begin()."""
        if location is None:
            f = sys._getframe(2)
            # walk out of profiler-internal frames (tracer, session/Gapp
            # facades, contextlib's @contextmanager machinery) to the user
            # call site
            while f is not None and (
                    (f.f_globals.get("__name__") or "").startswith("repro.core")
                    or f.f_globals.get("__name__") == "contextlib"):
                f = f.f_back
            if f is not None:
                location = f"{f.f_globals.get('__name__', '?')}:{f.f_lineno}"
        return self.tags.intern(tag, location)

    def _append_slow(self, shard) -> bool:
        """A shard hit capacity: try a non-blocking flush, then either admit
        the event or drop it (counted, BPF ringbuf semantics)."""
        if (self.autoflush and not self._reader_waiting
                and self._fold_lock.acquire(False)):
            try:
                # respect the decode budget: freeing one budget's worth of
                # rows is enough to admit the event without a long stall
                # lint: disable=guarded-by(fold lock IS held here — taken via the non-blocking acquire(False) two lines up, which the lexical pass cannot see)
                self._flush_locked(self.max_rows_per_sync)
            finally:
                self._fold_lock.release()
        if len(shard.metas) >= shard.capacity:
            shard.dropped += 1
            return False
        return True

    @property
    def total_count(self) -> int:
        return len(self.workers)

    def _resolved_n_min(self) -> float:
        return self.n_min if self.n_min is not None else self.total_count / 2

    # -- batched probe analysis (the deferred Table-1 state machine) ---------
    def sync(self) -> None:
        """Drain all shards and replay the batch through the vectorised
        chunk fold, advancing the online CMetric/critical-slice state.

        Always complete: with a ``max_rows_per_sync`` budget the backlog
        present at entry is consumed in budget-sized flushes (bounded even
        under a live producer — rows appended *during* the sync stay
        pending, exactly like the unbudgeted single-pass drain)."""
        with self._fold_lock:
            if self.max_rows_per_sync is None:
                self._flush_locked()
                return
            remaining = self.ring.pending()
            while remaining > 0:
                done = self._flush_locked(self.max_rows_per_sync)
                if done == 0:
                    break
                remaining -= done

    def sync_budgeted(self) -> int:
        """One budget-capped flush (the session drain loop's step): decodes
        at most ``max_rows_per_sync`` rows per shard, so a mid-capture
        ``snapshot()`` waiting on the fold lock is never stuck behind an
        unbounded decode.  Returns the rows still pending after it."""
        with self._fold_lock:
            self._flush_locked(self.max_rows_per_sync)
        return self.ring.pending()

    def _flush_locked(self, limit: int | None = None) -> int:  # guarded-by: self._fold_lock
        chunk = self.ring.drain(limit)
        # total_count *after* the drain: a worker that registered while we
        # drained may already have events in the chunk, and every map below
        # must cover its id
        w_count = self.total_count
        carry = self._carry
        carry.ensure_workers(w_count)
        if chunk is None:
            return 0
        drained = len(chunk)
        times = chunk.times
        workers = chunk.workers
        deltas = chunk.deltas
        tags = chunk.tags
        aux = chunk.aux
        # Cross-flush monotonic repair: a producer preempted between its
        # clock read and its publish can surface an event older than the
        # already-folded watermark; clamping keeps the accumulated log
        # time-sorted (the error is bounded by the preemption window).
        if carry.t_last_ns is not None and times[0] < carry.t_last_ns:
            times = np.maximum(times, carry.t_last_ns)
        # §3.2 tolerance, applied vectorised against the carry's open mask —
        # the fold updates it identically after consuming the clean chunk,
        # so the Table-1 carry is the single source of the per-worker state
        keep, _ = tolerance_keep(workers, deltas, carry.open)
        if not keep.all():
            self.tolerance_dropped += int(keep.size - keep.sum())
            times, workers, deltas, tags, aux = (
                times[keep], workers[keep], deltas[keep], tags[keep],
                aux[keep])
        if times.shape[0] == 0:
            return drained
        stacks_col = np.full(times.shape[0], NO_STACK, np.int32)
        clog = EventLog(times, workers, deltas, tags, stacks_col, w_count)
        self._carry, table = backends_lib.fold_chunk(
            carry, clog, backend=self.fold_backend)
        # §4.2: intern call paths for critical timeslices only
        crit_mask = table.threads_av < self._resolved_n_min()
        if crit_mask.any():
            deact_pos = np.flatnonzero(deltas == DEACTIVATE)
            aux_out = aux[deact_pos]
            intern_cons = self.stacks.intern_cons
            for r in np.flatnonzero(crit_mask):
                sid = intern_cons(aux_out[r])
                table.stack_id[r] = sid
                stacks_col[deact_pos[r]] = sid
            self._critical.extend_table(table, crit_mask)
        self._store.append_columns(times, workers, deltas, tags, stacks_col)
        for sink in self.sinks:
            sink.append_columns(times, workers, deltas, tags, stacks_col)
        self._total_slices += len(table)
        for hook in self.on_drain:
            hook(times.shape[0])
        return drained

    # -- public span API (compat wrappers over the handle hot path) ----------
    def begin(self, wid: int, tag: str, location: str | None = None) -> int:
        if not self.enabled:
            return NO_TAG
        return self._handles[wid].begin(tag, location)

    def end(self, wid: int) -> None:
        if not self.enabled:
            return
        self._handles[wid].end()

    @contextlib.contextmanager
    def span(self, wid: int, tag: str) -> Iterator[None]:
        h = self._handles[wid]
        h.begin(tag)
        try:
            yield
        finally:
            h.end()

    # Tag refinement inside an active span: adds call-path context without a
    # scheduling event (the worker stays active).
    def push(self, wid: int, tag: str) -> None:
        h = self._handles[wid]
        h.stack = (self.tags.intern(tag), h.stack)

    def pop(self, wid: int) -> None:
        h = self._handles[wid]
        s = h.stack
        if s is not None:
            h.stack = s[1]

    @contextlib.contextmanager
    def frame(self, wid: int, tag: str) -> Iterator[None]:
        self.push(wid, tag)
        try:
            yield
        finally:
            self.pop(wid)

    # -- sampling-probe reads (lock-free; see sampler.py) --------------------
    @property
    def thread_count(self) -> int:
        """Instantaneous active-worker count, read off the shards."""
        return sum(h.shard.is_open for h in self._handles)

    def active_tags(self) -> list[tuple[int, int]]:
        """(wid, top-of-stack tag) of each active worker — the 'instruction
        pointer' read.  Lock-free: cons stacks are immutable snapshots."""
        out = []
        for h in self._handles:
            s = h.stack
            if s is not None and h.shard.is_open:
                out.append((h.wid, s[0]))
        return out

    # -- ingestion of external (synthetic / device-side) event streams -------
    def ingest(self, t: int, wid: int, delta: int, tag: str = "",
               stack: tuple[str, ...] = ()) -> None:
        """Feed a pre-timestamped event (simulated fleet trace, device timing
        stream) into the worker's shard; it flows through the same drain +
        sanitize + fold pipeline as live spans.  Not a hot path."""
        h = self._handles[wid]
        sh = h.shard
        # the tag stack must mirror the caller's span structure even when
        # the ring is full — like the hot-path closures, apply the push/pop
        # unconditionally and drop only the event
        has_room = (len(sh.metas) < sh.capacity or self._append_slow(sh))
        if delta == ACTIVATE:
            tid = self.tags.intern(tag) if tag else NO_TAG
            h.stack = (tid, h.stack)
            if has_room:
                sh.times.append(int(t))
                sh.metas.append(tid)   # publishes: sh.times
        else:
            if stack:
                cons = None
                for s_ in stack:          # caller->callee in, head=callee out
                    cons = (self.tags.intern(s_), cons)
            else:
                cons = h.stack
            if has_room:
                sh.times.append(int(t))
                sh.metas.append(cons)  # publishes: sh.times
            s = h.stack
            if s is not None:
                h.stack = s[1]

    # -- results --------------------------------------------------------------
    def snapshot(self, budgeted: bool = False) -> dict:
        """One consistent view of the online state under a single sync —
        what the detector consumes (per-property access would re-sync and
        could interleave fresh mini-batches between reads).

        ``budgeted=True`` caps the flush at ``max_rows_per_sync`` rows per
        shard: the snapshot may then lag the capture by the undecoded
        backlog (incremental semantics), but its latency is bounded no
        matter how fast producers append."""
        self._reader_waiting = True
        try:
            with self._fold_lock:
                self._reader_waiting = False
                return self._snapshot_locked(budgeted)
        finally:
            self._reader_waiting = False

    def _snapshot_locked(self, budgeted: bool) -> dict:  # guarded-by: self._fold_lock
        self._flush_locked(self.max_rows_per_sync if budgeted else None)
        carry = self._carry
        return {
            "critical": self._critical.table(),
            "per_worker": carry.per_worker_padded(self.total_count),
            "total_slices": self._total_slices,
            "idle_time": carry.idle,
            "total_time": carry.total_time,
        }

    @property
    def critical(self) -> CriticalBuffer:
        """Online critical slices, columnar (synced on access)."""
        self.sync()
        return self._critical

    @property
    def idle_time(self) -> float:
        self.sync()
        return self._carry.idle

    @property
    def global_cm(self) -> float:
        self.sync()
        return self._carry.global_cm

    @property
    def t_first(self) -> int | None:
        self.sync()
        return self._carry.t0_ns

    @property
    def t_switch(self) -> int | None:
        self.sync()
        return self._carry.t_last_ns

    @property
    def total_slices(self) -> int:
        self.sync()
        return self._total_slices

    def freeze(self) -> EventLog:
        self.sync()
        return self._store.freeze(self.total_count)

    @property
    def store(self):
        """The accumulating event store (EventStore or SpillStore)."""
        return self._store

    def per_worker_cm(self) -> np.ndarray:
        self.sync()
        return self._carry.per_worker_padded(self.total_count)

    def worker_names(self) -> list[str]:
        return [w.name for w in self.workers]

    def memory_bytes(self) -> int:
        """Profiler-side *resident* memory: accumulated log (its RAM share
        only, for a spill store) + pending shards + critical buffer (the
        paper's Table-2 'M' column analogue)."""
        store_b = getattr(self._store, "resident_nbytes", None)
        if store_b is None:
            store_b = self._store.nbytes
        return store_b + self.ring.approx_nbytes() + self._critical.nbytes


class LockedTracer:
    """The seed probe body: one global lock + per-event Python map updates.

    Retained verbatim as (a) the measured baseline of the probe
    microbenchmark (``bench_cmetric`` / ``--smoke probe``) and (b) a
    semantic oracle for the sharded tracer — both maintain the paper's
    Table-1 state, one per event under a lock, one batched through the
    vectorised fold.  Do not use for live profiling: every ``begin``/``end``
    of every worker serializes on ``_lock``.
    """

    def __init__(self, n_min: float | None = None, top_m: int = 8,
                 capacity: int = 1 << 20, clock=time.perf_counter_ns):
        self.n_min = n_min
        self.clock = clock
        self.tags = TagRegistry()
        self.stacks = StackRegistry(top_m)
        self.ring = EventRing(capacity)
        self.workers: list[WorkerInfo] = []       # guarded-by: self._lock
        self._tag_stacks: dict[int, list[int]] = {}   # guarded-by: self._lock
        self._open: set[int] = set()              # guarded-by: self._lock
        self.global_cm = 0.0                      # guarded-by: self._lock
        self.local_cm: dict[int, float] = {}      # guarded-by: self._lock
        self.slice_start: dict[int, int] = {}     # guarded-by: self._lock
        self.thread_count = 0                     # guarded-by: self._lock
        self.cm_hash: dict[int, float] = {}       # guarded-by: self._lock
        self.idle_time = 0.0                      # guarded-by: self._lock
        self.t_switch: int | None = None          # guarded-by: self._lock
        self.t_first: int | None = None           # guarded-by: self._lock
        self.critical = CriticalBuffer()          # guarded-by: self._lock
        self._lock = threading.Lock()
        self.enabled = True

    def register_worker(self, name: str, kind: str = "thread") -> int:
        with self._lock:
            wid = len(self.workers)
            self.workers.append(WorkerInfo(wid, name, kind))
            self._tag_stacks[wid] = []
            self.cm_hash[wid] = 0.0
            self.local_cm[wid] = 0.0
        return wid

    @property
    def total_count(self) -> int:
        return len(self.workers)

    def _resolved_n_min(self) -> float:
        return self.n_min if self.n_min is not None else self.total_count / 2

    # the seed sched_switch probe body (call with self._lock held)
    def _event(self, t: int, wid: int, delta: int,  # guarded-by: self._lock
               tag: int, stack: int) -> None:
        if self.t_first is None:
            self.t_first = t
        dt = (t - self.t_switch) * 1e-9 if self.t_switch is not None else 0.0
        if self.thread_count > 0:
            self.global_cm += dt / self.thread_count
        else:
            self.idle_time += dt
        self.t_switch = t
        if delta == ACTIVATE:
            if wid in self._open:      # paper §3.2: already-running threads
                return                 # do not alter thread_count
            self.local_cm[wid] = self.global_cm
            self.slice_start[wid] = t
            self.thread_count += 1
            self._open.add(wid)
        else:
            if wid not in self._open:  # spurious switch-out: ignore
                return
            slice_cm = self.global_cm - self.local_cm[wid]
            self.cm_hash[wid] = self.cm_hash.get(wid, 0.0) + slice_cm
            self.thread_count -= 1
            self._open.discard(wid)
            dur = (t - self.slice_start.get(wid, t)) * 1e-9
            threads_av = dur / slice_cm if slice_cm > 0 else float(
                max(self.thread_count + 1, 1))
            if threads_av < self._resolved_n_min():
                self.critical.append(
                    wid, self.slice_start.get(wid, t), t, slice_cm,
                    threads_av, stack, self.thread_count + 1)
        self.ring.append(t, wid, delta, tag, stack)

    def begin(self, wid: int, tag: str, location: str | None = None) -> int:
        if not self.enabled:
            return NO_TAG
        if location is None:
            f = sys._getframe(1)
            location = f"{f.f_globals.get('__name__', '?')}:{f.f_lineno}"
        tid = self.tags.intern(tag, location)
        with self._lock:
            self._tag_stacks[wid].append(tid)
            self._event(self.clock(), wid, ACTIVATE, tid, NO_STACK)
        return tid

    def end(self, wid: int) -> None:
        if not self.enabled:
            return
        with self._lock:
            st = self._tag_stacks[wid]
            sid = self.stacks.intern(tuple(st))
            tid = st.pop() if st else NO_TAG
            self._event(self.clock(), wid, DEACTIVATE, tid, sid)

    @contextlib.contextmanager
    def span(self, wid: int, tag: str) -> Iterator[None]:
        self.begin(wid, tag)
        try:
            yield
        finally:
            self.end(wid)

    def sync(self) -> None:
        """No-op: the locked body maintains its state per event."""

    @property
    def total_slices(self) -> int:
        with self._lock:
            n = min(self.ring.head, self.ring.capacity)
        return int(np.sum(self.ring.deltas[:n] == DEACTIVATE)) if n else 0

    def snapshot(self) -> dict:
        """One consistent view of the online state (single lock hold)."""
        with self._lock:
            n = min(self.ring.head, self.ring.capacity)
            return {
                "critical": self.critical.table(),
                "per_worker": self.per_worker_cm(),
                "total_slices": int(np.sum(
                    self.ring.deltas[:n] == DEACTIVATE)) if n else 0,
                "idle_time": self.idle_time,
                "total_time": ((self.t_switch - self.t_first) * 1e-9
                               if self.t_first is not None else 0.0),
            }

    def freeze(self) -> EventLog:
        return self.ring.freeze(self.total_count)

    def per_worker_cm(self) -> np.ndarray:
        out = np.zeros(self.total_count)
        for w, v in self.cm_hash.items():
            out[w] = v
        return out

    def worker_names(self) -> list[str]:
        return [w.name for w in self.workers]
