"""Runtime tracer — the software analogue of GAPP's kernel probes.

The tracer plays the role of the eBPF ``sched_switch`` probe: every span
begin/end is a state-change event, and the probe body maintains *exactly* the
eBPF maps of paper Table 1, online, in O(1) per event:

    global_cm     running Σ T_i / n_i                      (global scalar)
    local_cm[w]   global_cm snapshot at switch-in          (per-worker)
    thread_count  number of active workers                 (global scalar)
    total_count   number of registered workers             (global scalar)
    cm_hash[w]    cumulative CMetric per worker            (global hash)
    t_switch      timestamp of the previous event          (local scalar)

As in the paper, call paths are captured **only** when a finished timeslice is
critical (``threads_av < n_min``) — the key low-overhead design rule — and raw
events additionally go to a ring buffer so the offline backends (streaming /
vectorised / Pallas) can recompute and cross-validate the online numbers.

Workers are *logical*: host threads, DP hosts, pipeline stages, MoE experts.
``register_worker`` mirrors the paper's ``task_newtask`` probe.
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
import threading
import time
from typing import Iterator

import numpy as np

from repro.core.events import ACTIVATE, DEACTIVATE, NO_STACK, NO_TAG, EventLog, EventRing
from repro.core.slices import CriticalBuffer, CriticalSlice  # noqa: F401 (re-export)


@dataclasses.dataclass
class WorkerInfo:
    wid: int
    name: str
    kind: str            # "host" | "thread" | "stage" | "expert" | "device"


class TagRegistry:
    """tag string -> dense id, with code location (the addr2line analogue)."""

    def __init__(self):
        self._ids: dict[str, int] = {}
        self.names: list[str] = []
        self.locations: list[str] = []
        self._lock = threading.Lock()

    def intern(self, tag: str, location: str | None = None) -> int:
        tid = self._ids.get(tag)
        if tid is not None:
            return tid
        with self._lock:
            tid = self._ids.get(tag)
            if tid is None:
                tid = len(self.names)
                self._ids[tag] = tid
                self.names.append(tag)
                self.locations.append(location or "<unknown>")
        return tid

    def __len__(self) -> int:
        return len(self.names)


class StackRegistry:
    """Interned call paths (tuples of tag ids), truncated to top-M frames."""

    def __init__(self, top_m: int = 8):
        self.top_m = top_m
        self._ids: dict[tuple, int] = {}
        self.paths: list[tuple] = []
        self._lock = threading.Lock()

    def intern(self, stack: tuple) -> int:
        stack = stack[-self.top_m:]
        sid = self._ids.get(stack)
        if sid is not None:
            return sid
        with self._lock:
            sid = self._ids.get(stack)
            if sid is None:
                sid = len(self.paths)
                self._ids[stack] = sid
                self.paths.append(stack)
        return sid

    def __len__(self) -> int:
        return len(self.paths)


class Tracer:
    """Low-overhead span tracer with online CMetric (the kernel-probe body)."""

    def __init__(self, n_min: float | None = None, top_m: int = 8,
                 capacity: int = 1 << 20, clock=time.perf_counter_ns):
        self.n_min = n_min              # None => total_count/2, resolved lazily
        self.clock = clock
        self.tags = TagRegistry()
        self.stacks = StackRegistry(top_m)
        self.ring = EventRing(capacity)
        self.workers: list[WorkerInfo] = []
        self._tag_stacks: dict[int, list[int]] = {}
        self._open: set[int] = set()      # workers with an open slice
        # eBPF-map state (paper Table 1)
        self.global_cm = 0.0
        self.local_cm: dict[int, float] = {}
        self.slice_start: dict[int, int] = {}
        self.thread_count = 0
        self.cm_hash: dict[int, float] = {}
        self.idle_time = 0.0
        self.t_switch: int | None = None
        self.t_first: int | None = None
        # online critical slices, stored columnar: .table() hands the whole
        # buffer to the vectorised detector without a per-slice conversion
        self.critical = CriticalBuffer()
        self._lock = threading.Lock()
        self.enabled = True

    # -- task_newtask analogue ----------------------------------------------
    def register_worker(self, name: str, kind: str = "thread") -> int:
        with self._lock:
            wid = len(self.workers)
            self.workers.append(WorkerInfo(wid, name, kind))
            self._tag_stacks[wid] = []
            self.cm_hash[wid] = 0.0
            self.local_cm[wid] = 0.0
        return wid

    @property
    def total_count(self) -> int:
        return len(self.workers)

    def _resolved_n_min(self) -> float:
        return self.n_min if self.n_min is not None else self.total_count / 2

    # -- the sched_switch probe body (call with self._lock held) -------------
    def _event(self, t: int, wid: int, delta: int, tag: int, stack: int) -> None:
        if self.t_first is None:
            self.t_first = t
        dt = (t - self.t_switch) * 1e-9 if self.t_switch is not None else 0.0
        if self.thread_count > 0:
            self.global_cm += dt / self.thread_count
        else:
            self.idle_time += dt
        self.t_switch = t
        if delta == ACTIVATE:
            if wid in self._open:      # paper §3.2: already-running threads
                return                 # do not alter thread_count
            self.local_cm[wid] = self.global_cm
            self.slice_start[wid] = t
            self.thread_count += 1
            self._open.add(wid)
        else:
            if wid not in self._open:  # spurious switch-out: ignore
                return
            slice_cm = self.global_cm - self.local_cm[wid]
            self.cm_hash[wid] = self.cm_hash.get(wid, 0.0) + slice_cm
            self.thread_count -= 1
            self._open.discard(wid)
            dur = (t - self.slice_start.get(wid, t)) * 1e-9
            threads_av = dur / slice_cm if slice_cm > 0 else float(
                max(self.thread_count + 1, 1))
            if threads_av < self._resolved_n_min():
                self.critical.append(
                    wid, self.slice_start.get(wid, t), t, slice_cm,
                    threads_av, stack, self.thread_count + 1)
        self.ring.append(t, wid, delta, tag, stack)

    # -- public span API ------------------------------------------------------
    def begin(self, wid: int, tag: str, location: str | None = None) -> int:
        if not self.enabled:
            return NO_TAG
        if location is None:
            f = sys._getframe(1)
            location = f"{f.f_globals.get('__name__', '?')}:{f.f_lineno}"
        tid = self.tags.intern(tag, location)
        with self._lock:
            self._tag_stacks[wid].append(tid)
            self._event(self.clock(), wid, ACTIVATE, tid, NO_STACK)
        return tid

    def end(self, wid: int) -> None:
        if not self.enabled:
            return
        with self._lock:
            st = self._tag_stacks[wid]
            sid = self.stacks.intern(tuple(st))
            tid = st.pop() if st else NO_TAG
            self._event(self.clock(), wid, DEACTIVATE, tid, sid)

    @contextlib.contextmanager
    def span(self, wid: int, tag: str) -> Iterator[None]:
        f = sys._getframe(2)
        self.begin(wid, tag, f"{f.f_globals.get('__name__', '?')}:{f.f_lineno}")
        try:
            yield
        finally:
            self.end(wid)

    # Tag refinement inside an active span: adds call-path context without a
    # scheduling event (the worker stays active).
    def push(self, wid: int, tag: str) -> None:
        tid = self.tags.intern(tag)
        with self._lock:
            self._tag_stacks[wid].append(tid)

    def pop(self, wid: int) -> None:
        with self._lock:
            st = self._tag_stacks[wid]
            if st:
                st.pop()

    @contextlib.contextmanager
    def frame(self, wid: int, tag: str) -> Iterator[None]:
        self.push(wid, tag)
        try:
            yield
        finally:
            self.pop(wid)

    # -- sampling-probe read: 'instruction pointer' of each active worker ----
    def active_tags(self) -> list[tuple[int, int]]:
        with self._lock:
            return [(wid, self._tag_stacks[wid][-1])
                    for wid in self._open if self._tag_stacks.get(wid)]

    # -- ingestion of external (synthetic / device-side) event streams -------
    def ingest(self, t: int, wid: int, delta: int, tag: str = "",
               stack: tuple[str, ...] = ()) -> None:
        """Feed a pre-timestamped event (simulated fleet trace, device timing
        stream) through the same probe body as live spans."""
        tid = self.tags.intern(tag) if tag else NO_TAG
        with self._lock:
            if delta == ACTIVATE:
                self._tag_stacks[wid].append(tid)
                self._event(t, wid, ACTIVATE, tid, NO_STACK)
            else:
                st = self._tag_stacks[wid]
                if stack:
                    sid = self.stacks.intern(
                        tuple(self.tags.intern(s) for s in stack))
                elif st:
                    sid = self.stacks.intern(tuple(st))
                else:
                    sid = NO_STACK
                self._event(t, wid, DEACTIVATE, tid, sid)
                if st:
                    st.pop()

    def freeze(self) -> EventLog:
        return self.ring.freeze(self.total_count)

    def per_worker_cm(self) -> np.ndarray:
        out = np.zeros(self.total_count)
        for w, v in self.cm_hash.items():
            out[w] = v
        return out

    def worker_names(self) -> list[str]:
        return [w.name for w in self.workers]
