"""CMetric backend registry.

Every offline CMetric implementation (the numpy float64 oracle, the
paper-faithful ``lax.scan`` stream, the data-parallel vector formulation and
the fused Pallas pipeline) registers itself here under a short name with a
set of capability tags.  ``compute`` dispatches by name; callers that want
"whatever runs on device" can select by capability instead of hardcoding a
backend string.

The registry replaces the old module-level ``_BACKENDS`` dict in
``repro.core.cmetric`` plus the special-cased lazy ``pallas`` import in
``cmetric.compute``: a backend may register a loader that defers heavy
imports (Pallas, kernels) until first use, so importing ``repro.core`` never
pulls in ``jax.experimental.pallas``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

# A backend maps an EventLog to a CMetricResult; typed loosely to keep this
# module import-cycle-free (cmetric imports backends, not vice versa).
BackendFn = Callable[..., object]


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    fn: BackendFn
    capabilities: frozenset[str]
    # carry-resumable chunk fold: (FoldCarry, EventLog) -> (FoldCarry,
    # SliceTable).  Optional — backends without one only support whole-log
    # computes; ``fold_chunk`` below raises for them.
    chunk_fn: BackendFn | None = None

    def __call__(self, log):
        return self.fn(log)


_REGISTRY: dict[str, Backend] = {}


def register_backend(name: str, fn: BackendFn | None = None, *,
                     capabilities: Iterable[str] = (),
                     fold_chunk: BackendFn | None = None) -> BackendFn:
    """Register ``fn`` as CMetric backend ``name``.

    Usable directly (``register_backend("numpy", compute_numpy)``) or as a
    decorator (``@register_backend("mine", capabilities={"device"})``).
    ``fold_chunk`` optionally attaches the backend's carry-resumable chunk
    fold (see :class:`repro.core.cmetric.FoldCarry`).  Re-registering a
    name replaces it (tests swap in instrumented backends).
    """
    def _register(f: BackendFn) -> BackendFn:
        _REGISTRY[name] = Backend(name, f, frozenset(capabilities),
                                  fold_chunk)
        return f
    return _register(fn) if fn is not None else _register


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown CMetric backend {name!r}; available: "
            f"{', '.join(available_backends())}") from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def backends_with(capability: str) -> list[str]:
    """Names of backends advertising ``capability`` (e.g. 'device')."""
    return sorted(b.name for b in _REGISTRY.values()
                  if capability in b.capabilities)


def compute(log, backend: str = "numpy"):
    """Dispatch an EventLog through the named backend."""
    return get_backend(backend)(log)


def backends_with_fold_chunk() -> list[str]:
    """Names of backends that support the carry-resumable chunk fold."""
    return sorted(b.name for b in _REGISTRY.values()
                  if b.chunk_fn is not None)


def fold_chunk(carry, log, backend: str = "numpy"):
    """Advance a :class:`repro.core.cmetric.FoldCarry` over one chunk with
    the named backend; returns ``(carry, SliceTable)``."""
    b = get_backend(backend)
    if b.chunk_fn is None:
        raise ValueError(f"backend {backend!r} has no chunked fold; "
                         f"available: {', '.join(backends_with_fold_chunk())}")
    return b.chunk_fn(carry, log)
