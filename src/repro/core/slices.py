"""Columnar timeslice IR — the interchange type of the offline pipeline.

Everything downstream of the CMetric fold (critical-slice extraction, sample
attachment, call-path merging, ranking) used to traffic in ``list[CriticalSlice]``
Python objects, which made the accelerated fold feed a host-side per-slice
loop — exactly the serialization pathology the paper profiles.  The types
here replace that with aligned struct-of-arrays:

* :class:`SliceTable` — S aligned columns describing completed timeslices
  (worker, start_ns, end_ns, cm, threads_av, stack_id, n_at_exit).  This is
  what the CMetric backends emit and what the detector consumes; every
  pipeline stage over it is a numpy/JAX array op.
* :class:`CriticalTable` — a :class:`SliceTable` filtered by the criticality
  threshold, remembering the ``n_min`` that produced it.
* :class:`CriticalBuffer` — amortized-O(1) growable columnar buffer used by
  the live tracer (the online analogue: slices are appended one at a time as
  the probe fires, but the stored form is already columnar so ``.table()``
  is a copy-free-ish view, not a conversion loop).
* :class:`CriticalSlice` — the legacy per-slice record, kept as the row view
  (``table[i]``) and for the retained Python-loop oracle in the detector.

Times are absolute nanoseconds on the source log's clock so samples (which
carry ns timestamps) attach without rebasing; CMetrics are seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence

import numpy as np


@dataclasses.dataclass
class CriticalSlice:
    """Row view of one completed timeslice (legacy / oracle representation)."""

    worker: int
    start_ns: int
    end_ns: int
    cm: float            # seconds
    threads_av: float
    stack_id: int
    n_at_exit: int       # instantaneous active count at switch-out


_COLUMNS = ("worker", "start_ns", "end_ns", "cm", "threads_av", "stack_id",
            "n_at_exit")
_DTYPES = (np.int32, np.int64, np.int64, np.float64, np.float64, np.int32,
           np.int32)


@dataclasses.dataclass
class SliceTable:
    """Aligned columns, one row per completed timeslice (time-ordered by
    slice end, the order DEACTIVATE events fire)."""

    worker: np.ndarray      # int32[S]
    start_ns: np.ndarray    # int64[S] absolute ns
    end_ns: np.ndarray      # int64[S]
    cm: np.ndarray          # float64[S] seconds
    threads_av: np.ndarray  # float64[S]
    stack_id: np.ndarray    # int32[S] interned call-path id (or -1)
    n_at_exit: np.ndarray   # int32[S]

    # -- construction --------------------------------------------------------
    @classmethod
    def empty(cls) -> "SliceTable":
        return cls(*[np.zeros(0, dt) for dt in _DTYPES])

    @classmethod
    def from_arrays(cls, worker, start_ns, end_ns, cm, threads_av, stack_id,
                    n_at_exit) -> "SliceTable":
        cols = (worker, start_ns, end_ns, cm, threads_av, stack_id, n_at_exit)
        return cls(*[np.asarray(c, dt) for c, dt in zip(cols, _DTYPES)])

    @classmethod
    def from_records(cls, records: Iterable[CriticalSlice]) -> "SliceTable":
        rows = list(records)
        if not rows:
            return cls.empty()
        return cls.from_arrays(
            [r.worker for r in rows], [r.start_ns for r in rows],
            [r.end_ns for r in rows], [r.cm for r in rows],
            [r.threads_av for r in rows], [r.stack_id for r in rows],
            [r.n_at_exit for r in rows])

    @classmethod
    def concat(cls, tables: Sequence["SliceTable"]) -> "SliceTable":
        if not tables:
            return cls.empty()
        return cls(*[np.concatenate([getattr(t, c) for t in tables])
                     for c in _COLUMNS])

    # -- views ---------------------------------------------------------------
    def __len__(self) -> int:
        return int(self.worker.shape[0])

    def row(self, i: int) -> CriticalSlice:
        return CriticalSlice(
            worker=int(self.worker[i]), start_ns=int(self.start_ns[i]),
            end_ns=int(self.end_ns[i]), cm=float(self.cm[i]),
            threads_av=float(self.threads_av[i]),
            stack_id=int(self.stack_id[i]), n_at_exit=int(self.n_at_exit[i]))

    def __getitem__(self, i):
        if isinstance(i, (int, np.integer)):
            return self.row(int(i))
        return SliceTable(*[getattr(self, c)[i] for c in _COLUMNS])

    def __iter__(self) -> Iterator[CriticalSlice]:
        for i in range(len(self)):
            yield self.row(i)

    def to_records(self) -> list[CriticalSlice]:
        return list(self)

    def filter(self, mask: np.ndarray) -> "SliceTable":
        return SliceTable(*[getattr(self, c)[mask] for c in _COLUMNS])

    @property
    def duration_ns(self) -> np.ndarray:
        return self.end_ns - self.start_ns

    def critical(self, n_min: float) -> "CriticalTable":
        """Rows under the criticality threshold (paper §4.2 trigger)."""
        mask = self.threads_av < n_min
        return CriticalTable(*[getattr(self, c)[mask] for c in _COLUMNS],
                             n_min=float(n_min))

    def validate(self) -> None:
        s = len(self)
        for c, dt in zip(_COLUMNS, _DTYPES):
            col = getattr(self, c)
            if col.shape != (s,):
                raise ValueError(f"column {c} misaligned: {col.shape}")
        if s and np.any(self.end_ns < self.start_ns):
            raise ValueError("slice ends before it starts")


@dataclasses.dataclass
class CriticalTable(SliceTable):
    """A :class:`SliceTable` filtered by ``threads_av < n_min``."""

    n_min: float = float("nan")


class CriticalBuffer:
    """Growable columnar buffer of critical slices (online tracer storage).

    Append is amortized O(1) into doubling numpy arrays; ``table()`` exposes
    the filled prefix as a :class:`SliceTable` without a per-row conversion.
    Row access (``buf[i]``) and iteration yield :class:`CriticalSlice` views
    so legacy consumers (chrome-trace overlay, tests) keep working.
    """

    def __init__(self, capacity: int = 1024):
        self._cap = max(int(capacity), 1)
        self._cols = [np.zeros(self._cap, dt) for dt in _DTYPES]
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def _grow(self) -> None:
        self._cap *= 2
        self._cols = [np.concatenate([c, np.zeros(len(c), c.dtype)])
                      for c in self._cols]

    def append(self, worker: int, start_ns: int, end_ns: int, cm: float,
               threads_av: float, stack_id: int, n_at_exit: int) -> None:
        if self._len == self._cap:
            self._grow()
        i = self._len
        vals = (worker, start_ns, end_ns, cm, threads_av, stack_id, n_at_exit)
        for col, v in zip(self._cols, vals):
            col[i] = v
        self._len = i + 1

    def extend_table(self, table: SliceTable,
                     mask: np.ndarray | None = None) -> None:
        """Bulk-append ``table`` rows (optionally only where ``mask``) —
        one vectorised copy per column, used by the tracer's batched flush
        instead of a per-slice Python loop."""
        src = table.filter(mask) if mask is not None else table
        s = len(src)
        if s == 0:
            return
        while self._len + s > self._cap:
            self._grow()
        lo = self._len
        for col, name in zip(self._cols, _COLUMNS):
            col[lo:lo + s] = getattr(src, name)
        self._len = lo + s

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self._cols)

    def table(self) -> SliceTable:
        # snapshot length and column list once: a concurrent append (live
        # tracer threads) past this point can't misalign the returned view,
        # since rows below the snapshot are fully written before _len moves
        n = self._len
        cols = self._cols
        return SliceTable(*[c[:n] for c in cols])

    def __getitem__(self, i):
        if isinstance(i, (int, np.integer)):
            idx = int(i)
            if idx < 0:
                idx += self._len
            if not 0 <= idx < self._len:
                raise IndexError(i)
            return self.table().row(idx)
        return self.table()[i]

    def __iter__(self) -> Iterator[CriticalSlice]:
        return iter(self.table())
