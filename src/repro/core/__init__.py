"""GAPP core: criticality-metric serialization-bottleneck profiler.

Architecture — the offline dataflow is columnar end-to-end::

    EventLog (struct-of-arrays event stream; ``events.py``)
        │  sanitize()           drop spurious double-ACTIVATE / unmatched
        │                       DEACTIVATE (the live tracer's §3.2 rules)
        ▼
    CMetric backend (``backends.py`` registry: numpy | stream | vector | pallas)
        │  fold                 interval lengths → active counts → global_cm
        │                       prefix (Pallas ``cmetric_fold`` on TPU)
        │  pair + segment-sum   stable sort by worker pairs IN/OUT events;
        │                       per-slice CMetric = gcm[out] - gcm[in]
        ▼
    CMetricResult — thin wrapper over a SliceTable (``slices.py``):
        aligned columns (worker, start_ns, end_ns, cm, threads_av,
        stack_id, n_at_exit), one row per completed timeslice
        │  critical(n_min)      threads_av threshold → CriticalTable
        ▼
    Detector (``detector.py``, fully vectorised over the table):
        sample attachment       one searchsorted per worker group
        path merge              bincount/segment-sum keyed on stack id
        tag frequency tables    flat (path, tag) histogram — Pallas
                                ``tag_hist`` kernel on the fused backend
        ▼
    BottleneckReport → render_text / to_json (``report.py``)

The live path (``tracer.py``) captures events into per-worker lock-free
shards (``ShardedEventRing``) and maintains the same Table-1 state by
draining the shards and replaying each batch through the carry-resumable
vectorised fold (``fold_chunk`` + ``FoldCarry``) — the hot path is two
deque appends, the map updates are batched array ops.  Critical slices
land in a growable columnar ``CriticalBuffer`` whose ``.table()`` feeds
the same detector; call paths are interned only for critical slices.
``detect_offline(chunk_events=...)`` streams arbitrarily long logs
through the same chunk fold in bounded memory.  Backends register
themselves in ``backends.py`` via ``register_backend(name, fn,
capabilities=..., fold_chunk=...)``; ``compute(log, backend=)``
dispatches by name and new implementations can be plugged in without
touching the pipeline.
"""
from repro.core.events import (ACTIVATE, DEACTIVATE, EventLog, EventRing,
                               EventStore, ShardedEventRing, sanitize_chunk,
                               synthetic_log, tolerance_keep)
from repro.core.slices import (CriticalBuffer, CriticalSlice, CriticalTable,
                               SliceTable)
from repro.core.backends import (available_backends, backends_with,
                                 backends_with_fold_chunk, get_backend,
                                 register_backend)
from repro.core.cmetric import (CMetricResult, FoldCarry, compute,
                                compute_numpy, compute_streaming,
                                compute_vectorized, fold_chunk)
from repro.core.tracer import (LockedTracer, StackRegistry, TagRegistry,
                               Tracer, WorkerHandle)
from repro.core.sampler import SampleBuffer, SamplingProbe, simulate_samples
from repro.core.detector import (BottleneckReport, PathProfile, detect,
                                 detect_offline, merge_table)
from repro.core.report import imbalance_stats, render_text, to_json
from repro.core.profiler import Gapp, profile_log

__all__ = [
    "ACTIVATE", "DEACTIVATE", "EventLog", "EventRing", "EventStore",
    "ShardedEventRing", "sanitize_chunk", "synthetic_log", "tolerance_keep",
    "SliceTable", "CriticalTable", "CriticalBuffer", "CriticalSlice",
    "available_backends", "backends_with", "backends_with_fold_chunk",
    "get_backend", "register_backend",
    "CMetricResult", "FoldCarry", "compute", "compute_numpy",
    "compute_streaming", "compute_vectorized", "fold_chunk",
    "StackRegistry", "TagRegistry", "Tracer", "LockedTracer", "WorkerHandle",
    "SampleBuffer", "SamplingProbe", "simulate_samples",
    "BottleneckReport", "PathProfile", "detect", "detect_offline",
    "merge_table", "imbalance_stats", "render_text", "to_json", "Gapp",
    "profile_log",
]
from repro.core.wakers import (classify_report, classify_tag,  # noqa: E402
                               critical_wakers, waker_edges)

__all__ += ["classify_report", "classify_tag", "critical_wakers",
            "waker_edges"]
from repro.core.timeline import dump_chrome_trace, to_chrome_trace  # noqa: E402,F401

__all__ += ["dump_chrome_trace", "to_chrome_trace"]
