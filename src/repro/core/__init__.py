"""GAPP core: criticality-metric serialization-bottleneck profiler."""
from repro.core.events import (ACTIVATE, DEACTIVATE, EventLog, EventRing,
                               synthetic_log)
from repro.core.cmetric import (CMetricResult, compute, compute_numpy,
                                compute_streaming, compute_vectorized)
from repro.core.tracer import (CriticalSlice, StackRegistry, TagRegistry,
                               Tracer)
from repro.core.sampler import SampleBuffer, SamplingProbe, simulate_samples
from repro.core.detector import (BottleneckReport, PathProfile, detect,
                                 detect_offline)
from repro.core.report import imbalance_stats, render_text, to_json
from repro.core.profiler import Gapp, profile_log

__all__ = [
    "ACTIVATE", "DEACTIVATE", "EventLog", "EventRing", "synthetic_log",
    "CMetricResult", "compute", "compute_numpy", "compute_streaming",
    "compute_vectorized", "CriticalSlice", "StackRegistry", "TagRegistry",
    "Tracer", "SampleBuffer", "SamplingProbe", "simulate_samples",
    "BottleneckReport", "PathProfile", "detect", "detect_offline",
    "imbalance_stats", "render_text", "to_json", "Gapp", "profile_log",
]
from repro.core.wakers import (classify_report, classify_tag,  # noqa: E402
                               critical_wakers, waker_edges)

__all__ += ["classify_report", "classify_tag", "critical_wakers",
            "waker_edges"]
from repro.core.timeline import dump_chrome_trace, to_chrome_trace  # noqa: E402,F401

__all__ += ["dump_chrome_trace", "to_chrome_trace"]
