"""GAPP core: criticality-metric serialization-bottleneck profiler.

Architecture — capture, analysis and output are one streaming pipeline
around a :class:`~repro.core.session.ProfileSession`::

    EventSource (``session.py``)
      ├── TracerSource   live sharded lock-free capture (``tracer.py``)
      ├── LogSource      offline EventLog replay in chunk_events batches
      └── SpillSource    replay of a disk-spilled capture (``spill.py``)
        │
        ▼  background drain+fold worker (overlaps capture)
    drain      k-way-merge the per-worker shards by timestamp
    sanitize   §3.2 tolerance rules against the carried per-worker state
    fold       carry-resumable ``fold_chunk``/``FoldCarry`` (``cmetric.py``)
               — the paper's Table-1 eBPF-map state, advanced batch-wise;
               backends registered in ``backends.py``
               (numpy | stream | vector | pallas)
    store      accumulated log: in-RAM ``EventStore`` or an append-only
               disk ``SpillStore`` (resident memory O(chunk_events))
        │
        ▼  at any time, without stopping the workload
    session.snapshot()  →  Detector (``detector.py``, fully vectorised
                           over the columnar SliceTable of ``slices.py``):
                           sample attachment, path merge, tag tables
        │
        ▼
    BottleneckReport → exporter registry (``exporters.py``:
        text | json | chrome | callback | watch) — ``session.export(fmt)``
        or live push via ``session.watch(callback, every=...)``

``session.result()`` quiesces and returns the final report — bit-equal on
the ``numpy`` backend to ``detect_offline`` over the frozen log, for any
drain/snapshot schedule.  ``Gapp``/``profile_log`` (``profiler.py``) are
deprecated thin wrappers kept for old call sites.

Multi-host: the :mod:`repro.fleet` package streams drained chunks over a
socket (``RemoteSink`` → ``IngestServer``, attached via
``session.export("remote", addr=...)``) and merges N host streams into
one session through ``FleetSource`` — same pipeline, reports carry host
provenance (``report.worker_hosts`` / per-host exporter lanes).

The offline dataflow (``detect_offline``) is the same pipeline driven
synchronously: EventLog → sanitize → CMetric backend → SliceTable →
detector → report; ``detect_offline(chunk_events=...)`` streams it through
the identical chunk fold in bounded memory.
"""
from repro.core.events import (ACTIVATE, DEACTIVATE, EventLog, EventRing,
                               EventStore, ShardedEventRing, sanitize_chunk,
                               synthetic_log, tolerance_keep)
from repro.core.slices import (CriticalBuffer, CriticalSlice, CriticalTable,
                               SliceTable)
from repro.core.backends import (available_backends, backends_with,
                                 backends_with_fold_chunk, get_backend,
                                 register_backend)
from repro.core.cmetric import (CMetricResult, FoldCarry, compute,
                                compute_numpy, compute_streaming,
                                compute_vectorized, fold_chunk)
from repro.core.tracer import (LockedTracer, StackRegistry, TagRegistry,
                               Tracer, WorkerHandle)
from repro.core.sampler import SampleBuffer, SamplingProbe, simulate_samples
from repro.core.detector import (BottleneckReport, PathProfile, build_report,
                                 detect, detect_offline, merge_table)
from repro.core.report import imbalance_stats, render_text, to_json
from repro.core.spill import SpillStore
from repro.core.exporters import (available_exporters, export, get_exporter,
                                  register_exporter)
from repro.core.session import (EventSource, LogSource, ProfileSession,
                                SpillSource, TracerSource)
from repro.core.profiler import Gapp, profile_log

__all__ = [
    "ACTIVATE", "DEACTIVATE", "EventLog", "EventRing", "EventStore",
    "ShardedEventRing", "sanitize_chunk", "synthetic_log", "tolerance_keep",
    "SliceTable", "CriticalTable", "CriticalBuffer", "CriticalSlice",
    "available_backends", "backends_with", "backends_with_fold_chunk",
    "get_backend", "register_backend",
    "CMetricResult", "FoldCarry", "compute", "compute_numpy",
    "compute_streaming", "compute_vectorized", "fold_chunk",
    "StackRegistry", "TagRegistry", "Tracer", "LockedTracer", "WorkerHandle",
    "SampleBuffer", "SamplingProbe", "simulate_samples",
    "BottleneckReport", "PathProfile", "build_report", "detect",
    "detect_offline", "merge_table", "imbalance_stats", "render_text",
    "to_json",
    "SpillStore", "available_exporters", "export", "get_exporter",
    "register_exporter",
    "ProfileSession", "EventSource", "TracerSource", "LogSource",
    "SpillSource",
    "Gapp", "profile_log",
]
from repro.core.wakers import (classify_report, classify_tag,  # noqa: E402
                               critical_wakers, waker_edges)

__all__ += ["classify_report", "classify_tag", "critical_wakers",
            "waker_edges"]
from repro.core.timeline import dump_chrome_trace, to_chrome_trace  # noqa: E402,F401

__all__ += ["dump_chrome_trace", "to_chrome_trace"]
