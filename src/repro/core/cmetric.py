"""Criticality Metric (CMetric) — the paper's §2/§4.1 algorithm.

Time is split into *switching intervals* ``T_i`` delimited by any worker
state-change event; every worker active during interval ``i`` earns
``T_i / n_i`` where ``n_i`` is the number of active workers.  A worker's
timeslice CMetric is recovered in O(1) per event with a running prefix
``global_cm`` and a per-worker snapshot ``local_cm`` (the paper's eBPF-map
trick)::

    global_cm        += (t - t_switch) / thread_count       # every event
    cm_hash[w]       += global_cm - local_cm[w]             # on switch-out
    local_cm[w]       = global_cm                           # on switch-in

Four implementations, equivalent up to float tolerance, registered in the
:mod:`repro.core.backends` registry:

* ``numpy``  — :func:`compute_numpy`, float64 oracle (reference for all).
* ``stream`` — :func:`compute_streaming`, paper-faithful event-at-a-time
  ``lax.scan`` maintaining exactly the eBPF-map state of Table 1.
* ``vector`` — :func:`compute_vectorized`, beyond-paper data-parallel
  formulation (cumsum + stable-sort pairing + segment-sum).  O(E log E)
  work but fully parallel.
* ``pallas`` — the vector pipeline with the interval fold swapped for the
  Pallas ``cmetric_fold`` kernel, fold + pairing + segment-sum fused into a
  single jitted call (no host round-trip between stages).

All backends emit a :class:`~repro.core.slices.SliceTable`;
:class:`CMetricResult` is a thin wrapper over it.

Each backend also registers a **carry-resumable chunk fold**:
``fold_chunk(carry, chunk) -> (carry, SliceTable)`` advances a
:class:`FoldCarry` — exactly the paper's Table-1 eBPF-map state — over one
batch of events.  Replaying *any* partition of a log reproduces the
whole-log result (bit-equal float64 for ``numpy``, float32 tolerance for
the device backends), which is what lets the live tracer maintain its
online state by batches and ``detect_offline(chunk_events=...)`` stream
unbounded logs in bounded memory.

Degenerate timeslices (``slice_cm == 0``) fall back to
``threads_av = max(n_at_exit, 1)`` — the instantaneous active count at
switch-out, including the exiting worker — in *every* backend (the numpy
oracle's semantics; the vector/pallas paths used to hardcode 1.0, which
could flip criticality between backends).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import backends as backends_lib
from repro.core.backends import register_backend
from repro.core.events import ACTIVATE, DEACTIVATE, EventLog
from repro.core.slices import CriticalTable, SliceTable


@dataclasses.dataclass
class CMetricResult:
    """Per-worker totals plus the per-timeslice table.

    ``table`` holds one row per completed timeslice (one per DEACTIVATE
    event), in absolute ns on the source log's clock; ``t0_ns`` is the log
    epoch so the legacy rebased-seconds views (``slice_start`` …) stay
    available as properties.  ``threads_av`` is the harmonic weighted
    average parallelism ``(end-start)/slice_cm`` (== n when parallelism is
    constant over the slice); the stack-trace trigger is
    ``threads_av < n_min`` (paper §4.2).
    """

    per_worker: np.ndarray        # float64[W] cumulative CMetric (cm_hash)
    table: SliceTable             # S rows, aligned columns (ns domain)
    t0_ns: int                    # log epoch for the seconds-domain views
    idle_time: float              # total time with zero active workers
    total_time: float             # t_last - t_first

    # -- legacy rebased-seconds views ---------------------------------------
    @property
    def slice_worker(self) -> np.ndarray:
        return self.table.worker

    @property
    def slice_start(self) -> np.ndarray:
        return (self.table.start_ns - self.t0_ns) * 1e-9

    @property
    def slice_end(self) -> np.ndarray:
        return (self.table.end_ns - self.t0_ns) * 1e-9

    @property
    def slice_cm(self) -> np.ndarray:
        return self.table.cm

    @property
    def slice_threads_av(self) -> np.ndarray:
        return self.table.threads_av

    @property
    def slice_stack(self) -> np.ndarray:
        return self.table.stack_id

    @property
    def num_slices(self) -> int:
        return len(self.table)

    def critical_mask(self, n_min: float) -> np.ndarray:
        return self.table.threads_av < n_min

    def critical_table(self, n_min: float) -> CriticalTable:
        return self.table.critical(n_min)


def _empty_result(num_workers: int) -> CMetricResult:
    return CMetricResult(np.zeros(num_workers), SliceTable.empty(), 0, 0.0,
                         0.0)


def _make_result(log: EventLog, per_worker, worker, start_s, end_s, cm,
                 threads_av, stack, n_at_exit, idle, total) -> CMetricResult:
    """Assemble a result from rebased-seconds slice columns (backend output
    domain), converting times back to the log's ns clock."""
    t0 = int(log.times[0]) if len(log) else 0
    table = SliceTable.from_arrays(
        worker=np.asarray(worker, np.int32),
        start_ns=t0 + np.round(np.asarray(start_s, np.float64)
                               * 1e9).astype(np.int64),
        end_ns=t0 + np.round(np.asarray(end_s, np.float64)
                             * 1e9).astype(np.int64),
        cm=np.asarray(cm, np.float64),
        threads_av=np.asarray(threads_av, np.float64),
        stack_id=np.asarray(stack, np.int32),
        n_at_exit=np.asarray(n_at_exit, np.int32),
    )
    return CMetricResult(per_worker=np.asarray(per_worker, np.float64),
                         table=table, t0_ns=t0, idle_time=float(idle),
                         total_time=float(total))


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------

def compute_numpy(log: EventLog) -> CMetricResult:
    """float64 reference implementation (event-at-a-time, like the kernel probe)."""
    e = len(log)
    if e == 0:
        return _empty_result(log.num_workers)
    t = log.slice_seconds()
    w = log.workers
    d = log.deltas
    gcm = 0.0
    idle = 0.0
    count = 0
    local = np.zeros(log.num_workers)
    start = np.zeros(log.num_workers)
    cm = np.zeros(log.num_workers)
    sw, ss, se, sc, sa, sk, sn = [], [], [], [], [], [], []
    t_prev = t[0]
    for i in range(e):
        dt = t[i] - t_prev
        if count > 0:
            gcm += dt / count
        else:
            idle += dt
        t_prev = t[i]
        wi = int(w[i])
        if d[i] == ACTIVATE:
            local[wi] = gcm
            start[wi] = t[i]
            count += 1
        else:
            slice_cm = gcm - local[wi]
            cm[wi] += slice_cm
            dur = t[i] - start[wi]
            sw.append(wi)
            ss.append(start[wi])
            se.append(t[i])
            sc.append(slice_cm)
            sa.append(dur / slice_cm if slice_cm > 0 else float(max(count, 1)))
            sk.append(int(log.stacks[i]))
            sn.append(count)                 # n_at_exit: before the decrement
            count -= 1
    return _make_result(log, cm, sw, ss, se, sc, sa, sk, sn, idle,
                        t[-1] - t[0])


# ---------------------------------------------------------------------------
# paper-faithful streaming scan (jax.lax.scan over events)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_workers",))
def _streaming_scan(times_s, workers, deltas, num_workers: int):
    """One scan step == one execution of the sched_switch probe function."""

    def step(carry, ev):
        gcm, idle, count, t_prev, local, start, cm = carry
        t, wi, d = ev
        dt = t - t_prev
        gcm = gcm + jnp.where(count > 0, dt / jnp.maximum(count, 1), 0.0)
        idle = idle + jnp.where(count > 0, 0.0, dt)
        is_in = d > 0
        # switch-in: snapshot local_cm; switch-out: emit timeslice record
        slice_cm = gcm - local[wi]
        dur = t - start[wi]
        local = jnp.where(is_in, local.at[wi].set(gcm), local)
        start = jnp.where(is_in, start.at[wi].set(t), start)
        cm = jnp.where(is_in, cm, cm.at[wi].add(slice_cm))
        count = count + jnp.where(is_in, 1, -1)
        threads_av = jnp.where(slice_cm > 0, dur / jnp.maximum(slice_cm, 1e-30),
                               jnp.maximum(count + 1, 1).astype(jnp.float32))
        out = (~is_in, wi, start[wi] * is_in + (t - dur) * (~is_in), t,
               slice_cm, threads_av, count + 1)
        return (gcm, idle, count, t, local, start, cm), out

    zero = jnp.zeros((num_workers,), jnp.float32)
    carry0 = (jnp.float32(0), jnp.float32(0), jnp.int32(0), times_s[0],
              zero, zero, zero)
    carry, outs = jax.lax.scan(step, carry0, (times_s, workers, deltas))
    gcm, idle, _, _, _, _, cm = carry
    return cm, idle, outs


def compute_streaming(log: EventLog) -> CMetricResult:
    """Paper-faithful streaming CMetric via ``lax.scan`` (float32 on device)."""
    e = len(log)
    if e == 0:
        return _empty_result(log.num_workers)
    t = jnp.asarray(log.slice_seconds(), jnp.float32)
    cm, idle, outs = _streaming_scan(t, jnp.asarray(log.workers),
                                     jnp.asarray(log.deltas, jnp.int32),
                                     log.num_workers)
    is_out, wi, s_start, s_end, s_cm, s_av, s_n = jax.tree.map(np.asarray,
                                                               outs)
    m = np.asarray(is_out)
    # slice start from the scan is reconstructed as end - dur for out events
    tn = np.asarray(t)
    return _make_result(log, cm, wi[m], s_start[m], s_end[m], s_cm[m],
                        s_av[m], log.stacks[m], s_n[m], idle, tn[-1] - tn[0])


# ---------------------------------------------------------------------------
# vectorised (beyond-paper) formulation
# ---------------------------------------------------------------------------

def _fold_interval_terms(times_s, deltas):
    """Interval lengths, active counts and the global_cm prefix.

    Returns (n, contrib, gcm) where ``n[i]``/``contrib[i]`` describe interval
    ``[t_i, t_{i+1})`` (length E-1) and ``gcm[e]`` is the value of global_cm
    when event ``e`` fires (length E).  This is the part the Pallas
    ``cmetric_fold`` kernel implements on-device.
    """
    dt = times_s[1:] - times_s[:-1]
    n = jnp.cumsum(deltas)[:-1]                      # active during interval i
    contrib = jnp.where(n > 0, dt / jnp.maximum(n, 1), 0.0)
    gcm = jnp.concatenate([jnp.zeros((1,), contrib.dtype), jnp.cumsum(contrib)])
    idle = jnp.sum(jnp.where(n > 0, 0.0, dt))
    return n, contrib, gcm, idle


def _pair_core(times_s, workers, deltas, gcm, idle, num_workers: int):
    """Pairing + aggregation stage shared by the vectorised and Pallas
    backends: ``gcm`` is the global_cm prefix (one entry per event).

    Traceable (un-jitted) so the Pallas backend can fuse it with the fold
    kernel inside one jit; :func:`compute_vectorized` wraps it in its own.
    """
    e = times_s.shape[0]
    # Stable grouping by worker: within a group events alternate IN/OUT, so
    # consecutive (even, odd) positions form a timeslice.
    perm = jnp.argsort(workers, stable=True)
    ws = workers[perm]
    idx = jnp.arange(e)
    boundary = jnp.concatenate([jnp.ones((1,), bool), ws[1:] != ws[:-1]])
    group_first = jax.lax.cummax(jnp.where(boundary, idx, 0))
    pos = idx - group_first
    is_out_pos = pos % 2 == 1
    prev_global = perm[jnp.maximum(idx - 1, 0)]      # matching ACTIVATE event
    out_global = perm
    slice_cm = gcm[out_global] - gcm[prev_global]
    s_start = times_s[prev_global]
    s_end = times_s[out_global]
    dur = s_end - s_start
    # active count at the out event, including the exiting worker (numpy
    # oracle semantics for the zero-CMetric fallback)
    n_exit = jnp.cumsum(deltas)[out_global] + 1
    threads_av = jnp.where(slice_cm > 0, dur / jnp.maximum(slice_cm, 1e-30),
                           jnp.maximum(n_exit, 1).astype(s_start.dtype))
    valid = is_out_pos
    per_worker = jax.ops.segment_sum(jnp.where(valid, slice_cm, 0.0), ws,
                                     num_segments=num_workers)
    return (per_worker, idle, valid, ws, s_start, s_end, slice_cm, threads_av,
            n_exit, out_global)


@functools.partial(jax.jit, static_argnames=("num_workers",))
def _vector_pipeline(times_s, workers, deltas, num_workers: int):
    _, _, gcm, idle = _fold_interval_terms(times_s, deltas)
    return _pair_core(times_s, workers, deltas, gcm, idle, num_workers)


def _result_from_pairing(log: EventLog, t, outs) -> CMetricResult:
    (per_worker, idle, valid, ws, s_start, s_end, s_cm, s_av, s_n,
     out_global) = outs
    valid = np.asarray(valid)
    out_global = np.asarray(out_global)[valid]
    order = np.argsort(out_global, kind="stable")    # restore time order
    sel = lambda x: np.asarray(x)[valid][order]
    tn = np.asarray(t)
    return _make_result(log, per_worker, sel(ws), sel(s_start), sel(s_end),
                        sel(s_cm), sel(s_av), log.stacks[out_global[order]],
                        sel(s_n), idle, tn[-1] - tn[0])


def drive_pairing(log: EventLog, pipeline) -> CMetricResult:
    """Shared host driver for pairing-based backends: move the log to device
    arrays, run one jitted ``pipeline(t, workers, deltas, num_workers=...)``
    returning :func:`_pair_core` outputs, and materialise the result table."""
    if len(log) == 0:
        return _empty_result(log.num_workers)
    t = jnp.asarray(log.slice_seconds(), jnp.float32)
    outs = pipeline(t, jnp.asarray(log.workers),
                    jnp.asarray(log.deltas, jnp.int32),
                    num_workers=log.num_workers)
    return _result_from_pairing(log, t, outs)


def compute_vectorized(log: EventLog) -> CMetricResult:
    """Data-parallel CMetric (sort + scans + segment-sum).  Same results as
    :func:`compute_numpy` up to float32 tolerance; the pairing core is shared
    with the Pallas fold backend (which swaps in its own gcm prefix)."""
    return drive_pairing(log, _vector_pipeline)


def _compute_pallas(log: EventLog) -> CMetricResult:
    # Lazy import: keeps jax.experimental.pallas out of plain-numpy users
    # and avoids a module-level import cycle with repro.kernels.
    from repro.kernels import ops
    return ops.compute_pallas(log)


# ---------------------------------------------------------------------------
# carry-resumable chunked fold
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FoldCarry:
    """The paper's Table-1 eBPF-map state, as the carry of a chunked fold.

    ``fold_chunk(carry, chunk)`` advances this state by one batch of events
    and emits the batch's completed timeslices; replaying any partition of a
    log through it reproduces the whole-log result — bit-equal to
    :func:`compute_numpy` for the float64 ``numpy`` chunk backend (every
    accumulation is kept strictly sequential: ``np.add.accumulate`` seeded
    with the carried scalar, ``np.add.at`` into the carried per-worker
    hash), within float32 tolerance for the device backends.

    Fields mirror the eBPF maps: ``global_cm`` (running Σ T_i/n_i), ``idle``
    (time with zero active workers), ``thread_count``, per-worker
    ``local_cm``/``slice_start`` snapshots taken at switch-in, the ``open``
    mask (which workers are mid-timeslice at the chunk boundary),
    ``cm_hash`` (cumulative per-worker CMetric), and the clock state
    ``t0_ns`` (stream epoch) / ``t_switch_s`` (rebased time of the previous
    event, the paper's ``t_switch``).
    """

    num_workers: int
    t0_ns: int | None = None
    t_last_ns: int | None = None
    t_switch_s: float = 0.0
    global_cm: float = 0.0
    idle: float = 0.0
    thread_count: int = 0
    local_cm: np.ndarray = None
    slice_start: np.ndarray = None
    open: np.ndarray = None
    cm_hash: np.ndarray = None
    events: int = 0
    slices: int = 0

    def __post_init__(self):
        w = self.num_workers
        if self.local_cm is None:
            self.local_cm = np.zeros(w)
        if self.slice_start is None:
            self.slice_start = np.zeros(w)
        if self.open is None:
            self.open = np.zeros(w, bool)
        if self.cm_hash is None:
            self.cm_hash = np.zeros(w)

    @classmethod
    def init(cls, num_workers: int) -> "FoldCarry":
        return cls(num_workers=num_workers)

    def ensure_workers(self, num_workers: int) -> None:
        """Grow the per-worker maps (workers may register mid-stream)."""
        w = self.num_workers
        if num_workers <= w:
            return
        pad = num_workers - w
        self.local_cm = np.concatenate([self.local_cm, np.zeros(pad)])
        self.slice_start = np.concatenate([self.slice_start, np.zeros(pad)])
        self.open = np.concatenate([self.open, np.zeros(pad, bool)])
        self.cm_hash = np.concatenate([self.cm_hash, np.zeros(pad)])
        self.num_workers = num_workers

    @property
    def total_time(self) -> float:
        return self.t_switch_s

    @property
    def per_worker(self) -> np.ndarray:
        return self.cm_hash

    def per_worker_padded(self, num_workers: int) -> np.ndarray:
        """A copy of ``cm_hash`` padded/truncated to ``num_workers`` — the
        per-worker CMetric view consumers read while workers may still be
        registering (the carry only grows at fold time)."""
        out = np.zeros(num_workers)
        n = min(num_workers, self.cm_hash.shape[0])
        out[:n] = self.cm_hash[:n]
        return out

    def state(self) -> dict:
        """Consistent copy of the aggregate state for incremental reports
        (what :meth:`ProfileSession.snapshot` reads mid-stream; take it
        under the fold lock so totals and per-worker rows agree)."""
        return {
            "per_worker": self.cm_hash.copy(),
            "idle_time": self.idle,
            "total_time": self.total_time,
            "events": self.events,
            "slices": self.slices,
        }


def _prefix_exact(carry: FoldCarry, contrib, idle_contrib):
    """Strictly sequential float64 prefix — bit-equal to the numpy oracle's
    ``gcm += dt / count`` loop (``np.add.accumulate`` is left-to-right)."""
    g = np.add.accumulate(np.concatenate(([carry.global_cm], contrib)))[1:]
    idle = np.add.accumulate(
        np.concatenate(([carry.idle], idle_contrib)))[-1]
    return g, float(idle)


def _prefix_f32_seq(carry: FoldCarry, contrib, idle_contrib):
    """Sequential float32 prefix (the streaming scan's arithmetic)."""
    g = np.add.accumulate(np.concatenate(
        ([carry.global_cm], contrib)).astype(np.float32))[1:]
    idle = np.add.accumulate(np.concatenate(
        ([carry.idle], idle_contrib)).astype(np.float32))[-1]
    return g.astype(np.float64), float(idle)


@jax.jit
def _cumsum_prefix_f32(g0, i0, contrib, idle_contrib):
    return g0 + jnp.cumsum(contrib), i0 + jnp.sum(idle_contrib)


def _prefix_vector(carry: FoldCarry, contrib, idle_contrib):
    """Data-parallel float32 prefix (jitted cumsum on device)."""
    g, idle = _cumsum_prefix_f32(jnp.float32(carry.global_cm),
                                 jnp.float32(carry.idle),
                                 jnp.asarray(contrib, jnp.float32),
                                 jnp.asarray(idle_contrib, jnp.float32))
    return np.asarray(g, np.float64), float(idle)


def _prefix_pallas(carry: FoldCarry, contrib, idle_contrib):
    # Lazy import as for _compute_pallas.
    from repro.kernels import ops
    return ops.fold_chunk_prefix(carry.global_cm, carry.idle, contrib,
                                 idle_contrib)


def _fold_chunk(carry: FoldCarry, log: EventLog, prefix) -> tuple[
        FoldCarry, SliceTable]:
    """Advance ``carry`` over one time-sorted, sanitized chunk.

    The chunk must be consistent with ``carry.open`` (use
    :func:`repro.core.events.sanitize_chunk` on dirty streams first) and
    start at or after ``carry.t_last_ns``.  Returns the same carry object,
    updated, plus one :class:`SliceTable` row per DEACTIVATE in the chunk
    (in event order, like every backend).
    """
    carry.ensure_workers(log.num_workers)
    e = len(log)
    if e == 0:
        return carry, SliceTable.empty()
    if carry.t0_ns is None:
        carry.t0_ns = int(log.times[0])
        carry.t_last_ns = carry.t0_ns      # first dt is 0, like the oracle
    t = (log.times - carry.t0_ns).astype(np.float64) * 1e-9
    w = log.workers
    d = log.deltas
    dt = np.empty(e, np.float64)
    dt[0] = t[0] - carry.t_switch_s
    dt[1:] = t[1:] - t[:-1]
    d64 = d.astype(np.int64)
    n_before = carry.thread_count + np.cumsum(d64) - d64
    pos_mask = n_before > 0
    contrib = np.where(pos_mask, dt / np.maximum(n_before, 1), 0.0)
    idle_contrib = np.where(pos_mask, 0.0, dt)
    g, idle_end = prefix(carry, contrib, idle_contrib)

    # -- pairing: each DEACTIVATE matches the previous event of its worker
    # group (alternation holds within a sanitized chunk) or the carry.
    idx = np.arange(e)
    order = np.argsort(w, kind="stable")
    ws = w[order]
    ds = d[order]
    firstg = np.concatenate([[True], ws[1:] != ws[:-1]])
    grp_first = np.maximum.accumulate(np.where(firstg, idx, 0))
    pos = idx - grp_first
    out_sorted = ds == DEACTIVATE
    out_global = order[out_sorted]
    has_prev = (pos > 0)[out_sorted]
    prev_global = order[np.maximum(idx - 1, 0)][out_sorted]
    w_out = ws[out_sorted]
    local = np.where(has_prev, g[prev_global], carry.local_cm[w_out])
    start_s = np.where(has_prev, t[prev_global],
                       carry.slice_start[w_out])
    slice_cm = g[out_global] - local
    end_s = t[out_global]
    dur = end_s - start_s
    n_exit = n_before[out_global]          # includes the exiting worker
    threads_av = np.where(
        slice_cm > 0, dur / np.where(slice_cm > 0, slice_cm, 1.0),
        np.maximum(n_exit, 1).astype(np.float64))

    # restore event (time) order, the order every backend emits slices in
    ord2 = np.argsort(out_global, kind="stable")
    w_out = w_out[ord2]
    out_eo = out_global[ord2]
    slice_cm = slice_cm[ord2]
    # sequential per-worker accumulation into the carried hash — the exact
    # order the oracle's ``cm[wi] += slice_cm`` runs in
    np.add.at(carry.cm_hash, w_out, slice_cm)
    table = SliceTable.from_arrays(
        worker=w_out,
        start_ns=carry.t0_ns + np.round(
            start_s[ord2] * 1e9).astype(np.int64),
        end_ns=carry.t0_ns + np.round(end_s[ord2] * 1e9).astype(np.int64),
        cm=slice_cm,
        threads_av=threads_av[ord2],
        stack_id=log.stacks[out_eo],
        n_at_exit=n_exit[ord2],
    )

    # -- carry update: per-worker last event decides the open snapshot
    lastg = np.concatenate([ws[1:] != ws[:-1], [True]])
    wl = ws[lastg]
    dl = ds[lastg]
    li = order[lastg]
    act = dl == ACTIVATE
    carry.local_cm[wl[act]] = g[li[act]]
    carry.slice_start[wl[act]] = t[li[act]]
    carry.open[wl] = act
    carry.thread_count += int(d64.sum())
    carry.global_cm = float(g[-1])
    carry.idle = idle_end
    carry.t_switch_s = float(t[-1])
    carry.t_last_ns = int(log.times[-1])
    carry.events += e
    carry.slices += int(len(table))
    return carry, table


def fold_chunk(carry: FoldCarry, log: EventLog,
               backend: str = "numpy") -> tuple[FoldCarry, SliceTable]:
    """Dispatch one chunk through the named backend's chunk fold."""
    return backends_lib.fold_chunk(carry, log, backend=backend)


def _make_fold_chunk(prefix):
    return functools.partial(_fold_chunk, prefix=prefix)


register_backend("numpy", compute_numpy,
                 capabilities={"oracle", "float64", "exact"},
                 fold_chunk=_make_fold_chunk(_prefix_exact))
register_backend("stream", compute_streaming,
                 capabilities={"device", "sequential", "paper-faithful"},
                 fold_chunk=_make_fold_chunk(_prefix_f32_seq))
register_backend("vector", compute_vectorized,
                 capabilities={"device", "parallel"},
                 fold_chunk=_make_fold_chunk(_prefix_vector))
register_backend("pallas", _compute_pallas,
                 capabilities={"device", "parallel", "fused", "tpu"},
                 fold_chunk=_make_fold_chunk(_prefix_pallas))


def compute(log: EventLog, backend: str = "numpy") -> CMetricResult:
    """Dispatch through the :mod:`repro.core.backends` registry."""
    return backends_lib.compute(log, backend=backend)
