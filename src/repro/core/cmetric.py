"""Criticality Metric (CMetric) — the paper's §2/§4.1 algorithm.

Time is split into *switching intervals* ``T_i`` delimited by any worker
state-change event; every worker active during interval ``i`` earns
``T_i / n_i`` where ``n_i`` is the number of active workers.  A worker's
timeslice CMetric is recovered in O(1) per event with a running prefix
``global_cm`` and a per-worker snapshot ``local_cm`` (the paper's eBPF-map
trick)::

    global_cm        += (t - t_switch) / thread_count       # every event
    cm_hash[w]       += global_cm - local_cm[w]             # on switch-out
    local_cm[w]       = global_cm                           # on switch-in

Three implementations, equivalent up to float tolerance:

* :func:`compute_numpy`    — float64 oracle (reference for everything else).
* :func:`compute_streaming`— paper-faithful event-at-a-time ``lax.scan``
  maintaining exactly the eBPF-map state of Table 1.
* :func:`compute_vectorized` — beyond-paper data-parallel formulation
  (cumsum + stable-sort pairing + segment-sum), which is what the Pallas
  fold kernel accelerates.  O(E log E) work but fully parallel.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.events import ACTIVATE, DEACTIVATE, EventLog


@dataclasses.dataclass
class CMetricResult:
    """Per-worker totals plus per-timeslice records.

    Slice arrays are aligned and length-S (one entry per completed timeslice,
    i.e. per DEACTIVATE event).  ``threads_av`` is the harmonic weighted
    average parallelism ``(end-start)/slice_cm`` (== n when parallelism is
    constant over the slice); the stack-trace trigger is
    ``threads_av < n_min`` (paper §4.2).
    """

    per_worker: np.ndarray        # float64[W] cumulative CMetric (cm_hash)
    slice_worker: np.ndarray      # int32[S]
    slice_start: np.ndarray       # float64[S] seconds (rebased)
    slice_end: np.ndarray         # float64[S]
    slice_cm: np.ndarray          # float64[S]
    slice_threads_av: np.ndarray  # float64[S]
    slice_stack: np.ndarray       # int32[S] interned call-path id (or -1)
    idle_time: float              # total time with zero active workers
    total_time: float             # t_last - t_first

    @property
    def num_slices(self) -> int:
        return int(self.slice_cm.shape[0])

    def critical_mask(self, n_min: float) -> np.ndarray:
        return self.slice_threads_av < n_min


def _empty_result(num_workers: int) -> CMetricResult:
    z = np.zeros((0,))
    return CMetricResult(np.zeros(num_workers), z.astype(np.int32), z, z, z, z,
                         z.astype(np.int32), 0.0, 0.0)


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------

def compute_numpy(log: EventLog) -> CMetricResult:
    """float64 reference implementation (event-at-a-time, like the kernel probe)."""
    e = len(log)
    if e == 0:
        return _empty_result(log.num_workers)
    t = log.slice_seconds()
    w = log.workers
    d = log.deltas
    gcm = 0.0
    idle = 0.0
    count = 0
    local = np.zeros(log.num_workers)
    start = np.zeros(log.num_workers)
    cm = np.zeros(log.num_workers)
    sw, ss, se, sc, sa, sk = [], [], [], [], [], []
    t_prev = t[0]
    for i in range(e):
        dt = t[i] - t_prev
        if count > 0:
            gcm += dt / count
        else:
            idle += dt
        t_prev = t[i]
        wi = int(w[i])
        if d[i] == ACTIVATE:
            local[wi] = gcm
            start[wi] = t[i]
            count += 1
        else:
            slice_cm = gcm - local[wi]
            cm[wi] += slice_cm
            dur = t[i] - start[wi]
            sw.append(wi)
            ss.append(start[wi])
            se.append(t[i])
            sc.append(slice_cm)
            sa.append(dur / slice_cm if slice_cm > 0 else float(max(count, 1)))
            sk.append(int(log.stacks[i]))
            count -= 1
    return CMetricResult(
        per_worker=cm,
        slice_worker=np.asarray(sw, np.int32),
        slice_start=np.asarray(ss),
        slice_end=np.asarray(se),
        slice_cm=np.asarray(sc),
        slice_threads_av=np.asarray(sa),
        slice_stack=np.asarray(sk, np.int32),
        idle_time=float(idle),
        total_time=float(t[-1] - t[0]),
    )


# ---------------------------------------------------------------------------
# paper-faithful streaming scan (jax.lax.scan over events)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_workers",))
def _streaming_scan(times_s, workers, deltas, num_workers: int):
    """One scan step == one execution of the sched_switch probe function."""

    def step(carry, ev):
        gcm, idle, count, t_prev, local, start, cm = carry
        t, wi, d = ev
        dt = t - t_prev
        gcm = gcm + jnp.where(count > 0, dt / jnp.maximum(count, 1), 0.0)
        idle = idle + jnp.where(count > 0, 0.0, dt)
        is_in = d > 0
        # switch-in: snapshot local_cm; switch-out: emit timeslice record
        slice_cm = gcm - local[wi]
        dur = t - start[wi]
        local = jnp.where(is_in, local.at[wi].set(gcm), local)
        start = jnp.where(is_in, start.at[wi].set(t), start)
        cm = jnp.where(is_in, cm, cm.at[wi].add(slice_cm))
        count = count + jnp.where(is_in, 1, -1)
        threads_av = jnp.where(slice_cm > 0, dur / jnp.maximum(slice_cm, 1e-30),
                               jnp.maximum(count + 1, 1).astype(jnp.float32))
        out = (~is_in, wi, start[wi] * is_in + (t - dur) * (~is_in), t,
               slice_cm, threads_av)
        return (gcm, idle, count, t, local, start, cm), out

    zero = jnp.zeros((num_workers,), jnp.float32)
    carry0 = (jnp.float32(0), jnp.float32(0), jnp.int32(0), times_s[0],
              zero, zero, zero)
    carry, outs = jax.lax.scan(step, carry0, (times_s, workers, deltas))
    gcm, idle, _, _, _, _, cm = carry
    return cm, idle, outs


def compute_streaming(log: EventLog) -> CMetricResult:
    """Paper-faithful streaming CMetric via ``lax.scan`` (float32 on device)."""
    e = len(log)
    if e == 0:
        return _empty_result(log.num_workers)
    t = jnp.asarray(log.slice_seconds(), jnp.float32)
    cm, idle, outs = _streaming_scan(t, jnp.asarray(log.workers),
                                     jnp.asarray(log.deltas, jnp.int32),
                                     log.num_workers)
    is_out, wi, s_start, s_end, s_cm, s_av = jax.tree.map(np.asarray, outs)
    m = np.asarray(is_out)
    # slice start from the scan is reconstructed as end - dur for out events
    return CMetricResult(
        per_worker=np.asarray(cm, np.float64),
        slice_worker=np.asarray(wi[m], np.int32),
        slice_start=np.asarray(s_start[m], np.float64),
        slice_end=np.asarray(s_end[m], np.float64),
        slice_cm=np.asarray(s_cm[m], np.float64),
        slice_threads_av=np.asarray(s_av[m], np.float64),
        slice_stack=log.stacks[m],
        idle_time=float(idle),
        total_time=float(np.asarray(t)[-1] - np.asarray(t)[0]),
    )


# ---------------------------------------------------------------------------
# vectorised (beyond-paper) formulation
# ---------------------------------------------------------------------------

def _fold_interval_terms(times_s, deltas):
    """Interval lengths, active counts and the global_cm prefix.

    Returns (n, contrib, gcm) where ``n[i]``/``contrib[i]`` describe interval
    ``[t_i, t_{i+1})`` (length E-1) and ``gcm[e]`` is the value of global_cm
    when event ``e`` fires (length E).  This is the part the Pallas
    ``cmetric_fold`` kernel implements on-device.
    """
    dt = times_s[1:] - times_s[:-1]
    n = jnp.cumsum(deltas)[:-1]                      # active during interval i
    contrib = jnp.where(n > 0, dt / jnp.maximum(n, 1), 0.0)
    gcm = jnp.concatenate([jnp.zeros((1,), contrib.dtype), jnp.cumsum(contrib)])
    idle = jnp.sum(jnp.where(n > 0, 0.0, dt))
    return n, contrib, gcm, idle


@functools.partial(jax.jit, static_argnames=("num_workers",))
def _pair_and_aggregate(times_s, workers, deltas, gcm, idle,
                        num_workers: int):
    """Pairing + aggregation stage shared by the vectorised and Pallas
    backends: ``gcm`` is the global_cm prefix (one entry per event)."""
    e = times_s.shape[0]
    # Stable grouping by worker: within a group events alternate IN/OUT, so
    # consecutive (even, odd) positions form a timeslice.
    perm = jnp.argsort(workers, stable=True)
    ws = workers[perm]
    idx = jnp.arange(e)
    boundary = jnp.concatenate([jnp.ones((1,), bool), ws[1:] != ws[:-1]])
    group_first = jax.lax.cummax(jnp.where(boundary, idx, 0))
    pos = idx - group_first
    is_out_pos = pos % 2 == 1
    prev_global = perm[jnp.maximum(idx - 1, 0)]      # matching ACTIVATE event
    out_global = perm
    slice_cm = gcm[out_global] - gcm[prev_global]
    s_start = times_s[prev_global]
    s_end = times_s[out_global]
    dur = s_end - s_start
    threads_av = jnp.where(slice_cm > 0, dur / jnp.maximum(slice_cm, 1e-30), 1.0)
    valid = is_out_pos
    per_worker = jax.ops.segment_sum(jnp.where(valid, slice_cm, 0.0), ws,
                                     num_segments=num_workers)
    return (per_worker, idle, valid, ws, s_start, s_end, slice_cm, threads_av,
            out_global)


def _result_from_pairing(log: EventLog, t, outs) -> CMetricResult:
    (per_worker, idle, valid, ws, s_start, s_end, s_cm, s_av, out_global) = outs
    valid = np.asarray(valid)
    out_global = np.asarray(out_global)[valid]
    order = np.argsort(out_global, kind="stable")    # restore time order
    sel = lambda x: np.asarray(x)[valid][order]
    return CMetricResult(
        per_worker=np.asarray(per_worker, np.float64),
        slice_worker=sel(ws).astype(np.int32),
        slice_start=sel(s_start).astype(np.float64),
        slice_end=sel(s_end).astype(np.float64),
        slice_cm=sel(s_cm).astype(np.float64),
        slice_threads_av=sel(s_av).astype(np.float64),
        slice_stack=log.stacks[out_global[order]],
        idle_time=float(idle),
        total_time=float(np.asarray(t)[-1] - np.asarray(t)[0]),
    )


def compute_vectorized(log: EventLog) -> CMetricResult:
    """Data-parallel CMetric (sort + scans + segment-sum).  Same results as
    :func:`compute_numpy` up to float32 tolerance; this host-side driver is
    also reused by the Pallas fold backend (which swaps in its own gcm)."""
    e = len(log)
    if e == 0:
        return _empty_result(log.num_workers)
    t = jnp.asarray(log.slice_seconds(), jnp.float32)
    deltas = jnp.asarray(log.deltas, jnp.int32)
    _, _, gcm, idle = _fold_interval_terms(t, deltas)
    outs = _pair_and_aggregate(t, jnp.asarray(log.workers), deltas, gcm, idle,
                               log.num_workers)
    return _result_from_pairing(log, t, outs)


_BACKENDS = {
    "numpy": compute_numpy,
    "stream": compute_streaming,
    "vector": compute_vectorized,
}


def compute(log: EventLog, backend: str = "numpy") -> CMetricResult:
    if backend == "pallas":                      # lazy import to avoid cycles
        from repro.kernels import ops
        return ops.compute_pallas(log)
    return _BACKENDS[backend](log)
