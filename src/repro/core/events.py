"""Event model for the GAPP profiler — capture is sharded, analysis is batched.

The unit of observation is a *state-change event* of a logical worker:

    ACTIVATE   (+1)  — the worker becomes busy (paper: switched in / woken up)
    DEACTIVATE (-1)  — the worker becomes idle (paper: switched out, blocked)

Two capture paths:

* :class:`ShardedEventRing` — the live hot path.  Every worker owns one
  shard and appends ``(t, meta)`` to it with **no cross-worker lock**; the
  software analogue of the paper's per-CPU eBPF buffers.  ``meta`` is the
  tag id for ACTIVATE and the captured call-stack (a cons chain, or
  ``None``) for DEACTIVATE, so the probe body never builds numpy rows or
  interns stacks — all decoding is deferred to :meth:`ShardedEventRing.drain`,
  which pops published events from every shard, decodes them columnar and
  k-way-merges them by timestamp in one vectorised argsort.  Publication
  order (timestamp first, meta last; readers snapshot ``len(metas)``) means
  a concurrent drain can only observe fully-published events — no torn rows.
* :class:`EventRing` — the legacy single-array ring, kept for external
  writers that want a locked multi-producer buffer.  Its append now stores
  the whole row *inside* the critical section (the seed reserved the slot
  under the lock but wrote the row after release, so a concurrent
  ``freeze()`` could sort half-written events).

Both overflow by dropping *new* events and counting them, mirroring BPF
ring-buffer drop semantics.

Finished streams are :class:`EventLog` struct-of-arrays (monotonic ns
int64 times) so the CMetric fold can run vectorised in numpy / JAX /
Pallas; :class:`EventStore` is the growable columnar accumulator the
tracer folds drained batches into.  :func:`sanitize_chunk` applies the
live tracer's §3.2 tolerance rules to a chunk given the carried per-worker
active state, so arbitrarily long dirty logs can be cleaned chunk by chunk
with results identical to whole-log :meth:`EventLog.sanitize`.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
from collections import deque

import numpy as np

ACTIVATE = 1
DEACTIVATE = -1

# Sentinel ids
NO_TAG = -1
NO_STACK = -1


@dataclasses.dataclass
class EventLog:
    """A finished, time-sorted event log.

    Attributes:
      times:   int64[E] monotonic timestamps (ns)
      workers: int32[E] logical worker ids (dense, 0..num_workers-1)
      deltas:  int8[E]  +1 activate / -1 deactivate
      tags:    int32[E] current top-of-stack tag id at the event (NO_TAG if none)
      stacks:  int32[E] interned call-path id recorded at DEACTIVATE (NO_STACK
               otherwise).  The call path is the worker's tag stack, truncated
               to the top ``M`` frames (paper §4.2); it is interned only when
               the finished timeslice was critical, so most entries are
               NO_STACK by design.
      num_workers: total number of registered workers (paper: total_count)
    """

    times: np.ndarray
    workers: np.ndarray
    deltas: np.ndarray
    tags: np.ndarray
    stacks: np.ndarray
    num_workers: int

    def __len__(self) -> int:
        return int(self.times.shape[0])

    def validate(self) -> None:
        if len(self) == 0:
            return
        if np.any(np.diff(self.times) < 0):
            raise ValueError("event log is not time sorted")
        if not np.all(np.abs(self.deltas) == 1):
            raise ValueError("deltas must be +1/-1")
        # A worker must alternate ACTIVATE/DEACTIVATE.
        for w in range(self.num_workers):
            d = self.deltas[self.workers == w]
            if d.size and (d[0] != ACTIVATE or np.any(d[1:] == d[:-1])):
                raise ValueError(f"worker {w} events do not alternate")

    def slice_seconds(self) -> np.ndarray:
        """Times rebased to t0 in float64 seconds (device-friendly)."""
        if len(self) == 0:
            return np.zeros((0,), np.float64)
        return (self.times - self.times[0]).astype(np.float64) * 1e-9

    def chunk(self, lo: int, hi: int) -> "EventLog":
        """Zero-copy view of rows ``[lo, hi)`` (for the chunked fold; the
        carry keeps the stream epoch, so chunks are never rebased to their
        own first event)."""
        return EventLog(self.times[lo:hi], self.workers[lo:hi],
                        self.deltas[lo:hi], self.tags[lo:hi],
                        self.stacks[lo:hi], self.num_workers)

    def is_well_formed(self, active: np.ndarray | None = None) -> bool:
        """True iff every worker's events alternate correctly given the
        per-worker ``active`` entry state (all-idle by default), checked
        vectorised — what :meth:`validate` enforces for fresh logs."""
        if len(self) == 0:
            return True
        order = np.argsort(self.workers, kind="stable")
        w = self.workers[order]
        d = self.deltas[order]
        first = np.concatenate([[True], w[1:] != w[:-1]])
        if active is None:
            first_ok = d[first] == ACTIVATE
        else:
            first_ok = (d[first] == ACTIVATE) != active[w[first]]
        return bool(np.all(first_ok)
                    and not np.any((d[1:] == d[:-1]) & (w[1:] == w[:-1])))

    def sanitize(self) -> "EventLog":
        """Apply the live tracer's tolerance rules (paper §3.2) offline:
        drop an ACTIVATE of an already-active worker and a DEACTIVATE of an
        inactive worker.  External/raw streams can carry both (spurious
        wake-ups, truncated captures); the offline pairing stage assumes
        alternation, so dirty logs must pass through here (``detect_offline``
        does it automatically).  Returns ``self`` when already well-formed.
        """
        if self.is_well_formed():
            return self
        clean, _, _ = sanitize_chunk(self,
                                     np.zeros(self.num_workers, bool))
        return clean


def tolerance_keep(workers: np.ndarray, deltas: np.ndarray,
                   active: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised §3.2 greedy filter with carried state.

    Per worker, the tracer keeps the subsequence that alternates correctly
    starting from its current ``active`` flag, chosen greedily — which for a
    ±1 stream equals collapsing runs of equal deltas to their first event
    and then dropping a leading survivor that does not toggle the carried
    state (an ACTIVATE while active / a DEACTIVATE while idle): runs
    alternate in value by construction, so the collapsed sequence already
    alternates, and skipping a mismatched initial run is exactly dropping
    its first survivor.

    Returns ``(keep_mask, active_out)``; ``active`` is not modified.
    """
    e = len(workers)
    if e == 0:
        return np.zeros(0, bool), active.copy()
    order = np.argsort(workers, kind="stable")
    w = workers[order]
    d = deltas[order]
    first = np.concatenate([[True], w[1:] != w[:-1]])
    run_start = np.concatenate([[True], d[1:] != d[:-1]]) | first
    mismatch = (d == ACTIVATE) == active[w]
    keep_sorted = run_start & ~(first & mismatch)
    keep = np.zeros(e, bool)
    keep[order] = keep_sorted
    # state after the chunk: the last *kept* delta per worker decides
    active_out = active.copy()
    kept_idx = np.flatnonzero(keep_sorted)
    if kept_idx.size:
        wk = w[kept_idx]
        dk = d[kept_idx]
        last = np.concatenate([wk[1:] != wk[:-1], [True]])
        active_out[wk[last]] = dk[last] == ACTIVATE
    return keep, active_out


def sanitize_chunk(
    log: EventLog, active: np.ndarray,
) -> tuple[EventLog, np.ndarray, np.ndarray]:
    """Chunk-resumable :meth:`EventLog.sanitize`.

    ``active`` is the per-worker open state carried from previous chunks
    (all-False for a fresh stream).  Returns ``(clean_chunk, active_out,
    keep_mask)``; folding a stream chunk by chunk through here keeps exactly
    the same events as whole-log ``sanitize`` — the greedy filter is
    sequential per worker, so its decisions cannot depend on where the
    stream is cut.
    """
    keep, active_out = tolerance_keep(log.workers, log.deltas, active)
    if keep.all():
        return log, active_out, keep
    clean = EventLog(log.times[keep], log.workers[keep], log.deltas[keep],
                     log.tags[keep], log.stacks[keep], log.num_workers)
    return clean, active_out, keep


class EventRing:
    """Pre-allocated locked ring buffer for events (multi-producer path).

    Append is a short critical section that both reserves the slot *and*
    stores the row — the seed released the lock between the two, so a
    concurrent ``freeze()`` could sort/copy partially-written rows.
    Overflow drops the new event and counts it (BPF ringbuf drop
    semantics).  The live tracer no longer uses this class (it captures
    into a :class:`ShardedEventRing`); it remains for external locked
    multi-producer use and as the torn-row regression target.
    """

    def __init__(self, capacity: int = 1 << 20):
        self.capacity = int(capacity)
        self.times = np.zeros(self.capacity, np.int64)      # guarded-by: self._lock
        self.workers = np.zeros(self.capacity, np.int32)    # guarded-by: self._lock
        self.deltas = np.zeros(self.capacity, np.int8)      # guarded-by: self._lock
        self.tags = np.full(self.capacity, NO_TAG, np.int32)      # guarded-by: self._lock
        self.stacks = np.full(self.capacity, NO_STACK, np.int32)  # guarded-by: self._lock
        self.head = 0                                       # guarded-by: self._lock
        self.dropped = 0                                    # guarded-by: self._lock
        self._lock = threading.Lock()

    def append(self, t: int, worker: int, delta: int, tag: int = NO_TAG,
               stack: int = NO_STACK) -> None:
        with self._lock:
            i = self.head
            if i >= self.capacity:
                self.dropped += 1
                return
            # the row must be fully published before the slot becomes
            # visible to freeze(): store under the same lock, bump head last
            self.times[i] = t
            self.workers[i] = worker
            self.deltas[i] = delta
            self.tags[i] = tag
            self.stacks[i] = stack
            self.head = i + 1  # publishes: self.times, self.workers, self.deltas, self.tags, self.stacks

    def freeze(self, num_workers: int) -> EventLog:
        with self._lock:
            n = min(self.head, self.capacity)
        order = np.argsort(self.times[:n], kind="stable")
        return EventLog(
            times=self.times[:n][order].copy(),
            workers=self.workers[:n][order].copy(),
            deltas=self.deltas[:n][order].copy(),
            tags=self.tags[:n][order].copy(),
            stacks=self.stacks[:n][order].copy(),
            num_workers=num_workers,
        )


class EventShard:
    """One worker's private capture buffer (single writer, lock-free).

    The hot path appends the timestamp to ``times`` and then the meta to
    ``metas``; a drain snapshots ``len(metas)`` and pops that many rows
    from both ends — because the meta is published last, every snapshotted
    row is complete.  ``meta`` encoding:

      int                       ACTIVATE, value = tag id
      tuple ``(tid, parent)``   DEACTIVATE, value = captured tag stack as a
                                cons chain (head = top of stack / callee)
      None                      DEACTIVATE with an empty tag stack
    """

    __slots__ = ("wid", "times", "metas", "capacity", "dropped",
                 "open_after_drain", "drained")

    def __init__(self, wid: int, capacity: int):
        self.wid = wid
        self.capacity = int(capacity)
        self.times: deque = deque()
        self.metas: deque = deque()
        self.dropped = 0
        self.open_after_drain = False
        self.drained = 0

    def __len__(self) -> int:
        return len(self.metas)

    @property
    def is_open(self) -> bool:
        """Best-effort active flag: the type of the most recent published
        meta (int == ACTIVATE).  Lock-free — deque end peeks are atomic."""
        try:
            return type(self.metas[-1]) is int
        except IndexError:
            return self.open_after_drain

    def last_time(self) -> int | None:
        try:
            return self.times[-1]
        except IndexError:
            return None


@dataclasses.dataclass
class DrainedChunk:
    """One merged, time-sorted batch popped from all shards.

    ``aux`` is an object array aligned with the rows: the captured cons
    stack for DEACTIVATE events (or ``None``), ``None`` for ACTIVATE —
    consumed by the tracer to intern call paths for critical slices only.
    """

    times: np.ndarray     # int64[E]
    workers: np.ndarray   # int32[E]
    deltas: np.ndarray    # int8[E]
    tags: np.ndarray      # int32[E]
    aux: np.ndarray       # object[E]

    def __len__(self) -> int:
        return int(self.times.shape[0])


class ShardedEventRing:
    """Per-worker sharded capture buffers + vectorised k-way drain.

    The hot path is shard-local: no cross-worker lock, no numpy row
    construction, no stack interning — just two deque appends (see
    :class:`EventShard`).  ``drain()`` (single consumer; the tracer calls
    it under its fold lock) pops all published rows from every shard,
    decodes metas columnar, and merges the shards by timestamp with one
    stable argsort — ties break by worker id, deterministically.

    Capacity is per shard.  A full shard drops new events and counts them
    per shard (surfaced via :attr:`dropped`); the tracer's append slow path
    gets a chance to trigger a flush first via ``on_highwater``.
    """

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = int(capacity)
        self.shards: list[EventShard] = []
        self.on_highwater = None    # optional () -> None flush hook

    def add_shard(self) -> EventShard:
        sh = EventShard(len(self.shards), self.capacity)
        self.shards.append(sh)
        return sh

    # -- stats ---------------------------------------------------------------
    @property
    def dropped(self) -> int:
        return sum(sh.dropped for sh in self.shards)

    def dropped_per_shard(self) -> list[int]:
        return [sh.dropped for sh in self.shards]

    def pending(self) -> int:
        """Published-but-undrained events across all shards."""
        return sum(len(sh) for sh in self.shards)

    def total_events(self) -> int:
        """Events accepted so far (drained + pending, excluding drops)."""
        return sum(sh.drained + len(sh) for sh in self.shards)

    def approx_nbytes(self) -> int:
        # deque of (int, PyObject*) rows: ~64B per pending event + slack
        return sum(64 * len(sh) + 64 * sh.capacity // 8 for sh in self.shards)

    # -- consumer side -------------------------------------------------------
    def drain(self, limit_per_shard: int | None = None) -> DrainedChunk | None:
        """Pop published events from every shard and merge by time.

        Single-consumer; safe against concurrent appends (producers only
        touch the right end of their own deques, we only pop the left of a
        snapshotted prefix).  Returns ``None`` when nothing is pending.

        ``limit_per_shard`` caps the decode work of one drain (the
        per-shard decode budget): at most that many rows are popped per
        shard, oldest first, leaving the rest pending for the next drain.
        When the cap truncates a shard, every shard's take is additionally
        trimmed to the *time frontier* — the earliest last-popped timestamp
        among truncated shards — and rows beyond it are pushed back, so a
        capped drain never interleaves one shard's future with another's
        past (skewed shard rates would otherwise hit the cross-flush
        monotonic clamp and distort durations).
        """
        popped: list[tuple[EventShard, list, list]] = []
        frontier: int | None = None
        for sh in self.shards:
            m = len(sh.metas)           # publication snapshot
            truncated = limit_per_shard is not None and m > limit_per_shard
            if truncated:
                m = limit_per_shard
            if m == 0:
                continue
            # popleft() is atomic per call and touches the opposite end from
            # the producer; iterating the deque (islice/list) instead would
            # raise "deque mutated during iteration" under concurrent
            # appends.
            tpop = sh.times.popleft
            mpop = sh.metas.popleft
            ts = [tpop() for _ in range(m)]
            ms = [mpop() for _ in range(m)]
            popped.append((sh, ts, ms))
            if truncated and (frontier is None or ts[-1] < frontier):
                frontier = ts[-1]
        parts_t, parts_w, parts_d, parts_g, parts_a = [], [], [], [], []
        for sh, ts, ms in popped:
            if frontier is not None and ts[-1] > frontier:
                # keep the <= frontier prefix, push the tail back unpopped
                # (appendleft touches the consumer's end only — producers
                # append on the right)
                cut = bisect.bisect_right(ts, frontier)
                for t, mv in zip(reversed(ts[cut:]), reversed(ms[cut:])):
                    sh.metas.appendleft(mv)
                    sh.times.appendleft(t)
                ts = ts[:cut]
                ms = ms[:cut]
            m = len(ts)
            if m == 0:
                continue
            sh.drained += m
            deltas = np.empty(m, np.int8)
            tags = np.empty(m, np.int32)
            aux = np.empty(m, object)
            for i, mv in enumerate(ms):
                if type(mv) is int:
                    deltas[i] = ACTIVATE
                    tags[i] = mv
                else:                    # cons chain or None
                    deltas[i] = DEACTIVATE
                    tags[i] = mv[0] if mv is not None else NO_TAG
                    aux[i] = mv
            sh.open_after_drain = type(ms[-1]) is int
            parts_t.append(np.fromiter(ts, np.int64, m))
            parts_w.append(np.full(m, sh.wid, np.int32))
            parts_d.append(deltas)
            parts_g.append(tags)
            parts_a.append(aux)
        if not parts_t:
            return None
        times = np.concatenate(parts_t)
        workers = np.concatenate(parts_w)
        deltas = np.concatenate(parts_d)
        # Merge order: time, then DEACTIVATE before ACTIVATE, then worker.
        # Shards don't record cross-worker arrival order, so timestamp ties
        # need a deterministic rule; switch-out-first matches the scheduler
        # semantics (a slot is freed before another worker takes it at the
        # same instant) and keeps n_at_exit consistent with serial replay.
        order = np.lexsort((workers, deltas, times))
        return DrainedChunk(
            times=times[order],
            workers=workers[order],
            deltas=deltas[order],
            tags=np.concatenate(parts_g)[order],
            aux=np.concatenate(parts_a)[order],
        )


class EventStore:
    """Growable columnar accumulator of folded events (the frozen log).

    The tracer appends each drained+sanitized chunk here after folding it;
    chunks arrive time-sorted and boundary-clamped, so ``freeze()`` is a
    copy of the filled prefix with no re-sort.  Doubling numpy arrays, like
    :class:`~repro.core.slices.CriticalBuffer`.

    This is the all-RAM store; the tracer accepts any object with this
    interface via ``Tracer(store=...)`` — in particular
    :class:`~repro.core.spill.SpillStore`, which pages full blocks to an
    append-only file so ``resident_rows``/``resident_nbytes`` stay bounded
    no matter how long the capture runs (for this in-RAM store they simply
    equal the total).
    """

    _DTYPES = (np.int64, np.int32, np.int8, np.int32, np.int32)

    def __init__(self, capacity: int = 4096):
        self._cap = max(int(capacity), 1)
        self._cols = [np.zeros(self._cap, dt) for dt in self._DTYPES]
        self._len = 0

    def __len__(self) -> int:
        return self._len

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self._cols)

    @property
    def resident_rows(self) -> int:
        """Rows held in RAM (== all rows: this store never spills)."""
        return self._len

    @property
    def resident_nbytes(self) -> int:
        return self.nbytes

    def spill(self) -> None:
        """No-op for the in-RAM store (interface parity with SpillStore)."""

    def close(self) -> None:
        """No-op for the in-RAM store (interface parity with SpillStore)."""

    def _reserve(self, extra: int) -> None:
        need = self._len + extra
        if need <= self._cap:
            return
        while self._cap < need:
            self._cap *= 2
        self._cols = [np.concatenate([c, np.zeros(self._cap - len(c),
                                                  c.dtype)])
                      for c in self._cols]

    def append_columns(self, times, workers, deltas, tags, stacks) -> None:
        e = len(times)
        if e == 0:
            return
        self._reserve(e)
        lo = self._len
        for col, arr in zip(self._cols, (times, workers, deltas, tags,
                                         stacks)):
            col[lo:lo + e] = arr
        self._len = lo + e

    def freeze(self, num_workers: int) -> EventLog:
        n = self._len
        t, w, d, g, s = (c[:n].copy() for c in self._cols)
        return EventLog(times=t, workers=w, deltas=d, tags=g, stacks=s,
                        num_workers=num_workers)


def synthetic_log(
    rng: np.random.Generator,
    num_workers: int,
    slices_per_worker: int,
    busy_ns=(10_000, 1_000_000),
    idle_ns=(1_000, 500_000),
    skew: np.ndarray | None = None,
) -> EventLog:
    """Generate a well-formed random log (used by tests/benchmarks).

    ``skew`` multiplies per-worker busy durations: a straggler has skew > 1.
    """
    times, workers, deltas = [], [], []
    skew = np.ones(num_workers) if skew is None else np.asarray(skew, np.float64)
    for w in range(num_workers):
        t = int(rng.integers(0, idle_ns[1]))
        for _ in range(slices_per_worker):
            busy = int(rng.integers(*busy_ns) * skew[w])
            times += [t, t + busy]
            workers += [w, w]
            deltas += [ACTIVATE, DEACTIVATE]
            t += busy + int(rng.integers(*idle_ns))
    order = np.argsort(np.asarray(times, np.int64), kind="stable")
    e = len(times)
    return EventLog(
        times=np.asarray(times, np.int64)[order],
        workers=np.asarray(workers, np.int32)[order],
        deltas=np.asarray(deltas, np.int8)[order],
        tags=np.full(e, NO_TAG, np.int32),
        stacks=np.full(e, NO_STACK, np.int32),
        num_workers=num_workers,
    )
