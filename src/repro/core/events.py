"""Event model for the GAPP profiler.

The unit of observation is a *state-change event* of a logical worker:

    ACTIVATE   (+1)  — the worker becomes busy (paper: switched in / woken up)
    DEACTIVATE (-1)  — the worker becomes idle (paper: switched out, blocked)

Events are stored struct-of-arrays (times are monotonic ns int64) so the
CMetric fold can run vectorised in numpy / JAX / Pallas without any Python
object overhead — the software analogue of the paper's in-kernel eBPF maps.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

ACTIVATE = 1
DEACTIVATE = -1

# Sentinel ids
NO_TAG = -1
NO_STACK = -1


@dataclasses.dataclass
class EventLog:
    """A finished, time-sorted event log.

    Attributes:
      times:   int64[E] monotonic timestamps (ns)
      workers: int32[E] logical worker ids (dense, 0..num_workers-1)
      deltas:  int8[E]  +1 activate / -1 deactivate
      tags:    int32[E] current top-of-stack tag id at the event (NO_TAG if none)
      stacks:  int32[E] interned call-path id recorded at DEACTIVATE (NO_STACK
               otherwise).  The call path is the worker's tag stack, truncated
               to the top ``M`` frames (paper §4.2).
      num_workers: total number of registered workers (paper: total_count)
    """

    times: np.ndarray
    workers: np.ndarray
    deltas: np.ndarray
    tags: np.ndarray
    stacks: np.ndarray
    num_workers: int

    def __len__(self) -> int:
        return int(self.times.shape[0])

    def validate(self) -> None:
        if len(self) == 0:
            return
        if np.any(np.diff(self.times) < 0):
            raise ValueError("event log is not time sorted")
        if not np.all(np.abs(self.deltas) == 1):
            raise ValueError("deltas must be +1/-1")
        # A worker must alternate ACTIVATE/DEACTIVATE.
        for w in range(self.num_workers):
            d = self.deltas[self.workers == w]
            if d.size and (d[0] != ACTIVATE or np.any(d[1:] == d[:-1])):
                raise ValueError(f"worker {w} events do not alternate")

    def slice_seconds(self) -> np.ndarray:
        """Times rebased to t0 in float64 seconds (device-friendly)."""
        if len(self) == 0:
            return np.zeros((0,), np.float64)
        return (self.times - self.times[0]).astype(np.float64) * 1e-9

    def is_well_formed(self) -> bool:
        """True iff every worker's events alternate starting with ACTIVATE
        (what :meth:`validate` enforces), checked vectorised."""
        if len(self) == 0:
            return True
        order = np.argsort(self.workers, kind="stable")
        w = self.workers[order]
        d = self.deltas[order]
        first = np.concatenate([[True], w[1:] != w[:-1]])
        return bool(np.all(d[first] == ACTIVATE)
                    and not np.any((d[1:] == d[:-1]) & (w[1:] == w[:-1])))

    def sanitize(self) -> "EventLog":
        """Apply the live tracer's tolerance rules (paper §3.2) offline:
        drop an ACTIVATE of an already-active worker and a DEACTIVATE of an
        inactive worker.  External/raw streams can carry both (spurious
        wake-ups, truncated captures); the offline pairing stage assumes
        alternation, so dirty logs must pass through here (``detect_offline``
        does it automatically).  Returns ``self`` when already well-formed.
        """
        if self.is_well_formed():
            return self
        # Vectorised greedy filter.  Per worker, the tracer's rules keep the
        # subsequence that alternates starting with ACTIVATE, chosen
        # greedily — which for a ±1 stream equals collapsing runs of equal
        # deltas to their first event and then dropping a leading
        # DEACTIVATE: runs alternate in value by construction, so the
        # collapsed sequence already alternates, and skipping an initial
        # all-DEACTIVATE run is exactly dropping its first survivor.
        order = np.argsort(self.workers, kind="stable")
        w = self.workers[order]
        d = self.deltas[order]
        first = np.concatenate([[True], w[1:] != w[:-1]])
        run_start = np.concatenate([[True], d[1:] != d[:-1]]) | first
        keep_sorted = run_start & ~(first & (d == DEACTIVATE))
        keep = np.zeros(len(self), bool)
        keep[order] = keep_sorted
        return EventLog(self.times[keep], self.workers[keep],
                        self.deltas[keep], self.tags[keep], self.stacks[keep],
                        self.num_workers)


class EventRing:
    """Pre-allocated ring buffer for events (paper's eBPF ring buffer).

    Append is O(1) into numpy arrays; a short critical section keeps it safe
    for multi-threaded producers (host threads are real threads here).
    Overflow wraps and is counted, mirroring BPF ringbuf drop semantics.
    """

    def __init__(self, capacity: int = 1 << 20):
        self.capacity = int(capacity)
        self.times = np.zeros(self.capacity, np.int64)
        self.workers = np.zeros(self.capacity, np.int32)
        self.deltas = np.zeros(self.capacity, np.int8)
        self.tags = np.full(self.capacity, NO_TAG, np.int32)
        self.stacks = np.full(self.capacity, NO_STACK, np.int32)
        self.head = 0
        self.dropped = 0
        self._lock = threading.Lock()

    def append(self, t: int, worker: int, delta: int, tag: int = NO_TAG,
               stack: int = NO_STACK) -> None:
        with self._lock:
            i = self.head
            if i >= self.capacity:
                self.dropped += 1
                return
            self.head = i + 1
        self.times[i] = t
        self.workers[i] = worker
        self.deltas[i] = delta
        self.tags[i] = tag
        self.stacks[i] = stack

    def freeze(self, num_workers: int) -> EventLog:
        n = min(self.head, self.capacity)
        order = np.argsort(self.times[:n], kind="stable")
        return EventLog(
            times=self.times[:n][order].copy(),
            workers=self.workers[:n][order].copy(),
            deltas=self.deltas[:n][order].copy(),
            tags=self.tags[:n][order].copy(),
            stacks=self.stacks[:n][order].copy(),
            num_workers=num_workers,
        )


def synthetic_log(
    rng: np.random.Generator,
    num_workers: int,
    slices_per_worker: int,
    busy_ns=(10_000, 1_000_000),
    idle_ns=(1_000, 500_000),
    skew: np.ndarray | None = None,
) -> EventLog:
    """Generate a well-formed random log (used by tests/benchmarks).

    ``skew`` multiplies per-worker busy durations: a straggler has skew > 1.
    """
    times, workers, deltas = [], [], []
    skew = np.ones(num_workers) if skew is None else np.asarray(skew, np.float64)
    for w in range(num_workers):
        t = int(rng.integers(0, idle_ns[1]))
        for _ in range(slices_per_worker):
            busy = int(rng.integers(*busy_ns) * skew[w])
            times += [t, t + busy]
            workers += [w, w]
            deltas += [ACTIVATE, DEACTIVATE]
            t += busy + int(rng.integers(*idle_ns))
    order = np.argsort(np.asarray(times, np.int64), kind="stable")
    e = len(times)
    return EventLog(
        times=np.asarray(times, np.int64)[order],
        workers=np.asarray(workers, np.int32)[order],
        deltas=np.asarray(deltas, np.int8)[order],
        tags=np.full(e, NO_TAG, np.int32),
        stacks=np.full(e, NO_STACK, np.int32),
        num_workers=num_workers,
    )
