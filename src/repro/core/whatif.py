"""Causal what-if engine: counterfactual bottleneck projections.

GAPP's report ranks serialization bottlenecks; this module answers the
question the ranking begs — *what would fixing one be worth?*  In the
style of causal profilers (TASKPROF / COZ virtual speedups), a
counterfactual is computed by **replaying the fold over a time-warped
copy of the captured event log**: no re-capture, no instrumentation
change, pure columnar transforms.

Model
-----
Pick a target — a tag, a host, a worker, or a ranked path — and a
``shrink`` factor in ``[0, 1]`` (``0.0`` removes the targeted work
entirely, ``0.5`` halves it).  The engine then

1. re-folds the captured log once to get the baseline critical-slice
   table (bit-equal to the report's own fold on the numpy backend);
2. marks the targeted *critical* slices and, between every pair of
   adjacent events, compresses the interval iff **every worker active in
   that interval is inside a targeted critical slice** — time where the
   target is the only thing the machine is waiting on.  Intervals where
   untargeted work is also running are untouched: that work would still
   have to happen, so wall-clock cannot shrink there;
3. rebuilds event times as the cumsum of the warped interval lengths
   (monotonicity is preserved by construction) and re-folds the warped
   log through the standard detection pipeline.

The projection is *exact* for exclusively-serial sections (a worker
running alone, e.g. a serial optimizer step or a straggling expert's
tail) and conservative when the targeted work overlaps other work.
``examples/moe_imbalance.py`` and ``examples/pipeline_bubbles.py``
construct ground truth where the true gain is known; the gated
``--smoke whatif`` benchmark asserts projected-vs-measured agreement.

Surface
-------
* ``report.what_if("tag", shrink=0.0)`` → :class:`WhatIfResult`
  (projected end-to-end speedup, the new CMetric ranking with rank
  moves, per-worker load shift);
* ``report.sensitivity(params)`` → :class:`SensitivityResult`
  (tolerance/sampling perturbation sweep reporting rank stability);
* ``GET /api/whatif?tag=&shrink=`` on the live service returns the same
  document byte-for-byte (both sides are ``json.dumps(doc, indent=2)``
  over the same deterministic fold);
* the text/json exporters accept ``what_if=N`` to append projections
  for the top-N ranked paths.

Reports gain these abilities through a :class:`ReplaySpec` handle
attached at detection time (``detect`` / ``detect_offline`` / offline
:meth:`~repro.core.session.ProfileSession.snapshot`); the handle holds a
log *provider*, not a copy — nothing is materialized until asked.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Callable

import numpy as np

from repro.core import backends as backends_lib
from repro.core import detector as detector_lib
from repro.core.events import EventLog
from repro.core.report import path_entries
from repro.core.sampler import SampleBuffer, simulate_samples
from repro.core.tracer import StackRegistry, TagRegistry

#: Version of the WhatIfResult / SensitivityResult JSON layout.
WHATIF_SCHEMA_VERSION = 1


@dataclasses.dataclass
class ReplaySpec:
    """Everything needed to re-fold a report's capture counterfactually.

    Attached to :class:`~repro.core.detector.BottleneckReport` by the
    detection entry points.  ``log_provider`` is lazy — the event log is
    only materialized when a what-if/sensitivity query actually runs.
    """

    log_provider: Callable[[], EventLog]
    tags: TagRegistry
    stacks: StackRegistry
    n_min: float
    backend: str = "numpy"
    samples: SampleBuffer | None = None
    sample_dt_ns: int | None = None
    worker_names: list[str] | None = None
    worker_hosts: list[str] | None = None
    chunk_events: int | None = None

    def resolved_worker_names(self, num_workers: int) -> list[str]:
        if self.worker_names:
            return list(self.worker_names)
        return [f"w{i}" for i in range(num_workers)]


# ---------------------------------------------------------------------------
# the time warp (pure columnar transform)
# ---------------------------------------------------------------------------

def warp_log(log: EventLog, starts_ns: np.ndarray, ends_ns: np.ndarray,
             shrink: float) -> tuple[EventLog, float, int, float]:
    """Compress every inter-event interval fully covered by the targeted
    slices.

    An interval ``[t[i], t[i+1])`` is *compressible* iff at least one
    worker is active in it and the number of targeted slices covering it
    equals the active-worker count — i.e. every active worker is inside
    targeted work, so scaling the interval by ``shrink`` removes only
    targeted time.  Returns ``(warped_log, saved_s, compressed_intervals,
    compressed_s)``; the input log must be sanitized/time-sorted.
    """
    e = len(log)
    if e < 2 or starts_ns.size == 0:
        return log, 0.0, 0, 0.0
    t = log.times

    def snap(x):
        # slice boundaries are event times by construction, but device
        # backends round-trip them through float32 — snap to the nearest
        # event so a few-ns perturbation cannot shift the coverage window
        idx = np.searchsorted(t, x)
        lo = np.clip(idx - 1, 0, e - 1)
        hi = np.clip(idx, 0, e - 1)
        return np.where(np.abs(t[hi] - x) < np.abs(x - t[lo]), t[hi], t[lo])

    # active workers during interval i: running delta sum after event i
    n = np.cumsum(log.deltas.astype(np.int64))[:-1]
    # slice [start, end) covers intervals [a, b): boundary-delta cumsum
    a = np.searchsorted(t, snap(starts_ns), side="left")
    b = np.searchsorted(t, snap(ends_ns), side="left")
    cover = np.zeros(e, np.int64)
    np.add.at(cover, np.minimum(a, e - 1), 1)
    np.add.at(cover, np.minimum(b, e - 1), -1)
    c = np.cumsum(cover)[:-1]
    dt = (t[1:] - t[:-1]).astype(np.float64)
    compress = (n > 0) & (c >= n)
    if not compress.any():
        return log, 0.0, 0, 0.0
    new_dt = np.where(compress, dt * float(shrink), dt)
    compressed_ns = float(dt[compress].sum())
    saved_ns = (1.0 - float(shrink)) * compressed_ns
    new_t = np.empty(e, np.int64)
    new_t[0] = t[0]
    # cumsum of non-negative floats is non-decreasing and round is
    # monotone, so warped times stay sorted
    new_t[1:] = t[0] + np.round(np.cumsum(new_dt)).astype(np.int64)
    warped = EventLog(new_t, log.workers, log.deltas, log.tags,
                      log.stacks, log.num_workers)
    return warped, saved_ns * 1e-9, int(compress.sum()), compressed_ns * 1e-9


# ---------------------------------------------------------------------------
# target selection
# ---------------------------------------------------------------------------

def _stack_ids_containing(stacks: StackRegistry, tid: int) -> np.ndarray:
    return np.asarray([s for s, p in enumerate(stacks.paths) if tid in p],
                      np.int64)


def _slice_tags_from_events(log: EventLog, crit) -> np.ndarray:
    """Per-slice governing tag, recovered from the event stream.

    Stack ids are interned only for slices the *live* tracer deemed
    critical — and the fleet wire format drops them entirely — but every
    event carries its top-of-stack tag.  The tag governing a slice is the
    one at the most recent event at-or-before the slice start on that
    worker (a worker's events are time-sorted within the log)."""
    out = np.full(len(crit), -1, np.int64)
    for w in np.unique(crit.worker):
        m = crit.worker == w
        ew = log.workers == w
        t_w = log.times[ew]
        tag_w = log.tags[ew].astype(np.int64)
        idx = np.searchsorted(t_w, crit.start_ns[m], side="right") - 1
        vals = np.full(int(m.sum()), -1, np.int64)
        ok = idx >= 0
        vals[ok] = tag_w[idx[ok]]
        out[m] = vals
    return out


def _resolve_target(rep, spec: ReplaySpec, crit, log, kind: str, value):
    """Map a (kind, value) target to (mask over ``crit`` rows, selection
    doc).  Unknown names raise ``ValueError`` listing what *is* known."""
    nrows = len(crit)
    if kind == "tag":
        names = list(spec.tags.names)
        if isinstance(value, str):
            if value not in names:
                known = ", ".join(repr(n) for n in sorted(names)[:25])
                raise ValueError(
                    f"unknown tag {value!r}; known tags: {known or '<none>'}")
            tid = names.index(value)
        else:
            tid = int(value)
            if not 0 <= tid < len(names):
                raise ValueError(
                    f"tag id {tid} out of range 0..{len(names) - 1}")
        sids = _stack_ids_containing(spec.stacks, tid)
        mask = (np.isin(crit.stack_id, sids) if sids.size
                else np.zeros(nrows, bool))
        if nrows:
            # slices with no interned stack (live-non-critical, or any
            # fleet-ingested slice) still match through their event tags
            mask = mask | (_slice_tags_from_events(log, crit) == tid)
        return mask, {"kind": "tag", "value": names[tid], "tag_id": tid}
    if kind == "worker":
        wn = spec.resolved_worker_names(int(crit.worker.max()) + 1
                                        if nrows else 0)
        if isinstance(value, str):
            if value not in wn:
                known = ", ".join(repr(n) for n in wn[:25])
                raise ValueError(
                    f"unknown worker {value!r}; known: {known or '<none>'}")
            wid = wn.index(value)
        else:
            wid = int(value)
        mask = crit.worker == wid
        name = wn[wid] if 0 <= wid < len(wn) else f"w{wid}"
        return mask, {"kind": "worker", "value": name, "worker_id": wid}
    if kind == "host":
        wh = spec.worker_hosts or rep.worker_hosts
        if not wh:
            raise ValueError(
                "report has no host provenance; host= targeting needs a "
                "fleet report")
        wids = np.asarray([i for i, h in enumerate(wh) if h == value],
                          np.int64)
        if wids.size == 0:
            known = ", ".join(repr(h) for h in sorted(set(wh)))
            raise ValueError(f"unknown host {value!r}; known hosts: {known}")
        mask = np.isin(crit.worker, wids)
        return mask, {"kind": "host", "value": str(value),
                      "workers": [int(w) for w in wids]}
    if kind == "path":
        rank = int(value)
        if not 1 <= rank <= len(rep.paths):
            raise ValueError(
                f"path rank {rank} out of range 1..{len(rep.paths)}")
        target = rep.paths[rank - 1].stack
        npaths = len(spec.stacks.paths)
        sids = np.asarray([s for s, p in enumerate(spec.stacks.paths)
                           if p == target], np.int64)
        mask = (np.isin(crit.stack_id, sids) if sids.size
                else np.zeros(nrows, bool))
        if target == () and nrows:
            # NO_STACK / out-of-range ids all mean "no path"
            mask = mask | (crit.stack_id < 0) | (crit.stack_id >= npaths)
        return mask, {"kind": "path",
                      "value": rep.path_str(rep.paths[rank - 1]),
                      "rank": rank}
    raise ValueError(f"unknown target kind {kind!r}")


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

def _finite(x: float) -> float | None:
    return float(x) if math.isfinite(x) else None


@dataclasses.dataclass
class WhatIfResult:
    """One counterfactual projection.  ``report`` is the full
    counterfactual :class:`~repro.core.detector.BottleneckReport` (it
    carries its own replay handle, so projections compose); everything
    else is JSON-ready via :meth:`to_doc`."""

    selection: dict
    shrink: float
    baseline_total_s: float
    projected_total_s: float
    saved_s: float
    speedup: float
    matched_slices: int
    matched_cm_s: float
    compressed_intervals: int
    compressed_s: float
    per_worker: list[dict]
    ranking: list[dict]
    report: object = dataclasses.field(repr=False, default=None)

    def to_doc(self) -> dict:
        """The deterministic JSON document — ``/api/whatif`` serves
        exactly ``json.dumps(self.to_doc(), indent=2)``, so the wire
        bytes match :meth:`to_json` on the same capture."""
        return {
            "schema_version": WHATIF_SCHEMA_VERSION,
            "selection": self.selection,
            "shrink": self.shrink,
            "baseline_total_s": self.baseline_total_s,
            "projected_total_s": self.projected_total_s,
            "saved_s": self.saved_s,
            "speedup": _finite(self.speedup),
            "matched_slices": self.matched_slices,
            "matched_cm_s": self.matched_cm_s,
            "compressed_intervals": self.compressed_intervals,
            "compressed_s": self.compressed_s,
            "per_worker": self.per_worker,
            "ranking": self.ranking,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_doc(), indent=2)


def what_if(rep, tag=None, *, shrink: float = 0.0, host: str | None = None,
            worker=None, path: int | None = None,
            top_n: int = 10) -> WhatIfResult:
    """Project the effect of shrinking one target's critical work.

    Exactly one of ``tag`` (name or id), ``host``, ``worker`` (name or
    id), or ``path`` (1-based rank in ``rep.paths``) selects the target;
    ``shrink`` scales its exclusively-critical time (``0.0`` removes
    it).  Raises ``RuntimeError`` if the report carries no replay
    handle and ``ValueError`` for unknown targets or a ``shrink``
    outside ``[0, 1]``.
    """
    spec = getattr(rep, "replay", None)
    if spec is None:
        raise RuntimeError(
            "report has no replay handle: what-if needs the captured event "
            "log (reports from detect()/detect_offline() and offline "
            "sessions carry one; build_report() alone does not)")
    if not 0.0 <= float(shrink) <= 1.0:
        raise ValueError(f"shrink must be in [0, 1], got {shrink}")
    chosen = [(k, v) for k, v in
              (("tag", tag), ("host", host), ("worker", worker),
               ("path", path)) if v is not None]
    if len(chosen) != 1:
        raise ValueError(
            "select exactly one target: tag=, host=, worker= or path=")
    kind, value = chosen[0]

    clean = spec.log_provider().sanitize()
    res = backends_lib.compute(clean, backend=spec.backend)
    crit = res.critical_table(spec.n_min)
    mask, selection = _resolve_target(rep, spec, crit, clean, kind, value)
    matched = int(mask.sum())
    matched_cm = float(crit.cm[mask].sum()) if matched else 0.0

    warped, saved_s, n_comp, comp_s = warp_log(
        clean, crit.start_ns[mask], crit.end_ns[mask], float(shrink))
    wn = spec.resolved_worker_names(clean.num_workers)
    cf = detector_lib.detect_offline(
        warped, spec.tags, spec.stacks, spec.n_min,
        sample_dt_ns=spec.sample_dt_ns, backend=spec.backend,
        top_n=top_n, worker_names=wn)
    cf.worker_hosts = spec.worker_hosts or rep.worker_hosts

    baseline_total = float(res.total_time)
    projected_total = float(cf.total_time)
    speedup = (baseline_total / projected_total if projected_total > 0
               else math.inf)

    base_rank = {rep.path_str(p): i + 1 for i, p in enumerate(rep.paths)}
    ranking = path_entries(cf, top_n)
    for e in ranking:
        prev = base_rank.get(e["path"])
        e["baseline_rank"] = prev
        e["rank_delta"] = (prev - e["rank"]) if prev is not None else None

    base_pw = np.asarray(res.per_worker, np.float64)
    cf_pw = np.asarray(cf.per_worker, np.float64)
    w = max(base_pw.shape[0], cf_pw.shape[0])
    bp = np.zeros(w)
    bp[:base_pw.shape[0]] = base_pw
    cp = np.zeros(w)
    cp[:cf_pw.shape[0]] = cf_pw
    hosts = spec.worker_hosts or rep.worker_hosts
    per_worker = []
    for wid in range(w):
        row = {"worker": wn[wid] if wid < len(wn) else f"w{wid}",
               "baseline_cmetric_s": float(bp[wid]),
               "projected_cmetric_s": float(cp[wid]),
               "delta_cmetric_s": float(cp[wid] - bp[wid])}
        if hosts and wid < len(hosts):
            row["host"] = hosts[wid]
        per_worker.append(row)

    return WhatIfResult(
        selection=selection, shrink=float(shrink),
        baseline_total_s=baseline_total, projected_total_s=projected_total,
        saved_s=saved_s, speedup=speedup,
        matched_slices=matched, matched_cm_s=matched_cm,
        compressed_intervals=n_comp, compressed_s=comp_s,
        per_worker=per_worker, ranking=ranking, report=cf)


# ---------------------------------------------------------------------------
# sensitivity: perturbation sweep over detection parameters
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SensitivityResult:
    """Rank-stability of the report under detection-parameter
    perturbation (the microarch-sensitivity idea applied to GAPP's own
    knobs: the ``n_min`` criticality threshold and the sampling
    cadence).  A ranking that survives the sweep is trustworthy; one
    that reshuffles is an artifact of the threshold."""

    baseline: dict
    variants: list[dict]
    rank_stability: dict
    summary: dict

    def to_doc(self) -> dict:
        return {
            "schema_version": WHATIF_SCHEMA_VERSION,
            "baseline": self.baseline,
            "variants": self.variants,
            "rank_stability": self.rank_stability,
            "summary": self.summary,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_doc(), indent=2)


def sensitivity(rep, params: dict | None = None, *,
                top_k: int = 5) -> SensitivityResult:
    """Sweep detection parameters and report how stable the ranking is.

    ``params`` may override ``{"n_min_scale": (...), "sample_dt_scale":
    (...)}``; scales multiply the report's own ``n_min`` / sampling
    cadence.  One fold of the capture is shared by every ``n_min``
    variant (criticality is a post-fold filter), so the sweep costs one
    fold plus cheap merges.
    """
    spec = getattr(rep, "replay", None)
    if spec is None:
        raise RuntimeError(
            "report has no replay handle: sensitivity needs the captured "
            "event log")
    knobs = {"n_min_scale": (0.5, 0.75, 1.0, 1.25, 1.5),
             "sample_dt_scale": (0.5, 1.0, 2.0)}
    if params:
        unknown = set(params) - set(knobs)
        if unknown:
            raise ValueError(f"unknown sensitivity params: {sorted(unknown)}")
        knobs.update(params)

    clean = spec.log_provider().sanitize()
    res = backends_lib.compute(clean, backend=spec.backend)
    wn = spec.resolved_worker_names(clean.num_workers)

    def build(n_min: float, samples):
        crit = res.critical_table(n_min)
        return detector_lib.build_report(
            crit, samples, spec.stacks, n_min,
            per_worker=res.per_worker, worker_names=wn,
            tag_names=list(spec.tags.names),
            tag_locations=list(spec.tags.locations),
            total_slices=res.num_slices, idle_time=res.idle_time,
            total_time=res.total_time, top_n=top_k,
            worker_hosts=spec.worker_hosts)

    base_top = [rep.path_str(p) for p in rep.paths[:top_k]]
    variants: list[dict] = []
    for s in knobs["n_min_scale"]:
        r = build(spec.n_min * float(s), spec.samples)
        variants.append({
            "param": "n_min_scale", "value": float(s),
            "n_min": spec.n_min * float(s),
            "critical_slices": r.total_critical,
            "top": [r.path_str(p) for p in r.paths],
        })
    if spec.sample_dt_ns:
        for s in knobs["sample_dt_scale"]:
            dt = max(int(spec.sample_dt_ns * float(s)), 1)
            samples = simulate_samples(clean, dt, spec.n_min)
            r = build(spec.n_min, samples)
            variants.append({
                "param": "sample_dt_scale", "value": float(s),
                "sample_dt_ns": dt,
                "critical_slices": r.total_critical,
                "top": [r.path_str(p) for p in r.paths],
            })

    base_set = set(base_top)
    top1_agree = 0
    for v in variants:
        vs = set(v["top"])
        union = len(base_set | vs)
        v["jaccard_vs_baseline"] = (len(base_set & vs) / union
                                    if union else 1.0)
        v["top1_agrees"] = bool(
            v["top"] and base_top and v["top"][0] == base_top[0])
        top1_agree += int(v["top1_agrees"])

    rank_stability = {}
    for i, p in enumerate(base_top, 1):
        ranks = [v["top"].index(p) + 1 for v in variants if p in v["top"]]
        rank_stability[p] = {
            "baseline_rank": i,
            "min_rank": min(ranks) if ranks else None,
            "max_rank": max(ranks) if ranks else None,
            "present_in": len(ranks),
            "variants": len(variants),
        }

    n_var = len(variants)
    summary = {
        "variants": n_var,
        "top1_stability": (top1_agree / n_var) if n_var else 1.0,
        "mean_jaccard": (sum(v["jaccard_vs_baseline"] for v in variants)
                         / n_var) if n_var else 1.0,
        "stable": bool(n_var == 0 or top1_agree == n_var),
    }
    return SensitivityResult(
        baseline={"n_min": spec.n_min, "sample_dt_ns": spec.sample_dt_ns,
                  "top": base_top},
        variants=variants, rank_stability=rank_stability, summary=summary)
