"""Bottleneck detection & post-processing (paper §4.4).

Inputs: critical timeslices (from the live tracer or recomputed offline from
an :class:`EventLog`), and conditional samples from the sampling probe.

Pipeline (exactly the paper's user-space probe):
  1. attach each sample to the enclosing critical timeslice of its worker;
  2. *merge* timeslices that share a call path — CMetrics are summed and the
     sampled tags folded into one frequency table per path;
  3. rank call paths by cumulative CMetric and keep the top N;
  4. if a critical slice has no samples and its exit-time active count was
     ≤ n_min, attach the top-of-stack tag labelled ``stack_top`` (§4.4
     "Critical timeslices with no samples").
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core import cmetric as cmetric_lib
from repro.core.events import EventLog, NO_STACK, NO_TAG
from repro.core.sampler import SampleBuffer, simulate_samples
from repro.core.tracer import CriticalSlice, StackRegistry, TagRegistry, Tracer


@dataclasses.dataclass
class PathProfile:
    """One merged call path (the unit of the final ranking)."""

    stack: tuple[int, ...]                 # interned tag ids, caller->callee
    cmetric: float = 0.0
    slices: int = 0
    tag_counts: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    stack_top_counts: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)   # fallback samples (§4.4)

    def top_tags(self, k: int = 5):
        merged = collections.Counter(self.tag_counts)
        return merged.most_common(k)


@dataclasses.dataclass
class BottleneckReport:
    paths: list[PathProfile]               # sorted by cmetric, desc, top-N
    per_worker: np.ndarray                 # cumulative CMetric per worker
    worker_names: list[str]
    tag_names: list[str]
    tag_locations: list[str]
    total_critical: int
    total_slices: int
    idle_time: float
    total_time: float

    @property
    def critical_ratio(self) -> float:     # paper Table 2 "CR" column
        return self.total_critical / max(self.total_slices, 1)

    def tag_name(self, tid: int) -> str:
        if 0 <= tid < len(self.tag_names):
            return self.tag_names[tid]
        return "<unknown>"

    def path_str(self, p: PathProfile) -> str:
        return " > ".join(self.tag_name(t) for t in p.stack) or "<no-path>"


def _merge(
    slices: list[CriticalSlice],
    samples: SampleBuffer | None,
    stacks: StackRegistry,
    n_min: float,
) -> tuple[dict[tuple, PathProfile], int]:
    """Steps 1/2/4: sample attachment, path merge, stack-top fallback."""
    by_path: dict[tuple, PathProfile] = {}
    if not slices:
        return by_path, 0
    if samples is not None and len(samples):
        st, sw, stag = samples.frozen()
        order = np.lexsort((st, sw))
        st, sw, stag = st[order], sw[order], stag[order]
    else:
        st = np.zeros(0, np.int64)
        sw = np.zeros(0, np.int32)
        stag = np.zeros(0, np.int32)
    attached = 0
    for cs in slices:
        path = stacks.paths[cs.stack_id] if 0 <= cs.stack_id < len(stacks.paths) \
            else ()
        prof = by_path.get(path)
        if prof is None:
            prof = by_path[path] = PathProfile(stack=path)
        prof.cmetric += cs.cm
        prof.slices += 1
        # samples of this worker inside [start, end]
        lo = np.searchsorted(sw, cs.worker, side="left")
        hi = np.searchsorted(sw, cs.worker, side="right")
        a = lo + np.searchsorted(st[lo:hi], cs.start_ns, side="left")
        b = lo + np.searchsorted(st[lo:hi], cs.end_ns, side="right")
        if b > a:
            prof.tag_counts.update(stag[a:b].tolist())
            attached += int(b - a)
        elif cs.n_at_exit <= n_min and path:
            # no samples: fall back to the stack top (caller return address)
            prof.stack_top_counts.update([path[-1]])
    return by_path, attached


def detect(
    tracer: Tracer,
    samples: SampleBuffer | None = None,
    top_n: int = 10,
) -> BottleneckReport:
    """Live-mode detection straight from the tracer's online state."""
    n_min = tracer._resolved_n_min()
    by_path, _ = _merge(tracer.critical, samples, tracer.stacks, n_min)
    paths = sorted(by_path.values(), key=lambda p: -p.cmetric)[:top_n]
    log_len = min(tracer.ring.head, tracer.ring.capacity)
    total_slices = int(np.sum(
        tracer.ring.deltas[:log_len] == -1)) if log_len else 0
    return BottleneckReport(
        paths=paths,
        per_worker=tracer.per_worker_cm(),
        worker_names=tracer.worker_names(),
        tag_names=list(tracer.tags.names),
        tag_locations=list(tracer.tags.locations),
        total_critical=len(tracer.critical),
        total_slices=total_slices,
        idle_time=tracer.idle_time,
        total_time=((tracer.t_switch - tracer.t_first) * 1e-9
                    if tracer.t_first is not None else 0.0),
    )


def detect_offline(
    log: EventLog,
    tags: TagRegistry,
    stacks: StackRegistry,
    n_min: float,
    samples: SampleBuffer | None = None,
    sample_dt_ns: int | None = None,
    backend: str = "numpy",
    top_n: int = 10,
    worker_names: list[str] | None = None,
) -> BottleneckReport:
    """Offline pipeline: recompute CMetric from a raw event log with any
    backend (numpy / stream / vector / pallas), optionally replaying the
    sampling probe, then run the same merge+rank post-processing."""
    res = cmetric_lib.compute(log, backend=backend)
    if samples is None and sample_dt_ns is not None:
        samples = simulate_samples(log, sample_dt_ns, n_min)
    crit = critical_slices_from_result(log, res, n_min)
    by_path, _ = _merge(crit, samples, stacks, n_min)
    paths = sorted(by_path.values(), key=lambda p: -p.cmetric)[:top_n]
    return BottleneckReport(
        paths=paths,
        per_worker=res.per_worker,
        worker_names=worker_names or [f"w{i}" for i in range(log.num_workers)],
        tag_names=list(tags.names),
        tag_locations=list(tags.locations),
        total_critical=len(crit),
        total_slices=res.num_slices,
        idle_time=res.idle_time,
        total_time=res.total_time,
    )


def critical_slices_from_result(
    log: EventLog, res: cmetric_lib.CMetricResult, n_min: float,
) -> list[CriticalSlice]:
    """Rebuild CriticalSlice records from an offline CMetric result.

    Slice times in the result are rebased seconds; convert back to the log's
    ns timeline so samples (which carry ns timestamps) can be attached.
    """
    t0 = int(log.times[0]) if len(log) else 0
    mask = res.critical_mask(n_min)
    out: list[CriticalSlice] = []
    # instantaneous active count at exit: recompute from the log
    counts = np.cumsum(log.deltas.astype(np.int64))
    out_positions = np.flatnonzero(log.deltas == -1)
    n_at_exit = counts[out_positions] + 1   # count before the decrement
    for i in np.flatnonzero(mask):
        out.append(CriticalSlice(
            worker=int(res.slice_worker[i]),
            start_ns=t0 + int(round(res.slice_start[i] * 1e9)),
            end_ns=t0 + int(round(res.slice_end[i] * 1e9)),
            cm=float(res.slice_cm[i]),
            threads_av=float(res.slice_threads_av[i]),
            stack_id=int(res.slice_stack[i]),
            n_at_exit=int(n_at_exit[i]) if i < len(n_at_exit) else 1,
        ))
    return out
