"""Bottleneck detection & post-processing (paper §4.4).

Inputs: critical timeslices (from the live tracer or recomputed offline from
an :class:`EventLog`), and conditional samples from the sampling probe.

Pipeline (exactly the paper's user-space probe):
  1. attach each sample to the enclosing critical timeslice of its worker;
  2. *merge* timeslices that share a call path — CMetrics are summed and the
     sampled tags folded into one frequency table per path;
  3. rank call paths by cumulative CMetric and keep the top N;
  4. if a critical slice has no samples and its exit-time active count was
     ≤ n_min, attach the top-of-stack tag labelled ``stack_top`` (§4.4
     "Critical timeslices with no samples").

Two merge implementations:

* :func:`merge_table` — the production path, fully vectorised over the
  columnar :class:`~repro.core.slices.SliceTable`: one ``searchsorted`` per
  worker group for sample attachment (instead of two per slice), path merge
  via grouped ``bincount`` keyed on stack id, and tag frequency tables via a
  flat (path, tag) histogram that can run through the Pallas ``tag_hist``
  kernel.
* :func:`_merge_python` — the original per-slice Python loop, retained as
  the equivalence oracle for tests and as the reference semantics.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core import backends as backends_lib
from repro.core.events import EventLog
from repro.core.sampler import SampleBuffer, simulate_samples
from repro.core.slices import CriticalSlice, SliceTable
from repro.core.tracer import StackRegistry, TagRegistry, Tracer


@dataclasses.dataclass
class PathProfile:
    """One merged call path (the unit of the final ranking)."""

    stack: tuple[int, ...]                 # interned tag ids, caller->callee
    cmetric: float = 0.0
    slices: int = 0
    tag_counts: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)
    stack_top_counts: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)   # fallback samples (§4.4)

    def top_tags(self, k: int = 5):
        merged = collections.Counter(self.tag_counts)
        return merged.most_common(k)


@dataclasses.dataclass
class BottleneckReport:
    paths: list[PathProfile]               # sorted by cmetric, desc, top-N
    per_worker: np.ndarray                 # cumulative CMetric per worker
    worker_names: list[str]
    tag_names: list[str]
    tag_locations: list[str]
    total_critical: int
    total_slices: int
    idle_time: float
    total_time: float
    critical_table: SliceTable | None = None   # the merged slices, columnar
    # host provenance (fleet ingest): worker_hosts[wid] names the host that
    # produced worker ``wid``; None for single-host sessions
    worker_hosts: list[str] | None = None
    # counterfactual replay handle (repro.core.whatif.ReplaySpec) attached
    # by detect()/detect_offline()/offline snapshots; None when the capture
    # is not recoverable (e.g. build_report() called directly)
    replay: object | None = dataclasses.field(default=None, repr=False)

    @property
    def critical_ratio(self) -> float:     # paper Table 2 "CR" column
        return self.total_critical / max(self.total_slices, 1)

    # -- causal what-if (repro.core.whatif) -----------------------------------
    def what_if(self, tag=None, *, shrink: float = 0.0, host=None,
                worker=None, path=None, top_n: int = 10):
        """Counterfactual projection: replay the fold with the target's
        critical slices shrunk by ``shrink`` (0.0 == removed) and report
        projected speedup, the new ranking, and per-worker load shift.
        See :func:`repro.core.whatif.what_if`."""
        from repro.core import whatif as whatif_lib
        return whatif_lib.what_if(self, tag, shrink=shrink, host=host,
                                  worker=worker, path=path, top_n=top_n)

    def sensitivity(self, params: dict | None = None, *, top_k: int = 5):
        """Perturbation sweep over detection parameters (``n_min`` /
        sampling cadence) reporting rank stability.  See
        :func:`repro.core.whatif.sensitivity`."""
        from repro.core import whatif as whatif_lib
        return whatif_lib.sensitivity(self, params, top_k=top_k)

    def tag_name(self, tid: int) -> str:
        if 0 <= tid < len(self.tag_names):
            return self.tag_names[tid]
        return "<unknown>"

    def path_str(self, p: PathProfile) -> str:
        return " > ".join(self.tag_name(t) for t in p.stack) or "<no-path>"

    # -- host-provenance views (fleet reports) -------------------------------
    @property
    def hosts(self) -> list[str]:
        """Distinct host names in worker order ([] for single-host)."""
        if not self.worker_hosts:
            return []
        return list(dict.fromkeys(self.worker_hosts))

    def host_of_worker(self, wid: int) -> str | None:
        if self.worker_hosts and 0 <= wid < len(self.worker_hosts):
            return self.worker_hosts[wid]
        return None

    def per_host(self) -> dict[str, dict]:
        """Group the fleet-wide numbers per host: cumulative CMetric,
        worker count, and the critical-slice share (count / summed CMetric
        / mean ``threads_av``) of each host's workers.  Empty for
        single-host reports — everything is already 'this host'."""
        if not self.worker_hosts:
            return {}
        hosts = self.hosts
        idx = {h: i for i, h in enumerate(hosts)}
        wh = np.asarray([idx[h] for h in self.worker_hosts], np.int64)
        out = {}
        pw = self.per_worker
        ct = self.critical_table
        for h in hosts:
            mask = wh == idx[h]
            wids = np.flatnonzero(mask)
            row = {
                "workers": int(mask.sum()),
                "cmetric_s": float(pw[wids[wids < pw.shape[0]]].sum())
                if pw.size else 0.0,
                "critical": 0,
                "critical_cm_s": 0.0,
                "threads_av_mean": None,
            }
            if ct is not None and len(ct):
                cmask = np.isin(ct.worker, wids)
                row["critical"] = int(cmask.sum())
                if cmask.any():
                    row["critical_cm_s"] = float(ct.cm[cmask].sum())
                    row["threads_av_mean"] = float(
                        np.mean(ct.threads_av[cmask]))
            out[h] = row
        return out


# ---------------------------------------------------------------------------
# merge: vectorised table pipeline (production) + Python loop (oracle)
# ---------------------------------------------------------------------------

def _path_groups(stack_ids: np.ndarray, stacks: StackRegistry):
    """Group slice rows by call path, preserving first-seen order.

    Distinct stack ids can resolve to the same path key (NO_STACK and any
    out-of-range id both mean "no path"), so grouping goes through the path
    tuple.  Work is O(unique ids), not O(slices).
    """
    sid_vals, first_idx, inv = np.unique(stack_ids, return_index=True,
                                         return_inverse=True)
    paths = stacks.paths
    gid_of_val = np.zeros(len(sid_vals), np.int64)
    path_by_gid: list[tuple] = []
    seen: dict[tuple, int] = {}
    for k in np.argsort(first_idx, kind="stable"):
        sid = int(sid_vals[k])
        path = paths[sid] if 0 <= sid < len(paths) else ()
        g = seen.get(path)
        if g is None:
            g = seen[path] = len(path_by_gid)
            path_by_gid.append(path)
        gid_of_val[k] = g
    return gid_of_val[inv], path_by_gid


def _attach_samples(crit: SliceTable, samples: SampleBuffer | None):
    """Vectorised step 1: map every sample to its enclosing critical slices.

    Slices are sorted by (worker, start); per *worker group* (not per slice)
    two ``searchsorted`` calls bound the contiguous run of slices whose
    inclusive ``[start, end]`` window contains each sample — a worker's
    slices are time-disjoint, so starts *and* ends are non-decreasing within
    a group, and a sample on a shared boundary (end of one slice == start of
    the next) lands in both, exactly like the per-slice oracle's two-sided
    range check.  Returns (slice row indices, sample tags) of the attached
    samples, one entry per (sample, slice) match.
    """
    if samples is None or len(samples) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int32)
    st, sw, stag = samples.frozen_sorted()
    order = np.lexsort((crit.start_ns, crit.worker))
    cw = crit.worker[order]
    cs = crit.start_ns[order]
    ce = crit.end_ns[order]
    grp_w, grp_lo = np.unique(cw, return_index=True)
    grp_hi = np.append(grp_lo[1:], len(cw))
    rows, tags = [], []
    for g in range(len(grp_w)):
        lo = np.searchsorted(sw, grp_w[g], side="left")
        hi = np.searchsorted(sw, grp_w[g], side="right")
        if lo == hi:
            continue
        tw = st[lo:hi]
        a, b = grp_lo[g], grp_hi[g]
        j_lo = np.searchsorted(ce[a:b], tw, side="left")
        j_hi = np.searchsorted(cs[a:b], tw, side="right")
        counts = np.maximum(j_hi - j_lo, 0)
        total = int(counts.sum())
        if total == 0:
            continue
        # expand each sample to its [j_lo, j_hi) run of enclosing slices
        base = np.repeat(j_lo, counts)
        offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                            counts)
        rows.append(order[a + base + offs])
        tags.append(np.repeat(stag[lo:hi], counts))
    if not rows:
        return np.zeros(0, np.int64), np.zeros(0, np.int32)
    return np.concatenate(rows), np.concatenate(tags)


def _pallas_hist_native() -> bool:
    """True when the Pallas ``tag_hist`` kernel compiles natively — in
    interpret mode (off-TPU) ``np.bincount`` is far faster, so the fused
    backend only routes the histogram on real TPU hardware."""
    from repro.kernels import ops
    return not ops.default_interpret()


def _key_hist(keys: np.ndarray, num_bins: int, use_pallas: bool) -> np.ndarray:
    """Histogram of flat (group, tag) keys — optionally on the Pallas
    ``tag_hist`` kernel (TPU path); ``bincount`` otherwise."""
    if use_pallas and num_bins <= (1 << 20):
        import jax.numpy as jnp
        from repro.kernels import ops
        counts, _ = ops.tag_histogram(jnp.asarray(keys, jnp.int32),
                                      num_bins=num_bins)
        return np.asarray(counts)
    return np.bincount(keys, minlength=num_bins)


def merge_table(
    crit: SliceTable,
    samples: SampleBuffer | None,
    stacks: StackRegistry,
    n_min: float,
    *,
    use_pallas_hist: bool = False,
) -> tuple[list[PathProfile], int]:
    """Steps 1/2/4 over the columnar IR.  Returns the merged profiles in
    first-seen path order (the seed dict-insertion order, so downstream
    ranking tie-breaks identically) and the attached-sample count."""
    s = len(crit)
    if s == 0:
        return [], 0
    gids, path_by_gid = _path_groups(crit.stack_id, stacks)
    ngroups = len(path_by_gid)
    cm_sum = np.bincount(gids, weights=crit.cm, minlength=ngroups)
    n_slices = np.bincount(gids, minlength=ngroups)

    rows, tags = _attach_samples(crit, samples)
    attached = int(rows.size)
    per_slice_hits = np.bincount(rows, minlength=s)

    # per-(path, tag) frequency tables via one flat histogram; the +1 offset
    # admits NO_TAG (-1) samples, which the per-slice Counter also recorded
    tag_tables: list[collections.Counter] = [collections.Counter()
                                             for _ in range(ngroups)]
    if attached:
        k = int(tags.max()) + 2
        counts = _key_hist(gids[rows] * k + (tags.astype(np.int64) + 1),
                           ngroups * k, use_pallas_hist)
        for key in np.flatnonzero(counts):
            tag_tables[key // k][int(key % k) - 1] = int(counts[key])

    # stack-top fallback (§4.4): sampleless slice, low exit parallelism
    path_len = np.asarray([len(p) for p in path_by_gid])
    fb_mask = ((per_slice_hits == 0) & (crit.n_at_exit <= n_min)
               & (path_len[gids] > 0))
    fallbacks = np.bincount(gids[fb_mask], minlength=ngroups)

    profiles = []
    for g in range(ngroups):
        p = PathProfile(stack=path_by_gid[g], cmetric=float(cm_sum[g]),
                        slices=int(n_slices[g]), tag_counts=tag_tables[g])
        if fallbacks[g]:
            p.stack_top_counts[path_by_gid[g][-1]] = int(fallbacks[g])
        profiles.append(p)
    return profiles, attached


def _merge_python(
    slices: list[CriticalSlice],
    samples: SampleBuffer | None,
    stacks: StackRegistry,
    n_min: float,
) -> tuple[dict[tuple, PathProfile], int]:
    """Seed per-slice merge loop — the equivalence oracle for
    :func:`merge_table` (two searchsorted per slice, Counter updates)."""
    by_path: dict[tuple, PathProfile] = {}
    if not slices:
        return by_path, 0
    if samples is not None and len(samples):
        st, sw, stag = samples.frozen()
        order = np.lexsort((st, sw))
        st, sw, stag = st[order], sw[order], stag[order]
    else:
        st = np.zeros(0, np.int64)
        sw = np.zeros(0, np.int32)
        stag = np.zeros(0, np.int32)
    attached = 0
    for cs in slices:
        path = stacks.paths[cs.stack_id] if 0 <= cs.stack_id < len(stacks.paths) \
            else ()
        prof = by_path.get(path)
        if prof is None:
            prof = by_path[path] = PathProfile(stack=path)
        prof.cmetric += cs.cm
        prof.slices += 1
        # samples of this worker inside [start, end]
        lo = np.searchsorted(sw, cs.worker, side="left")
        hi = np.searchsorted(sw, cs.worker, side="right")
        a = lo + np.searchsorted(st[lo:hi], cs.start_ns, side="left")
        b = lo + np.searchsorted(st[lo:hi], cs.end_ns, side="right")
        if b > a:
            prof.tag_counts.update(stag[a:b].tolist())
            attached += int(b - a)
        elif cs.n_at_exit <= n_min and path:
            # no samples: fall back to the stack top (caller return address)
            prof.stack_top_counts.update([path[-1]])
    return by_path, attached


# Back-compat alias (seed name).
_merge = _merge_python


def build_report(
    crit: SliceTable,
    samples: SampleBuffer | None,
    stacks: StackRegistry,
    n_min: float,
    *,
    per_worker: np.ndarray,
    worker_names: list[str],
    tag_names: list[str],
    tag_locations: list[str],
    total_slices: int,
    idle_time: float,
    total_time: float,
    top_n: int = 10,
    use_pallas_hist: bool = False,
    worker_hosts: list[str] | None = None,
) -> BottleneckReport:
    """Merge + rank a critical-slice table into a :class:`BottleneckReport`.

    The shared tail of every detection path — live :func:`detect`, offline
    :func:`detect_offline`, and the incremental
    :meth:`~repro.core.session.ProfileSession.snapshot`, which calls this
    directly on the carried fold state mid-capture.  ``worker_hosts`` tags
    each worker with its origin host (fleet ingest)."""
    paths_all, _ = merge_table(crit, samples, stacks, n_min,
                               use_pallas_hist=use_pallas_hist)
    paths = sorted(paths_all, key=lambda p: -p.cmetric)[:top_n]
    return BottleneckReport(
        paths=paths,
        per_worker=np.asarray(per_worker, np.float64),
        worker_names=worker_names,
        tag_names=tag_names,
        tag_locations=tag_locations,
        total_critical=len(crit),
        total_slices=total_slices,
        idle_time=idle_time,
        total_time=total_time,
        critical_table=crit,
        worker_hosts=worker_hosts,
    )


def detect(
    tracer: Tracer,
    samples: SampleBuffer | None = None,
    top_n: int = 10,
    budgeted: bool = False,
) -> BottleneckReport:
    """Live-mode detection from the tracer's batched online state (one
    ``snapshot()``: pending shard events are drained and folded once, and
    every reported number comes from the same sync point).  ``budgeted``
    caps that flush at the tracer's ``max_rows_per_sync`` decode budget —
    bounded latency, possibly lagging the capture by the backlog."""
    n_min = tracer._resolved_n_min()
    # keyword only when asked: LockedTracer's snapshot has no budget
    snap = tracer.snapshot(budgeted=True) if budgeted else tracer.snapshot()
    crit = snap["critical"]
    rep = build_report(
        crit, samples, tracer.stacks, n_min,
        per_worker=snap["per_worker"],
        worker_names=tracer.worker_names(),
        tag_names=list(tracer.tags.names),
        tag_locations=list(tracer.tags.locations),
        total_slices=snap["total_slices"],
        idle_time=snap["idle_time"],
        total_time=snap["total_time"],
        top_n=top_n,
    )
    from repro.core.whatif import ReplaySpec
    rep.replay = ReplaySpec(
        log_provider=tracer.freeze, tags=tracer.tags, stacks=tracer.stacks,
        n_min=n_min, samples=samples, worker_names=tracer.worker_names())
    return rep


def detect_offline(
    log: EventLog,
    tags: TagRegistry,
    stacks: StackRegistry,
    n_min: float,
    samples: SampleBuffer | None = None,
    sample_dt_ns: int | None = None,
    backend: str = "numpy",
    top_n: int = 10,
    worker_names: list[str] | None = None,
    chunk_events: int | None = None,
) -> BottleneckReport:
    """Offline pipeline: recompute CMetric from a raw event log with any
    registered backend (numpy / stream / vector / pallas), optionally
    replaying the sampling probe, then run the same merge+rank
    post-processing — all stages over the columnar slice table.

    Raw logs are sanitized first (spurious double-ACTIVATE / unmatched
    DEACTIVATE are dropped exactly as the live tracer would), so adversarial
    streams produce the same report on every backend.

    ``chunk_events`` streams the fold: the log is pushed through the
    backend's carry-resumable ``fold_chunk`` in batches of that many
    events, sanitizing each chunk with carried per-worker state, and only
    the *critical* slice rows are retained between chunks — so arbitrarily
    long logs profile in memory bounded by the chunk size plus the critical
    set.  Results are identical to the whole-log path (bit-equal for the
    float64 ``numpy`` backend).
    """
    raw_log = log
    if chunk_events is not None and len(log):
        from repro.core.cmetric import FoldCarry
        from repro.core.events import sanitize_chunk
        carry = FoldCarry.init(log.num_workers)
        crit_parts = []
        for lo in range(0, len(log), chunk_events):
            part = log.chunk(lo, lo + chunk_events)
            # carry.open is the Table-1 per-worker state: sanitize against
            # it, and the fold advances it after consuming the clean chunk
            part, _, _ = sanitize_chunk(part, carry.open)
            carry, tbl = backends_lib.fold_chunk(carry, part,
                                                 backend=backend)
            ct = tbl.critical(n_min)
            if len(ct):
                crit_parts.append(ct)
        crit = SliceTable.concat(crit_parts)
        per_worker, idle, total = carry.per_worker, carry.idle, carry.total_time
        num_slices = carry.slices
        if samples is None and sample_dt_ns is not None:
            samples = simulate_samples(log.sanitize(), sample_dt_ns, n_min)
    else:
        log = log.sanitize()
        res = backends_lib.compute(log, backend=backend)
        if samples is None and sample_dt_ns is not None:
            samples = simulate_samples(log, sample_dt_ns, n_min)
        crit = res.critical_table(n_min)
        per_worker, idle, total = res.per_worker, res.idle_time, res.total_time
        num_slices = res.num_slices
    caps = backends_lib.get_backend(backend).capabilities
    rep = build_report(
        crit, samples, stacks, n_min,
        per_worker=per_worker,
        worker_names=worker_names or [f"w{i}" for i in range(log.num_workers)],
        tag_names=list(tags.names),
        tag_locations=list(tags.locations),
        total_slices=num_slices,
        idle_time=idle,
        total_time=total,
        top_n=top_n,
        use_pallas_hist="fused" in caps and _pallas_hist_native(),
    )
    from repro.core.whatif import ReplaySpec
    rep.replay = ReplaySpec(
        log_provider=lambda: raw_log, tags=tags, stacks=stacks, n_min=n_min,
        backend=backend, samples=samples, sample_dt_ns=sample_dt_ns,
        worker_names=worker_names, chunk_events=chunk_events)
    return rep


def critical_slices_from_result(log, res, n_min: float) -> list[CriticalSlice]:
    """Legacy view: critical rows of an offline result as per-slice records
    (the columnar pipeline uses ``res.critical_table(n_min)`` directly)."""
    del log  # times are already on the log's ns clock inside the table
    return res.critical_table(n_min).to_records()
