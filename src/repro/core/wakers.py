"""Waker analysis + bottleneck classification (paper §7 future work).

The paper's conclusion sketches two extensions we implement here:

* **Waker edges** — "by combining GAPP's existing criticality information
  with an analysis of futex 'wakers' it is relatively easy to distinguish
  critical from non-critical lock holders".  Our analogue: when worker A
  deactivates at time t and worker B activates within ``eps`` after t, A
  plausibly *released* whatever B was waiting on.  Aggregating these edges
  weighted by the waiting worker's subsequent slice CMetric yields a
  wait-for attribution — which worker's completions unblock the most
  critical work (the wPerf-style view, built from the same event stream).

* **Bottleneck classification** — critical call paths are bucketed by tag
  taxonomy (data / checkpoint / collective / compute / serve / other), the
  "automate the process of bottleneck classification" step.
"""
from __future__ import annotations

import collections
import dataclasses


from repro.core.detector import BottleneckReport
from repro.core.events import EventLog


@dataclasses.dataclass
class WakerEdge:
    waker: int
    woken: int
    count: int
    cm_unblocked: float     # CMetric of the woken worker's following slices


def waker_edges(log: EventLog, eps_ns: int = 10_000) -> list[WakerEdge]:
    """Derive wake-up edges from deactivate→activate adjacency."""
    from repro.core.cmetric import compute_numpy
    res = compute_numpy(log)
    # slice start (ns, rebased) -> slice cm, per worker
    t0 = int(log.times[0]) if len(log) else 0
    slice_by_start: dict[tuple[int, int], float] = {}
    for w, s, cm in zip(res.slice_worker, res.slice_start, res.slice_cm):
        slice_by_start[(int(w), t0 + int(round(s * 1e9)))] = float(cm)
    edges: dict[tuple[int, int], list] = collections.defaultdict(
        lambda: [0, 0.0])
    deact = [(int(t), int(w)) for t, w, d in
             zip(log.times, log.workers, log.deltas) if d == -1]
    act = [(int(t), int(w)) for t, w, d in
           zip(log.times, log.workers, log.deltas) if d == 1]
    ai = 0
    for t, w in deact:
        while ai < len(act) and act[ai][0] < t:
            ai += 1
        j = ai
        while j < len(act) and act[j][0] <= t + eps_ns:
            tw, ww = act[j]
            if ww != w:
                e = edges[(w, ww)]
                e[0] += 1
                e[1] += slice_by_start.get((ww, tw), 0.0)
            j += 1
    out = [WakerEdge(a, b, c, cm) for (a, b), (c, cm) in edges.items()]
    out.sort(key=lambda e: -e.cm_unblocked)
    return out


def critical_wakers(log: EventLog, top_k: int = 5,
                    eps_ns: int = 10_000) -> list[tuple[int, float]]:
    """Workers ranked by how much critical work their completions unblock."""
    agg: dict[int, float] = collections.defaultdict(float)
    for e in waker_edges(log, eps_ns):
        agg[e.waker] += e.cm_unblocked
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top_k]


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------

TAXONOMY = {
    "data": ("data/", "load", "wait_data", "prefetch"),
    "io": ("write", "read", "flush", "output", "disk", "file"),
    "checkpoint": ("ckpt", "save", "restore"),
    "collective": ("all_reduce", "all_gather", "all_to_all", "psum",
                   "barrier", "sync"),
    "serve": ("decode/", "prefill", "request", "slot"),
    "compute": ("step", "layer", "matmul", "ffn", "attn", "expert",
                "compute", "stage"),
}


def classify_tag(tag: str) -> str:
    low = tag.lower()
    for cls, keys in TAXONOMY.items():
        if any(k in low for k in keys):
            return cls
    return "other"


def classify_report(rep: BottleneckReport) -> dict[str, float]:
    """Cumulative critical CMetric per bottleneck class."""
    out: dict[str, float] = collections.defaultdict(float)
    for p in rep.paths:
        tag = rep.tag_name(p.stack[-1]) if p.stack else "other"
        out[classify_tag(tag)] += p.cmetric
    return dict(out)
