"""Report exporter registry — the output-side twin of :mod:`backends`.

Every way of getting a :class:`~repro.core.detector.BottleneckReport` out of
the profiler registers here under a short name, mirroring the CMetric
backend registry: ``register_exporter(name, fn, capabilities=...)`` and
``export(report, fmt, ...)`` dispatches by name, so new output formats plug
in without touching the pipeline.  Built-ins:

* ``"text"``     — :func:`repro.core.report.render_text` (Figure-7 profile)
* ``"json"``     — :func:`repro.core.report.to_json` (versioned schema)
* ``"chrome"``   — :func:`repro.core.timeline.to_chrome_trace`; needs the
  event log, which it pulls from ``session=`` (a
  :class:`~repro.core.session.ProfileSession`) or an explicit ``log=``
* ``"callback"`` — invokes ``callback(report)`` (one-shot push)
* ``"watch"``    — subscribes ``callback`` to *live* incremental reports on
  a session (``export(rep, "watch", session=s, callback=cb, every=0.5)``
  == ``s.watch(cb, every=0.5)``); the session's background drain worker
  pushes a fresh top-N report every ``every`` seconds while the workload
  runs.  Returns the unsubscribe handle.

Exporter signature: ``fn(report, *, session=None, **kw)``; ``session`` is
the originating session when the export goes through
:meth:`ProfileSession.export`, giving exporters access to the event log and
live state without the report having to carry them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.core.report import render_text, to_json
from repro.core.timeline import to_chrome_trace

ExporterFn = Callable[..., object]


@dataclasses.dataclass(frozen=True)
class Exporter:
    name: str
    fn: ExporterFn
    capabilities: frozenset[str]

    def __call__(self, rep, **kw):
        return self.fn(rep, **kw)


_REGISTRY: dict[str, Exporter] = {}

# Exporters that live in optional packages: resolved on first use so the
# core never imports them eagerly (``session.export("remote", addr=...)``
# just works without an explicit ``import repro.fleet``).
_LAZY_EXPORTERS = {"remote": "repro.fleet.transport"}


def register_exporter(name: str, fn: ExporterFn | None = None, *,
                      capabilities: Iterable[str] = ()) -> ExporterFn:
    """Register ``fn`` as exporter ``name`` (direct call or decorator, like
    :func:`repro.core.backends.register_backend`).  Re-registering a name
    replaces it."""
    def _register(f: ExporterFn) -> ExporterFn:
        _REGISTRY[name] = Exporter(name, f, frozenset(capabilities))
        return f
    return _register(fn) if fn is not None else _register


def unregister_exporter(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_exporter(name: str) -> Exporter:
    try:
        return _REGISTRY[name]
    except KeyError:
        pass
    mod = _LAZY_EXPORTERS.get(name)
    if mod is not None:
        import importlib
        importlib.import_module(mod)    # registers on import
        if name in _REGISTRY:
            return _REGISTRY[name]
    known = ", ".join(sorted(set(available_exporters())
                             | set(_LAZY_EXPORTERS)))
    raise KeyError(
        f"unknown exporter {name!r}; available: {known}") from None


def available_exporters() -> list[str]:
    return sorted(_REGISTRY)


def exporters_with(capability: str) -> list[str]:
    return sorted(e.name for e in _REGISTRY.values()
                  if capability in e.capabilities)


def export(rep, fmt: str = "text", *, session=None, **kw):
    """Dispatch ``rep`` through the named exporter."""
    return get_exporter(fmt)(rep, session=session, **kw)


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------

@register_exporter("text", capabilities={"human"})
def _export_text(rep, *, session=None, **kw) -> str:
    out = render_text(rep, **kw)
    if session is not None:
        try:
            stats = session.stats()
        except Exception:
            stats = {}
        src = stats.get("source") or {}
        shed = int(src.get("shed_chunks") or 0)
        lost = int(src.get("lost_chunks") or 0)
        idle = int(src.get("idle_hosts") or 0)
        if shed or lost or idle:
            # degraded capture: the ranking above folded an incomplete
            # stream — say so right next to the numbers it skews
            out += ("\ncapture health: DEGRADED — "
                    f"{shed} chunk(s) shed under overload "
                    "(recoverable from fleet journals), "
                    f"{lost} chunk(s) lost in transit, "
                    f"{idle} idle host(s) released from the watermark\n")
    return out


@register_exporter("json", capabilities={"machine", "versioned"})
def _export_json(rep, *, session=None, **kw) -> str:
    """``what_if=N`` (optionally ``what_if_shrink=``) appends the
    counterfactual projections block — computed only on request, so the
    default export (and ``/api/report`` byte-equality) costs nothing."""
    return to_json(rep, **kw)


@register_exporter("chrome", capabilities={"trace"})
def _export_chrome(rep, *, session=None, log=None, path=None,
                   tag_names=None, worker_names=None, critical=None,
                   worker_hosts=None) -> str:
    """Chrome-trace JSON.  The report alone does not carry the event stream,
    so the log comes from ``log=`` or ``session.freeze()``; names, host
    lanes and the critical overlay default to the report's."""
    if log is None:
        if session is None:
            raise ValueError("chrome exporter needs log= or session=")
        log = session.freeze()
    data = to_chrome_trace(
        log,
        tag_names=tag_names if tag_names is not None else rep.tag_names,
        worker_names=(worker_names if worker_names is not None
                      else rep.worker_names),
        critical=critical if critical is not None else rep.critical_table,
        worker_hosts=(worker_hosts if worker_hosts is not None
                      else rep.worker_hosts))
    if path is not None:
        with open(path, "w") as f:
            f.write(data)
    return data


@register_exporter("callback", capabilities={"push"})
def _export_callback(rep, *, session=None, callback=None, **kw):
    if callback is None:
        raise ValueError("callback exporter needs callback=")
    callback(rep)
    return rep


@register_exporter("watch", capabilities={"push", "live", "incremental",
                                          "subscription"})
def _export_watch(rep, *, session=None, callback=None, every: float = 0.5,
                  top_n: int | None = None, payload: bool = False, **kw):
    """Subscribe ``callback`` to live top-N updates on ``session``; the
    drain worker pushes a fresh incremental report every ``every`` seconds
    (plus one final report at close).  Returns the unsubscribe handle.
    ``payload=True`` delivers the JSON-ready ``/api/stream`` frame (with
    ``worker_hosts``/``per_host`` lanes and ``health``) instead of the
    report object — see :func:`repro.obs.payload.build_watch_payload`."""
    if session is None or callback is None:
        raise ValueError("watch exporter needs session= and callback=")
    return session.watch(callback, every=every, top_n=top_n,
                         payload=payload)
