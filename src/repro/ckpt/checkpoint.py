"""Sharded checkpointing with atomic manifests and elastic resharding.

Layout::

    <dir>/step_000100/
        manifest.json        # tree structure, shapes, dtypes, step
        shard_h000.npz       # this host's param/opt leaves (addressable data)
        .complete            # atomic commit marker (written last)

Every host writes the leaves it is primary for (here: single-host writes
all).  Restore reassembles the tree and ``jax.device_put``s each leaf with
the *target* sharding — which may belong to a different mesh than the one
that saved it (elastic N→M restart): the arrays are laid out from the host
copy, so resharding is automatic.  The checkpoint writer runs in a
background thread and is a registered GAPP worker — a slow blocking save
shows up as a serialization bottleneck in the profile (the paper's
Bodytrack OutputBMP case, verbatim, at fleet scale).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, tree, blocking: bool = True,
         gapp=None, wid=None) -> threading.Thread | None:
    """Write a checkpoint; returns the writer thread when non-blocking.

    Device arrays are snapshotted to host *synchronously* (donated buffers
    may be invalidated by the very next step) — only the file I/O runs on
    the writer thread."""
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}

    def _write():
        if gapp is not None:
            gapp.begin(wid, "ckpt/save")
        d = os.path.join(directory, f"step_{step:06d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_h000.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, ".complete"), "w") as f:
            f.write("ok")
        if os.path.isdir(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        if gapp is not None:
            gapp.end(wid)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True, name="ckpt-writer")
    t.start()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(directory, name, ".complete")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Rebuild ``like_tree``-structured arrays; ``shardings`` (same
    structure) places each leaf — independent of the saving mesh."""
    d = os.path.join(directory, f"step_{step:06d}")
    if not os.path.exists(os.path.join(d, ".complete")):
        raise FileNotFoundError(f"incomplete checkpoint: {d}")
    data = np.load(os.path.join(d, "shard_h000.npz"))
    flat_like, treedef = _flatten(like_tree)
    keys = list(flat_like)
    missing = [k for k in keys if k not in data]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    flat_sh = _flatten(shardings)[0] if shardings is not None else None
    leaves = []
    for k in keys:
        arr = data[k]
        like = flat_like[k]
        arr = arr.astype(like.dtype) if arr.dtype != like.dtype else arr
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[k]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), leaves)


def prune(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    all_steps = sorted(int(n.split("_")[1]) for n in os.listdir(directory)
                       if n.startswith("step_") and not n.endswith(".tmp"))
    for s in all_steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:06d}"),
                      ignore_errors=True)
