"""Checkpointing: sharded, atomic, elastic."""
from repro.ckpt import checkpoint  # noqa: F401
