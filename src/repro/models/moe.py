"""Mixture-of-Experts FFN: top-k routing with capacity, sort-based dispatch.

Covers grok-1 (8 experts, top-2) and arctic (128 experts, top-2 **plus** a
dense residual MLP in parallel).  Expert parallelism: the (E, C, D) expert
batch is sharded over the ``experts`` logical axis (→ ``model``) when E
divides the axis; otherwise (grok: E=8 on a 16-way axis) expert weights fall
back to tensor parallelism over ``expert_mlp`` and the token batch stays
data-parallel — both bindings are chosen per arch by the launcher rules.

Dispatch is sort-free on the hot path: position-in-expert comes from a
cumsum over the token-choice one-hot (GShard style), tokens beyond capacity
are dropped (and counted), and combine is the transpose einsum weighted by
router probabilities.  An auxiliary load-balance loss (Switch §2.2) is
returned so training can keep the router healthy — expert imbalance is one
of the serialization bottlenecks the GAPP profiler is pointed at (a hot
expert serializes the all-to-all), so the router stats are also exported as
profiler span-weights by the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init
from repro.sharding.api import constrain


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    pdt = cfg.param_dtype
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "we_gate": dense_init(ks[1], (e, d, f), in_axis=1, dtype=pdt),
        "we_up": dense_init(ks[2], (e, d, f), in_axis=1, dtype=pdt),
        "we_down": dense_init(ks[3], (e, f, d), in_axis=1, dtype=pdt),
    }
    if cfg.dense_residual:
        km = jax.random.split(ks[4], 3)
        p["dense_gate"] = dense_init(km[0], (d, f), dtype=pdt)
        p["dense_up"] = dense_init(km[1], (d, f), dtype=pdt)
        p["dense_down"] = dense_init(km[2], (f, d), dtype=pdt)
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * tokens_per_group
            / max(cfg.num_experts, 1))
    return max(4, -(-c // 4) * 4)            # round up to a multiple of 4


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D), aux metrics dict.

    Groups are batch rows (B groups of S tokens): routing, capacity and the
    dispatch/combine einsums are per-group, so the batch dim stays on the DP
    axes and the expert dim carries the EP all-to-all.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cdt = cfg.compute_dtype
    cap = _capacity(cfg, s)

    logits = x.astype(jnp.float32) @ p["router"]          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                # (B,S,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # position-in-expert via cumsum over the flattened (S*k) choice sequence,
    # k-th choices ranked after all (k-1)-th choices (GShard ordering).
    choice_eh = jax.nn.one_hot(top_e, e, dtype=jnp.int32)  # (B,S,k,E)
    flat = choice_eh.transpose(0, 2, 1, 3).reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                     # (B,S*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(b, k, s).transpose(0, 2, 1)
    keep = pos < cap                                       # (B,S,k)
    dropped = jnp.sum(~keep)

    # dispatch: (B,S,k) scatter -> (B,E,C,D)
    def dispatch_one(xg, eg, posg, keepg):                 # per batch row
        out = jnp.zeros((e, cap, d), cdt)
        idx_e = eg.reshape(-1)
        idx_c = jnp.where(keepg, posg, cap).reshape(-1).astype(jnp.int32)
        src = jnp.repeat(xg[:, None], k, axis=1).reshape(-1, d).astype(cdt)
        return out.at[idx_e, jnp.minimum(idx_c, cap - 1)].add(
            src * keepg.reshape(-1, 1))

    expert_in = jax.vmap(dispatch_one)(x, top_e, pos, keep)  # (B,E,C,D)
    expert_in = constrain(expert_in, "batch", "experts_act", None, "embed")

    # expert FFN (SwiGLU), E sharded (EP) or F sharded (TP fallback)
    wg = p["we_gate"].astype(cdt)
    wu = p["we_up"].astype(cdt)
    wd = p["we_down"].astype(cdt)
    if cfg.opt_level >= 1:
        # pin the bf16 copies to the weights' own sharding so any gather at
        # the einsum moves bf16, not the f32 master (cast-then-gather)
        wg = constrain(wg, "experts", "expert_in", "expert_mlp")
        wu = constrain(wu, "experts", "expert_in", "expert_mlp")
        wd = constrain(wd, "experts", "expert_mlp", "expert_in")
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, wg)) \
        * jnp.einsum("becd,edf->becf", expert_in, wu)
    h = constrain(h, "batch", "experts_act", None, "expert_mlp")
    expert_out = jnp.einsum("becf,efd->becd", h, wd)
    expert_out = constrain(expert_out, "batch", "experts_act", None, "embed")

    # combine: gather back with router weights
    def combine_one(yg, eg, posg, keepg, pg):
        src = yg[eg.reshape(-1), jnp.where(keepg, posg, 0).reshape(-1)
                 .astype(jnp.int32)]
        src = src * (keepg.reshape(-1, 1) * pg.reshape(-1, 1)).astype(cdt)
        return jnp.sum(src.reshape(s, k, d), axis=1)

    y = jax.vmap(combine_one)(expert_out, top_e, pos, keep, top_p)
    y = constrain(y, "batch", "seq", "embed")

    if cfg.dense_residual:
        hd_ = jax.nn.silu(x @ p["dense_gate"].astype(cdt)) \
            * (x @ p["dense_up"].astype(cdt))
        hd_ = constrain(hd_, "batch", "seq", "mlp")
        y = y + hd_ @ p["dense_down"].astype(cdt)

    # Switch-style load-balance auxiliary loss + routing stats
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = {
        "aux_loss": cfg.router_aux_weight * e
        * jnp.sum(frac_tokens * frac_probs),
        "expert_load": jnp.sum(
            jnp.sum(choice_eh, axis=2).reshape(-1, e), axis=0),
        "dropped": dropped,
    }
    return y, aux
