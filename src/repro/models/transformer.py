"""Model assembly: decoder-only LM, encoder-decoder, and VLM wrappers.

Layers run as a python loop over ``num_groups`` pattern groups (straight-line
HLO: best overlap and honest ``cost_analysis``) or as ``lax.scan`` over
stacked group params (compact HLO for very deep configs) — ``scan_layers``
selects.  Activation remat wraps each group when ``cfg.remat``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import ModelConfig, dense_init, rms_norm, softcap
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, cfg.num_groups + 4)
    params: dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), in_axis=1,
                            dtype=cfg.param_dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                       dtype=cfg.param_dtype)
    groups = []
    for g in range(cfg.num_groups):
        gk = jax.random.split(ks[2 + g], cfg.group_size)
        groups.append({f"b{i}": blk.init_block(gk[i], cfg, kind)
                       for i, kind in enumerate(cfg.block_pattern)})
    params["groups"] = groups
    if cfg.tail_pattern:
        tk = jax.random.split(jax.random.fold_in(key, 999),
                              len(cfg.tail_pattern))
        params["tail"] = {f"b{i}": blk.init_block(tk[i], cfg, kind)
                          for i, kind in enumerate(cfg.tail_pattern)}
    if cfg.enc_layers:
        ek = jax.random.split(ks[-1], cfg.enc_layers + 2)
        params["enc_frontend"] = dense_init(
            ek[0], (cfg.frontend_dim, cfg.d_model), dtype=cfg.param_dtype)
        params["encoder"] = [blk.init_block(ek[1 + i], cfg, "encoder")
                             for i in range(cfg.enc_layers)]
        params["enc_norm"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    elif cfg.frontend_dim:      # vlm: patch-embedding projector
        params["frontend"] = dense_init(
            ks[-1], (cfg.frontend_dim, cfg.d_model), dtype=cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.compute_dtype))
    return constrain(x, "batch", "seq", "embed")


def _unembed(params, x, cfg: ModelConfig):
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(cfg.compute_dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.logits_softcap)
    return constrain(logits, "batch", "seq", "vocab")


def _group_fn(gparams, x, positions, cfg: ModelConfig, *, memory=None,
              memory_positions=None, local_impl="mask", pattern=None):
    aux_sum = None
    for i, kind in enumerate(pattern or cfg.block_pattern):
        x, aux = blk.apply_block(
            gparams[f"b{i}"], x, positions, cfg, kind, memory=memory,
            memory_positions=memory_positions, local_impl=local_impl)
        if aux:
            aux_sum = aux if aux_sum is None else jax.tree.map(
                jnp.add, aux_sum, aux)
    return x, aux_sum


def encode(params, frontend_feats, cfg: ModelConfig):
    """Encoder stack over precomputed (stubbed) frontend embeddings."""
    x = (frontend_feats.astype(cfg.compute_dtype)
         @ params["enc_frontend"].astype(cfg.compute_dtype))
    x = constrain(x, "batch", "seq", "embed")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for p in params["encoder"]:
        x, _ = blk.apply_block(p, x, positions, cfg, "encoder")
    return rms_norm(x, params["enc_norm"])


def forward(params, batch: dict, cfg: ModelConfig, *, scan_layers=False,
            local_impl="mask"):
    """Full-sequence forward -> (logits, aux).

    batch keys: "tokens" (B,S) int32; optional "frontend" (B,Sf,frontend_dim)
    (audio frames / vision patches, precomputed per the assignment stub);
    optional "positions".
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(params, tokens, cfg)
    memory = memory_positions = None
    if cfg.enc_layers:
        memory = encode(params, batch["frontend"], cfg)
        mp = memory.shape[1]
        memory_positions = jnp.broadcast_to(jnp.arange(mp)[None], (b, mp))
    elif cfg.frontend_dim:
        prefix = (batch["frontend"].astype(cfg.compute_dtype)
                  @ params["frontend"].astype(cfg.compute_dtype))
        x = jnp.concatenate([prefix, x], axis=1)
        s = x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    gfn = functools.partial(_group_fn, cfg=cfg, memory=memory,
                            memory_positions=memory_positions,
                            local_impl=local_impl)
    if cfg.remat:
        gfn = jax.checkpoint(gfn, static_argnums=())
    aux_total = None
    if scan_layers:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params["groups"])

        def body(carry, gparams):
            y, aux = gfn(gparams, carry, positions)
            return y, aux
        x, auxs = jax.lax.scan(body, x, stacked)
        aux_total = None if auxs is None else jax.tree.map(
            lambda a: jnp.sum(a, axis=0), auxs)
    else:
        for gparams in params["groups"]:
            x, aux = gfn(gparams, x, positions)
            if aux:
                aux_total = aux if aux_total is None else jax.tree.map(
                    jnp.add, aux_total, aux)
    if cfg.tail_pattern:
        tfn = functools.partial(_group_fn, cfg=cfg, memory=memory,
                                memory_positions=memory_positions,
                                local_impl=local_impl,
                                pattern=cfg.tail_pattern)
        if cfg.remat:
            tfn = jax.checkpoint(tfn)
        x, aux = tfn(params["tail"], x, positions)
        if aux:
            aux_total = aux if aux_total is None else jax.tree.map(
                jnp.add, aux_total, aux)
    logits = _unembed(params, x, cfg)
    return logits, (aux_total or {})


def lm_loss(params, batch: dict, cfg: ModelConfig, **fw_kwargs):
    """Next-token cross entropy (mean over non-pad tokens) + MoE aux loss."""
    logits, aux = forward(params, batch, cfg, **fw_kwargs)
    tokens = batch["tokens"]
    if cfg.frontend_dim and not cfg.enc_layers:    # vlm: skip patch prefix
        logits = logits[:, -tokens.shape[1]:]
    targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
    mask = (targets >= 0) & (batch.get("mask", jnp.ones_like(tokens)) > 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, jnp.maximum(targets, 0)[..., None], axis=-1)[..., 0]
    nll = (logz - tgt) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    metrics = {"loss": loss, "tokens": jnp.sum(mask)}
    if "aux_loss" in aux:
        loss = loss + aux["aux_loss"]
        metrics["moe_aux"] = aux["aux_loss"]
        metrics["moe_dropped"] = aux.get("dropped", 0)
        metrics["expert_load"] = aux.get("expert_load")
    return loss, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int) -> list:
    state = []
    for g in range(cfg.num_groups):
        state.append({f"b{i}": blk.init_block_state(cfg, kind, batch,
                                                    cache_len)
                      for i, kind in enumerate(cfg.block_pattern)})
    if cfg.tail_pattern:
        state.append({f"b{i}": blk.init_block_state(cfg, kind, batch,
                                                    cache_len)
                      for i, kind in enumerate(cfg.tail_pattern)})
    return state


def decode_step(params, tokens, pos, state, cfg: ModelConfig, *,
                memory=None):
    """One token for every sequence.  tokens: i32[B]; pos: i32[B].

    Returns (logits f32[B,V], new_state).  ``memory``: (k, v) pair or encoder
    output for enc-dec cross attention (projected per block on the fly).
    """
    x = jnp.take(params["embed"], tokens[:, None],
                 axis=0).astype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.compute_dtype))
    x = constrain(x, "batch", None, "embed")
    new_state = []
    group_list = [(gp, cfg.block_pattern) for gp in params["groups"]]
    if cfg.tail_pattern:
        group_list.append((params["tail"], cfg.tail_pattern))
    for g, (gparams, pattern) in enumerate(group_list):
        gs = dict(state[g])
        for i, kind in enumerate(pattern):
            mem = None
            if kind == "cross" and memory is not None:
                mem = memory
            x, gs[f"b{i}"] = blk.step_block(gparams[f"b{i}"], x, pos,
                                            state[g][f"b{i}"], cfg, kind,
                                            memory=mem)
        new_state.append(gs)
    logits = _unembed(params, x, cfg)
    return logits[:, 0], new_state


def cross_memory(params, cfg: ModelConfig, frontend_feats):
    """Precompute encoder memory K/V inputs for enc-dec decode."""
    mem = encode(params, frontend_feats, cfg)
    b, s, _ = mem.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return mem, positions
