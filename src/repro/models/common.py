"""Shared model-substrate pieces: config, init helpers, norms, RoPE."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers all ten assigned families; unused fields are inert.

    ``block_pattern`` is the repeating block-type cycle; layers are scanned in
    groups of ``len(block_pattern)`` (e.g. gemma3 = 5×"local"+1×"dense",
    recurrentgemma = 2×"rglru"+1×"local", rwkv6 = 1×"rwkv").
    """

    name: str = "model"
    family: str = "dense"            # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int | None = None      # default d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    block_pattern: tuple[str, ...] = ("dense",)
    window: int = 1024               # local-attention window
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 2
    dense_residual: bool = False     # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # recurrent families
    lru_width: int | None = None     # rg-lru width (default d_model)
    conv_width: int = 4              # rg-lru temporal conv
    rwkv_head_dim: int = 64
    chunk_size: int = 128            # chunked linear-recurrence block length
    # encoder-decoder / multimodal frontends (stubbed per assignment)
    enc_layers: int = 0              # >0 => encoder-decoder
    frontend_dim: int = 0            # precomputed frame/patch embedding width
    num_prefix: int = 0              # vlm: patch-token prefix length
    # numerics / execution
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    logits_softcap: float = 0.0      # grok uses 30.0
    # beyond-paper perf levers (§Perf hillclimb; 0 = paper-faithful baseline)
    opt_level: int = 0               # >=1: extra sharding constraints on the
                                     # big recurrent/attention intermediates
    attn_qchunk: int = 0             # >0: blockwise causal attention with
                                     # this q-chunk (bounds the S² score set)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else (
            self.d_model // self.num_heads)

    @property
    def group_size(self) -> int:
        return len(self.block_pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.group_size

    @property
    def tail_pattern(self) -> tuple[str, ...]:
        """Leftover layers when the pattern doesn't divide num_layers
        (gemma3: 26 = 4×(5L+1G) + 2L; recurrentgemma: 26 = 8×(R,R,A)+R,R)."""
        return self.block_pattern[: self.num_layers % self.group_size]

    @property
    def lru(self) -> int:
        return self.lru_width or self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOP estimates)."""
        d, f, hd = self.d_model, self.d_ff, self.hd
        per_block = {}
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        mlp = 3 * d * f
        per_block["dense"] = attn + mlp + 2 * d
        per_block["local"] = per_block["dense"]
        per_block["moe"] = attn + d * self.num_experts \
            + self.num_experts * 3 * d * f + 2 * d \
            + (mlp if self.dense_residual else 0)
        r = self.lru
        per_block["rglru"] = (2 * d * r + self.conv_width * r + 3 * r
                              + r * d) + mlp + 2 * d
        nh = d // self.rwkv_head_dim
        per_block["rwkv"] = (5 * d * d + 2 * d * nh + d) \
            + (2 * d * (f // 1) + d * d) + 2 * d
        per_block["cross"] = 2 * attn + mlp + 3 * d
        n = 0
        for b in (self.block_pattern * self.num_groups + self.tail_pattern):
            n += per_block[b]
        n += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.enc_layers:
            n += self.enc_layers * per_block["dense"]
            n += self.frontend_dim * d
        if self.frontend_dim and not self.enc_layers:
            n += self.frontend_dim * d
        return n


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Fan-in scaled truncated-normal-ish init."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(
        jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]                             # (..., S, 1, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin,
    ], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x
