"""Recurrent temporal-mix blocks: RG-LRU (recurrentgemma) and RWKV-6.

Both are linear recurrences ``h_t = a_t ⊙ h_{t-1} + b_t`` with
data-dependent decay.  TPU adaptation: no token-level while-loops — the
RG-LRU uses ``jax.lax.associative_scan`` over the sequence, and RWKV-6 uses
the chunked form (intra-chunk matmuls on the MXU + an associative scan over
per-chunk state summaries).  This keeps the HLO loop-free, which matters for
two reasons: XLA overlaps/pipelines straight-line code far better than a
524288-trip while loop, and ``cost_analysis`` on a while body would not
multiply by the trip count, which would corrupt the roofline accounting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rms_norm
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma, arXiv:2402.19427)
# ---------------------------------------------------------------------------

_C = 8.0  # the paper's fixed scaling constant


def init_rglru(key, cfg: ModelConfig) -> dict:
    d, r, w = cfg.d_model, cfg.lru, cfg.conv_width
    ks = jax.random.split(key, 8)
    pdt = cfg.param_dtype
    return {
        "wx": dense_init(ks[0], (d, r), dtype=pdt),      # recurrence branch
        "wy": dense_init(ks[1], (d, r), dtype=pdt),      # gate branch
        "conv_w": dense_init(ks[2], (w, r), dtype=pdt),
        "conv_b": jnp.zeros((r,), pdt),
        # per-channel (diagonal) gates, as in the BlockDiagonalLinear of the
        # reference implementation collapsed to its diagonal
        "gate_a_w": dense_init(ks[3], (r,), dtype=pdt),
        "gate_a_b": jnp.zeros((r,), pdt),
        "gate_x_w": dense_init(ks[4], (r,), dtype=pdt),
        "gate_x_b": jnp.zeros((r,), pdt),
        # Λ parametrised so that a = exp(-C softplus(Λ)·sigmoid(r_t))
        "log_lambda": jnp.asarray(
            jnp.linspace(0.1, 0.9, r), pdt),
        "wo": dense_init(ks[5], (r, d), dtype=pdt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along S: x (B,S,R), w (W,R)."""
    width = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(width):
        shifted = jnp.pad(x, ((0, 0), (width - 1 - i, 0), (0, 0)))[
            :, : x.shape[1]]
        out = out + shifted * w[i]
    return out + b


def _rglru_scan(a, b):
    """h_t = a_t ⊙ h_{t-1} + b_t via associative scan over S (axis=1)."""
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2
    return jax.lax.associative_scan(combine, (a, b), axis=1)


def _rglru_gates(p, u, cfg: ModelConfig):
    f32 = jnp.float32
    r_t = jax.nn.sigmoid(u.astype(f32) * p["gate_a_w"].astype(f32)
                         + p["gate_a_b"].astype(f32))
    i_t = jax.nn.sigmoid(u.astype(f32) * p["gate_x_w"].astype(f32)
                         + p["gate_x_b"].astype(f32))
    log_a = -_C * jax.nn.softplus(p["log_lambda"].astype(f32)) * r_t
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i_t * u.astype(f32))
    return a, gated


def rglru_block(p, x, cfg: ModelConfig, state=None):
    """Full-sequence RG-LRU temporal mix.  x: (B,S,D) -> (B,S,D).

    ``state``: optional (B,R) initial hidden state (chained prefill); the
    final state is returned for decode handoff."""
    cdt = cfg.compute_dtype
    y = jax.nn.gelu(x @ p["wy"].astype(cdt))
    u = x @ p["wx"].astype(cdt)
    u = _causal_conv(u, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
    u = constrain(u, "batch", "seq", "lru")
    a, gated = _rglru_gates(p, u, cfg)
    if state is not None:
        # fold the carried state in as a virtual step-0 contribution
        gated = gated.at[:, 0].add(a[:, 0] * state.astype(jnp.float32))
    _, h = _rglru_scan(a, gated)
    h = constrain(h.astype(cdt), "batch", "seq", "lru")
    out = (h * y) @ p["wo"].astype(cdt)
    return constrain(out, "batch", "seq", "embed"), h[:, -1].astype(jnp.float32)


def rglru_step(p, x, state, cfg: ModelConfig):
    """One-token decode: x (B,1,D), state {'h': (B,R), 'conv': (B,W-1,R)}."""
    cdt = cfg.compute_dtype
    y = jax.nn.gelu(x @ p["wy"].astype(cdt))
    u = x @ p["wx"].astype(cdt)
    hist = jnp.concatenate([state["conv"], u], axis=1)       # (B,W,R)
    w = p["conv_w"].astype(cdt)
    u = jnp.einsum("bwr,wr->br", hist, w)[:, None] + p["conv_b"].astype(cdt)
    a, gated = _rglru_gates(p, u, cfg)
    h = a[:, 0] * state["h"] + gated[:, 0]
    out = (h[:, None].astype(cdt) * y) @ p["wo"].astype(cdt)
    return out, {"h": h, "conv": hist[:, 1:]}


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    return {"h": jnp.zeros((batch, cfg.lru), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru),
                              cfg.compute_dtype)}


# ---------------------------------------------------------------------------
# RWKV-6 "Finch" (arXiv:2404.05892) — data-dependent decay time mix
# ---------------------------------------------------------------------------

def init_rwkv_tmix(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = d // cfg.rwkv_head_dim
    ks = jax.random.split(key, 8)
    pdt = cfg.param_dtype
    return {
        "mix_r": jnp.full((d,), 0.5, pdt), "mix_k": jnp.full((d,), 0.5, pdt),
        "mix_v": jnp.full((d,), 0.5, pdt), "mix_w": jnp.full((d,), 0.5, pdt),
        "mix_g": jnp.full((d,), 0.5, pdt),
        "wr": dense_init(ks[0], (d, d), dtype=pdt),
        "wk": dense_init(ks[1], (d, d), dtype=pdt),
        "wv": dense_init(ks[2], (d, d), dtype=pdt),
        "wg": dense_init(ks[3], (d, d), dtype=pdt),
        # data-dependent decay: w_t = exp(-exp(ω + tanh(x W1) W2))
        "decay_base": jnp.full((d,), -6.0, pdt),
        "decay_w1": dense_init(ks[4], (d, 64), dtype=pdt),
        "decay_w2": dense_init(ks[5], (64, d), dtype=pdt),
        "bonus_u": dense_init(ks[6], (nh, cfg.rwkv_head_dim), dtype=pdt),
        "ln_x": jnp.zeros((d,), pdt),
        "wo": dense_init(ks[7], (d, d), dtype=pdt),
    }


def _token_shift(x, prev):
    """x_{t-1} stream; ``prev`` (B,1,D) is the carried last token (decode/
    chained prefill) or zeros."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv_project(p, x, prev, cfg: ModelConfig):
    cdt = cfg.compute_dtype
    xs = _token_shift(x, prev)
    mix = lambda m: x + (xs - x) * m.astype(cdt)
    r = mix(p["mix_r"]) @ p["wr"].astype(cdt)
    k = mix(p["mix_k"]) @ p["wk"].astype(cdt)
    v = mix(p["mix_v"]) @ p["wv"].astype(cdt)
    g = mix(p["mix_g"]) @ p["wg"].astype(cdt)
    dx = mix(p["mix_w"]).astype(jnp.float32)
    logw = -jnp.exp(p["decay_base"].astype(jnp.float32)
                    + jnp.tanh(dx @ p["decay_w1"].astype(jnp.float32))
                    @ p["decay_w2"].astype(jnp.float32))      # (B,S,D) ≤ 0
    return r, k, v, g, logw


def _heads(x, nh, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, nh, hd)


def rwkv_tmix(p, x, cfg: ModelConfig, state=None):
    """Chunked RWKV-6 time mix.  x: (B,S,D) -> (B,S,D).

    Per chunk of length c: intra-chunk attention-like matmuls with decay
    weights (exact, fp32 exponents masked to i ≤ t so they never overflow),
    inter-chunk via an associative scan over per-chunk (decay-product,
    state-update) summaries.  state: optional {'s': (B,NH,hd,hd),
    'prev': (B,1,D)} carried across calls."""
    b, s, d = x.shape
    c = min(cfg.chunk_size, s)
    assert s % c == 0, (s, c)
    nc = s // c
    nh = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    prev = state["prev"] if state is not None else jnp.zeros(
        (b, 1, d), x.dtype)
    s0 = state["s"] if state is not None else jnp.zeros(
        (b, nh, hd, hd), jnp.float32)

    r, k, v, g, logw = _rwkv_project(p, x, prev, cfg)
    f32 = jnp.float32
    rh = _heads(r, nh, hd).astype(f32).reshape(b, nc, c, nh, hd)
    kh = _heads(k, nh, hd).astype(f32).reshape(b, nc, c, nh, hd)
    vh = _heads(v, nh, hd).astype(f32).reshape(b, nc, c, nh, hd)
    lw = logw.reshape(b, nc, c, nh, hd)
    if cfg.opt_level >= 1:
        # pin the chunked tensors to (batch=dp, heads=tp): without this the
        # partitioner replicates the O(B·S·D) f32 intermediates over 'model'
        hx = lambda t: constrain(t, "batch", None, None, "rwkv_heads", None)
        rh, kh, vh, lw = hx(rh), hx(kh), hx(vh), hx(lw)

    lsum = jnp.cumsum(lw, axis=2)                   # L_t inclusive, ≤ 0, ↓
    ltot = lsum[:, :, -1]                           # (B,nc,NH,hd)
    lprev = lsum - lw                               # L_{t-1} (exclusive)
    # ----- intra-chunk: o_t += Σ_{i<t} (r_t · e^{L_{t-1}-L_i} ⊙ k_i) v_i.
    # The decay weight is per-channel, so A cannot be one matmul; we tile the
    # chunk into sub-chunks of m and assemble A block-wise.  Off-diagonal
    # blocks (key sub-chunk strictly earlier) factor EXACTLY through the key
    # sub-chunk's boundary decay M: e^{L_{t-1}-L_i} = e^{L_{t-1}-M}·e^{M-L_i}
    # with both exponents ≤ 0 (L is non-increasing) — no overflow, no
    # approximation.  Diagonal blocks materialise the (m,m,hd) exponent with
    # the i<t mask applied before exp (argument ≤ 0, equally safe).
    m = min(16, c)
    nsc = c // m
    shp = (b, nc, nsc, m, nh, hd)
    rs, ks_, vs = rh.reshape(shp), kh.reshape(shp), vh.reshape(shp)
    lps, lss = lprev.reshape(shp), lsum.reshape(shp)
    mbound = lss[:, :, :, -1]                       # (B,nc,nsc,NH,hd)
    tri_m = jnp.tril(jnp.ones((m, m), bool), k=-1)[None, None, :, :, None,
                                                   None]
    blocks = []
    for ti in range(nsc):
        row = []
        for si in range(nsc):
            if si > ti:
                row.append(jnp.zeros((b, nc, nh, m, m), f32))
            elif si == ti:
                diff = lps[:, :, ti, :, None] - lss[:, :, si, None, :]
                w_pair = jnp.where(tri_m, jnp.exp(jnp.minimum(diff, 0.0)),
                                   0.0)
                row.append(jnp.einsum(
                    "btihd,bihd->bhti",
                    (w_pair * rs[:, :, ti, :, None]).reshape(
                        b * nc, m, m, nh, hd),
                    ks_[:, :, si].reshape(b * nc, m, nh, hd),
                ).reshape(b, nc, nh, m, m))
            else:
                mb = mbound[:, :, si]               # (B,nc,NH,hd)
                qt = rs[:, :, ti] * jnp.exp(lps[:, :, ti] - mb[:, :, None])
                kt = ks_[:, :, si] * jnp.exp(mb[:, :, None] - lss[:, :, si])
                row.append(jnp.einsum("bnthd,bnihd->bnhti", qt, kt))
        blocks.append(jnp.concatenate(row, axis=-1))
    att = jnp.concatenate(blocks, axis=-2)          # (B,nc,NH,c,c)
    if cfg.opt_level >= 1:
        att = constrain(att, "batch", None, "rwkv_heads", None, None)
    # bonus (u) diagonal term: i == t
    bonus = jnp.einsum("bnthd,bnthd->bnht", rh * p["bonus_u"].astype(f32),
                       kh)
    intra = jnp.einsum("bnhti,bnihd->bnthd", att, vh) \
        + bonus.transpose(0, 1, 3, 2)[..., None] * vh
    # ----- inter-chunk: per-chunk state summary then associative scan
    # chunk update: S_end = e^{ltot} ⊙_rows S_start + Σ_i e^{ltot-L_i} k_i v_iᵀ
    kdec = kh * jnp.exp(ltot[:, :, None] - lsum)    # (B,nc,c,NH,hd)
    upd = jnp.einsum("bnchk,bnchv->bnhkv", kdec,
                     vh)                            # (B,nc,NH,hd,hd)
    adec = jnp.exp(ltot)                            # (B,nc,NH,hd)

    def combine(left, right):
        a1, u1 = left
        a2, u2 = right
        return a1 * a2, a2[..., None] * u1 + u2

    a_pfx, u_pfx = jax.lax.associative_scan(combine, (adec, upd), axis=1)
    # state at the *start* of each chunk (exclusive prefix, seeded with s0)
    s_starts = jnp.concatenate([
        s0[:, None],
        a_pfx[:, :-1, :, :, None] * s0[:, None] + u_pfx[:, :-1]], axis=1)
    rdec = rh * jnp.exp(lprev)                      # r̃_t = r_t e^{L_{t-1}}
    inter = jnp.einsum("bnchk,bnhkv->bnchv", rdec, s_starts)
    o = (intra + inter).reshape(b, s, nh, hd)
    s_final = a_pfx[:, -1, :, :, None] * s0 + u_pfx[:, -1]
    # group norm per head + gate
    o = rms_norm(o, p["ln_x"].reshape(nh, hd)).reshape(b, s, d)
    out = (o.astype(cfg.compute_dtype) * jax.nn.silu(g)) \
        @ p["wo"].astype(cfg.compute_dtype)
    out = constrain(out, "batch", "seq", "embed")
    return out, {"s": s_final, "prev": x[:, -1:]}


def rwkv_tmix_step(p, x, state, cfg: ModelConfig):
    """One-token decode.  x: (B,1,D)."""
    b, _, d = x.shape
    nh = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    r, k, v, g, logw = _rwkv_project(p, x, state["prev"], cfg)
    f32 = jnp.float32
    rh = _heads(r, nh, hd)[:, 0].astype(f32)
    kh = _heads(k, nh, hd)[:, 0].astype(f32)
    vh = _heads(v, nh, hd)[:, 0].astype(f32)
    w = jnp.exp(logw[:, 0].reshape(b, nh, hd))
    s_prev = state["s"]
    kv = kh[..., :, None] * vh[..., None, :]          # (B,NH,hd,hd)
    o = jnp.einsum("bhk,bhkv->bhv", rh,
                   s_prev + p["bonus_u"].astype(f32)[..., None] * kv)
    s_new = w[..., None] * s_prev + kv
    o = rms_norm(o, p["ln_x"].reshape(nh, hd)).reshape(b, 1, d)
    out = (o.astype(cfg.compute_dtype) * jax.nn.silu(g)) \
        @ p["wo"].astype(cfg.compute_dtype)
    return out, {"s": s_new, "prev": x}


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    nh = d // cfg.rwkv_head_dim
    return {"s": jnp.zeros((batch, nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                           jnp.float32),
            "prev": jnp.zeros((batch, 1, d), cfg.compute_dtype)}


def init_rwkv_cmix(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    pdt = cfg.param_dtype
    return {
        "mix_k": jnp.full((d,), 0.5, pdt),
        "mix_r": jnp.full((d,), 0.5, pdt),
        "wk": dense_init(ks[0], (d, f), dtype=pdt),
        "wv": dense_init(ks[1], (f, d), dtype=pdt),
        "wr": dense_init(jax.random.fold_in(key, 7), (d, d), dtype=pdt),
    }


def rwkv_cmix(p, x, cfg: ModelConfig, prev=None):
    """Channel mix (the RWKV FFN) with token shift."""
    cdt = cfg.compute_dtype
    prev = prev if prev is not None else jnp.zeros_like(x[:, :1])
    xs = _token_shift(x, prev)
    mix = lambda m: x + (xs - x) * m.astype(cdt)
    k = jnp.square(jax.nn.relu(mix(p["mix_k"]) @ p["wk"].astype(cdt)))
    k = constrain(k, "batch", "seq", "mlp")
    r = jax.nn.sigmoid(mix(p["mix_r"]) @ p["wr"].astype(cdt))
    out = r * (k @ p["wv"].astype(cdt))
    return constrain(out, "batch", "seq", "embed"), x[:, -1:]
