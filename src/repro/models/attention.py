"""Attention substrate: GQA with RoPE, qk-norm, bias, local windows, caches.

Covers every assigned attention variant:
  * MHA / GQA with arbitrary kv_heads (deepseek 32, qwen1.5 20, qwen3 8, ...)
  * qk_norm (qwen3), QKV bias (qwen1.5), logit softcap (grok)
  * sliding-window ("local") attention with either a banded mask (baseline)
    or exact chunked evaluation (optimised path for long prefill)
  * bidirectional encoder attention and cross attention (seamless enc-dec)
  * decode against a KV cache, including the sequence-sharded two-pass
    flash-decode combine used when the cache is sharded over the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rms_norm, rope, softcap
from repro.sharding.api import constrain

NEG_INF = -2.3819763e38


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=cfg.param_dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=cfg.param_dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=cfg.param_dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype=cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((kv * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((kv * hd,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.param_dtype)
    return p


def _project_q(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    q = x @ p["wq"].astype(cfg.compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cfg.compute_dtype)
    q = q.reshape(b, s, cfg.num_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    q = rope(q, positions, cfg.rope_theta)
    return constrain(q, "batch", "seq", "heads", "head_dim")


def _project_kv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    k = x @ p["wk"].astype(cfg.compute_dtype)
    v = x @ p["wv"].astype(cfg.compute_dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(cfg.compute_dtype)
        v = v + p["bv"].astype(cfg.compute_dtype)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    if positions is not None:
        k = rope(k, positions, cfg.rope_theta)
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Grouped scaled dot-product attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); mask: broadcastable to
    (B, KV, G, Sq, Sk) or None.

    opt_level>=1 switches to the repeated-KV layout: scores carry the full
    H head dim (shardable over the model axis even when KV < TP degree —
    the grouped (KV, G) layout replicates the O(S²) scores whenever KV
    doesn't divide the axis, which is every GQA arch here).  The repeat
    costs O(S·H·hd) extra KV bytes — negligible next to O(H·S²) scores.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    if cfg.opt_level >= 1:
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q = q * (hd ** -0.5)
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                            preferred_element_type=jnp.float32)
        scores = softcap(scores, cfg.logits_softcap)
        if mask is not None:
            scores = jnp.where(
                jnp.broadcast_to(mask, (b, kv, g) + scores.shape[-2:])
                .reshape(b, h, *scores.shape[-2:])
                if mask.shape[1:3] != (1, 1) else mask.reshape(
                    mask.shape[0], 1, *mask.shape[-2:]),
                scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", probs, v)
    q = q.reshape(b, sq, kv, g, hd) * (hd ** -0.5)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = softcap(scores, cfg.logits_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, h, hd)


def _band_mask(q_pos, k_pos, window: int | None, causal: bool):
    """(B?, Sq, Sk) boolean mask; window is the local-attention band."""
    m = jnp.ones(jnp.broadcast_shapes(q_pos[..., :, None].shape,
                                      k_pos[..., None, :].shape), bool)
    if causal:
        m &= q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        m &= q_pos[..., :, None] - k_pos[..., None, :] < window
    return m


def attention(p, x, positions, cfg: ModelConfig, *, window: int | None,
              causal: bool = True, kv_x=None, kv_positions=None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).  ``kv_x`` switches to
    cross attention (keys/values from encoder memory, no causal mask)."""
    q = _project_q(p, x, cfg, positions)
    if kv_x is None:
        k, v = _project_kv(p, x, cfg, positions)
        mask = _band_mask(positions, positions, window, causal)
    else:
        k, v = _project_kv(p, kv_x, cfg, kv_positions)
        mask = None
    if mask is not None:
        mask = mask[:, None, None]            # (B, 1, 1, Sq, Sk)
    out = _sdpa(q, k, v, mask, cfg)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    b, s, _, _ = out.shape
    y = out.reshape(b, s, -1) @ p["wo"].astype(cfg.compute_dtype)
    return constrain(y, "batch", "seq", "embed")


def attention_blockwise(p, x, positions, cfg: ModelConfig, *,
                        q_chunk: int, window: int | None = None,
                        causal: bool = True) -> jnp.ndarray:
    """Exact full attention evaluated per q-chunk (flash-style, jnp-level).

    The (Sq, Sk) score matrix is never materialised whole — only
    (q_chunk, Sk) slabs, unrolled as straight-line HLO (no while loop, so
    ``cost_analysis`` stays faithful and XLA can overlap slabs).  Causal
    chunks additionally skip keys beyond the chunk's last query.  This is
    the optimised path for long-prefill dense archs where the S² scores of
    the naive path dominate the memory term (§Perf)."""
    b, s, _ = x.shape
    q = _project_q(p, x, cfg, positions)
    k, v = _project_kv(p, x, cfg, positions)
    nq = -(-s // q_chunk)
    outs = []
    for i in range(nq):
        lo, hi = i * q_chunk, min((i + 1) * q_chunk, s)
        qp = positions[:, lo:hi]
        k_hi = hi if causal else s      # causal: keys beyond hi are masked
        mask = _band_mask(qp, positions[:, :k_hi], window, causal)
        outs.append(_sdpa(q[:, lo:hi], k[:, :k_hi], v[:, :k_hi],
                          mask[:, None, None], cfg))
    out = jnp.concatenate(outs, axis=1)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    y = out.reshape(b, s, -1) @ p["wo"].astype(cfg.compute_dtype)
    return constrain(y, "batch", "seq", "embed")


def attention_chunked_local(p, x, positions, cfg: ModelConfig, *,
                            window: int) -> jnp.ndarray:
    """Exact sliding-window attention in O(S·w) instead of O(S²).

    The sequence is cut into chunks of length ``window``; each chunk attends
    to itself and its predecessor under the banded mask — exact for causal
    windows ≤ chunk length.  This is the optimised path for long local
    prefill (gemma3 32k: 32× less attention compute than the banded mask
    over full S²)."""
    b, s, d = x.shape
    w = window
    assert s % w == 0 and s >= 2 * w, (s, w)
    q = _project_q(p, x, cfg, positions)
    k, v = _project_kv(p, x, cfg, positions)
    nc = s // w
    # (B, nc, w, H, hd); keys get a 2-window tail: [prev chunk | this chunk]
    qc = q.reshape(b, nc, w, cfg.num_heads, cfg.hd)
    kc = k.reshape(b, nc, w, cfg.num_kv_heads, cfg.hd)
    vc = v.reshape(b, nc, w, cfg.num_kv_heads, cfg.hd)
    k2 = jnp.concatenate([jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0),
                                               (0, 0))), kc], axis=2)
    v2 = jnp.concatenate([jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0),
                                               (0, 0))), vc], axis=2)
    pc = positions.reshape(b, nc, w)
    p2 = jnp.concatenate([jnp.pad(pc[:, :-1], ((0, 0), (1, 0), (0, 0)),
                                  constant_values=-10**9), pc], axis=2)
    mask = _band_mask(pc, p2, w, causal=True)[:, :, None, None]  # B,nc,1,1,w,2w
    bn = b * nc
    out = _sdpa(qc.reshape(bn, w, cfg.num_heads, cfg.hd),
                k2.reshape(bn, 2 * w, cfg.num_kv_heads, cfg.hd),
                v2.reshape(bn, 2 * w, cfg.num_kv_heads, cfg.hd),
                mask.reshape(bn, 1, 1, w, 2 * w), cfg)
    y = out.reshape(b, s, cfg.num_heads * cfg.hd) @ p["wo"].astype(
        cfg.compute_dtype)
    return constrain(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# decode (one new token against a cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, length: int,
                  dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    shape = (batch, length, cfg.num_kv_heads, cfg.hd)
    return {
        "k": constrain(jnp.zeros(shape, dtype),
                       "batch", "cache_seq", "kv_heads", "head_dim"),
        "v": constrain(jnp.zeros(shape, dtype),
                       "batch", "cache_seq", "kv_heads", "head_dim"),
    }


def decode_attention(p, x, pos, cache, cfg: ModelConfig, *,
                     window: int | None, kv_memory=None) -> tuple:
    """One-token decode step.  ``pos``: i32[B] absolute positions.

    The new (k, v) is written at ``pos % cache_len`` (ring semantics for
    local windows, linear for full caches — callers size the cache
    accordingly).  Attention itself runs over the full cache with a validity
    mask, so the same code serves both layouts; when the cache's sequence
    dim is sharded over the model axis, XLA partitions the softmax
    reductions into the two-pass flash-decode combine (see
    serve/decode_sharded.py for the explicit shard_map variant)."""
    b = x.shape[0]
    positions = pos[:, None]                     # (B, 1)
    q = _project_q(p, x, cfg, positions)
    if kv_memory is not None:                    # cross attention: no cache
        k, v = kv_memory
        out = _sdpa(q, k, v, None, cfg)
        y = out.reshape(b, 1, -1) @ p["wo"].astype(cfg.compute_dtype)
        return constrain(y, "batch", None, "embed"), cache
    k_new, v_new = _project_kv(p, x, cfg, positions)
    length = cache["k"].shape[1]
    slot = (pos % length).astype(jnp.int32)      # (B,)
    rows = jnp.arange(b)
    k = constrain(cache["k"].at[rows, slot].set(k_new[:, 0]),
                  "batch", "cache_seq", "kv_heads", "head_dim")
    v = constrain(cache["v"].at[rows, slot].set(v_new[:, 0]),
                  "batch", "cache_seq", "kv_heads", "head_dim")
    # validity: cache slot s holds absolute position p_s; with ring writes
    # p_s = s + length*floor((pos-s-1)/length + 1)... for the dry-run step we
    # mask by "slot was written and within window".
    slots = jnp.arange(length)[None, :]          # (1, L)
    written = slots <= pos[:, None]              # linear-fill semantics
    if window is not None:
        written &= slots > pos[:, None] - window
    mask = written[:, None, None, None, :]       # (B,1,1,1,L)
    out = _sdpa(q, k, v, mask, cfg)
    y = out.reshape(b, 1, -1) @ p["wo"].astype(cfg.compute_dtype)
    y = constrain(y, "batch", None, "embed")
    return y, {"k": k, "v": v}
