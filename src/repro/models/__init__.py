"""Model substrate: composable blocks covering all assigned families."""
from repro.models.common import ModelConfig
from repro.models import attention, blocks, moe, recurrent, transformer
from repro.models.transformer import (cross_memory, decode_step, forward,
                                      init_decode_state, init_lm, lm_loss)

__all__ = [
    "ModelConfig", "attention", "blocks", "moe", "recurrent", "transformer",
    "cross_memory", "decode_step", "forward", "init_decode_state", "init_lm",
    "lm_loss",
]
