"""Block assembly: one residual block per ``block_pattern`` entry.

Block types:
  "dense"  — pre-norm GQA attention + SwiGLU MLP (llama family)
  "local"  — same with sliding-window attention (gemma3, recurrentgemma)
  "moe"    — attention + top-k MoE FFN (grok; arctic via dense_residual)
  "rglru"  — RG-LRU temporal mix + SwiGLU MLP (recurrentgemma)
  "rwkv"   — RWKV-6 time mix + channel mix
  "cross"  — self-attention + cross-attention + MLP (enc-dec decoder)
  "encoder"— bidirectional attention + MLP (enc-dec encoder)

Every block exposes init / apply (full sequence) / step (one-token decode
with explicit state) so the same definitions serve train, prefill and decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models.common import ModelConfig, dense_init, rms_norm
from repro.sharding.api import constrain


def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    pdt = cfg.param_dtype
    return {
        "gate": dense_init(ks[0], (d, f), dtype=pdt),
        "up": dense_init(ks[1], (d, f), dtype=pdt),
        "down": dense_init(ks[2], (f, d), dtype=pdt),
    }


def mlp(p, x, cfg: ModelConfig):
    cdt = cfg.compute_dtype
    h = jax.nn.silu(x @ p["gate"].astype(cdt)) * (x @ p["up"].astype(cdt))
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(h @ p["down"].astype(cdt), "batch", "seq", "embed")


def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    pdt = cfg.param_dtype
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), pdt), "ln2": jnp.zeros((d,), pdt)}
    if kind in ("dense", "local", "moe", "encoder"):
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["ffn"] = moe_lib.init_moe(ks[1], cfg) if kind == "moe" \
            else init_mlp(ks[1], cfg)
    elif kind == "cross":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["xattn"] = attn_lib.init_attention(ks[1], cfg, cross=True)
        p["ln_x"] = jnp.zeros((d,), pdt)
        p["ffn"] = init_mlp(ks[2], cfg)
    elif kind == "rglru":
        p["mix"] = rec_lib.init_rglru(ks[0], cfg)
        p["ffn"] = init_mlp(ks[1], cfg)
    elif kind == "rwkv":
        p["ln0"] = jnp.zeros((d,), pdt)  # unused except layer 0 by convention
        p["mix"] = rec_lib.init_rwkv_tmix(ks[0], cfg)
        p["ffn"] = rec_lib.init_rwkv_cmix(ks[1], cfg)
    else:
        raise ValueError(kind)
    return p


def apply_block(p, x, positions, cfg: ModelConfig, kind: str, *,
                memory=None, memory_positions=None, local_impl: str = "mask"):
    """Full-sequence forward.  Returns (y, aux)."""
    aux = {}
    # residual stream lives seq-sharded under SP; intra-block tensors are
    # all-gathered/TP'd and the exit constraint reduce-scatters back
    x = constrain(x, "batch", "resid_seq", "embed")
    # pin the post-norm tensor seq-sharded too: otherwise the partitioner
    # may all-gather the f32 upcast inside the norm (2x wire, 16x redundant
    # norm compute) instead of the bf16 output at the consuming matmul
    h = constrain(rms_norm(x, p["ln1"]), "batch", "resid_seq", "embed")
    if kind in ("dense", "moe"):
        if cfg.attn_qchunk and x.shape[1] > cfg.attn_qchunk:
            a = attn_lib.attention_blockwise(p["attn"], h, positions, cfg,
                                             q_chunk=cfg.attn_qchunk)
        else:
            a = attn_lib.attention(p["attn"], h, positions, cfg, window=None)
    elif kind == "local":
        if local_impl == "chunked" and x.shape[1] % cfg.window == 0 \
                and x.shape[1] >= 2 * cfg.window:
            a = attn_lib.attention_chunked_local(p["attn"], h, positions, cfg,
                                                 window=cfg.window)
        else:
            a = attn_lib.attention(p["attn"], h, positions, cfg,
                                   window=cfg.window)
    elif kind == "encoder":
        a = attn_lib.attention(p["attn"], h, positions, cfg, window=None,
                               causal=False)
    elif kind == "cross":
        a = attn_lib.attention(p["attn"], h, positions, cfg, window=None)
    elif kind == "rglru":
        a, _ = rec_lib.rglru_block(p["mix"], h, cfg)
    elif kind == "rwkv":
        a, _ = rec_lib.rwkv_tmix(p["mix"], h, cfg)
    else:
        raise ValueError(kind)
    x = x + a
    if kind == "cross":
        hx = rms_norm(x, p["ln_x"])
        x = x + attn_lib.attention(p["xattn"], hx, positions, cfg, window=None,
                                   kv_x=memory, kv_positions=memory_positions)
    h2 = constrain(rms_norm(x, p["ln2"]), "batch", "resid_seq", "embed")
    if kind == "moe":
        f, aux = moe_lib.moe_ffn(p["ffn"], h2, cfg)
    elif kind == "rwkv":
        f, _ = rec_lib.rwkv_cmix(p["ffn"], h2, cfg)
    else:
        f = mlp(p["ffn"], h2, cfg)
    return constrain(x + f, "batch", "resid_seq", "embed"), aux


# ---------------------------------------------------------------------------
# decode: explicit per-block state
# ---------------------------------------------------------------------------

def init_block_state(cfg: ModelConfig, kind: str, batch: int,
                     cache_len: int, memory=None) -> dict:
    if kind in ("dense", "moe", "encoder"):
        return {"kv": attn_lib.init_kv_cache(cfg, batch, cache_len)}
    if kind == "local":
        return {"kv": attn_lib.init_kv_cache(cfg, batch,
                                             min(cfg.window, cache_len))}
    if kind == "cross":
        return {"kv": attn_lib.init_kv_cache(cfg, batch, cache_len)}
    if kind == "rglru":
        return {"rec": rec_lib.init_rglru_state(cfg, batch)}
    if kind == "rwkv":
        return {"rec": rec_lib.init_rwkv_state(cfg, batch),
                "cmix_prev": jnp.zeros((batch, 1, cfg.d_model),
                                       cfg.compute_dtype)}
    raise ValueError(kind)


def step_block(p, x, pos, state, cfg: ModelConfig, kind: str, *,
               memory=None):
    """One-token decode.  x: (B,1,D), pos: i32[B].  Returns (y, new_state)."""
    h = rms_norm(x, p["ln1"])
    new_state = dict(state)
    if kind in ("dense", "moe", "encoder"):
        a, new_state["kv"] = attn_lib.decode_attention(
            p["attn"], h, pos, state["kv"], cfg, window=None)
    elif kind == "local":
        a, new_state["kv"] = attn_lib.decode_attention(
            p["attn"], h, pos, state["kv"], cfg, window=cfg.window)
    elif kind == "cross":
        a, new_state["kv"] = attn_lib.decode_attention(
            p["attn"], h, pos, state["kv"], cfg, window=None)
    elif kind == "rglru":
        a, new_state["rec"] = rec_lib.rglru_step(p["mix"], h, state["rec"],
                                                 cfg)
    elif kind == "rwkv":
        a, new_state["rec"] = rec_lib.rwkv_tmix_step(p["mix"], h,
                                                     state["rec"], cfg)
    else:
        raise ValueError(kind)
    x = x + a
    if kind == "cross":
        hx = rms_norm(x, p["ln_x"])
        mem_x, mem_pos = memory
        kv = attn_lib._project_kv(p["xattn"], mem_x, cfg, mem_pos)
        y, _ = attn_lib.decode_attention(p["xattn"], hx, pos, state["kv"],
                                         cfg, window=None, kv_memory=kv)
        x = x + y
    h2 = rms_norm(x, p["ln2"])
    if kind == "moe":
        f, _ = moe_lib.moe_ffn(p["ffn"], h2, cfg)
    elif kind == "rwkv":
        f, new_state["cmix_prev"] = rec_lib.rwkv_cmix(
            p["ffn"], h2, cfg, prev=state["cmix_prev"])
    else:
        f = mlp(p["ffn"], h2, cfg)
    return x + f, new_state
