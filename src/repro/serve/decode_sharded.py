"""Explicit sequence-sharded flash-decode via shard_map.

When the KV cache's sequence dim is sharded over the ``model`` axis, each
chip attends over its local cache slice and the partial softmaxes are
combined with the numerically-stable two-pass rule:

    m  = psum-max of local max
    l  = psum of exp(local_max - m) · local_sum
    o  = psum of exp(local_max - m) · local_weighted_V   / l

GSPMD derives an equivalent program from the jnp path in
``attention.decode_attention``; this explicit version pins the collective
schedule (3 small psums instead of whatever the partitioner picks) and is
the decode-cell §Perf lever.  Works for any kv_heads (no head-divisibility
constraint) — the reason sequence sharding is the default decode layout
(DESIGN §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig


def flash_decode_local(q, k_local, v_local, valid_local, axis_name: str):
    """One-token attention over a sequence-sharded cache.

    q: (B, 1, H, hd) replicated over ``axis_name``;
    k_local/v_local: (B, L/n, KV, hd); valid_local: (B, L/n) bool.
    Returns (B, 1, H, hd), replicated.
    """
    b, _, h, hd = q.shape
    kv = k_local.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd) * (hd ** -0.5)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_local,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid_local[:, None, None, :], s, -jnp.inf)
    m_loc = jnp.max(s, axis=-1, keepdims=True)              # (B,KV,G,1)
    m = jax.lax.pmax(m_loc, axis_name)
    # guard fully-masked shards: exp(-inf - m) -> 0
    w = jnp.exp(jnp.where(jnp.isfinite(s), s - m, -jnp.inf))
    l_loc = jnp.sum(w, axis=-1, keepdims=True)
    o_loc = jnp.einsum("bkgs,bskh->bkgh", w.astype(v_local.dtype), v_local)
    l = jax.lax.psum(l_loc, axis_name)
    o = jax.lax.psum(o_loc.astype(jnp.float32), axis_name)
    out = o / jnp.maximum(l, 1e-30)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def make_flash_decode(mesh, cfg: ModelConfig, axis_name: str = "model"):
    """Returns f(q, k, v, valid) with k/v sequence-sharded over axis_name."""
    fn = functools.partial(flash_decode_local, axis_name=axis_name)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(), P(None, axis_name, None, None),
                  P(None, axis_name, None, None), P(None, axis_name)),
        out_specs=P(),
        check_rep=False,
    )
