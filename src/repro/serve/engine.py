"""Serving: prefill and decode step builders + a minimal batched engine.

``make_prefill_step`` runs the full-sequence forward and returns last-token
logits; ``make_decode_step`` advances one token against the decode state
(KV caches / recurrent states).  Cache layout under the production mesh:
batch on the DP axes and cache sequence on the model axis (sequence-sharded
flash-decode — see DESIGN.md §5), falling back to head sharding when the
rules say so.

The :class:`Engine` drives continuous batched decoding on the host and is
GAPP-instrumented: each request slot is a logical worker, so stalls from
uneven sequence lengths (a serialization bottleneck: one long request holds
the whole batch) surface directly in the CMetric profile.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_decode_state
from repro.models.common import ModelConfig


def make_prefill_step(cfg: ModelConfig, **fw_kwargs) -> Callable:
    def prefill(params, batch):
        logits, _ = forward(params, batch, cfg, **fw_kwargs)
        return logits[:, -1]
    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    def step(params, tokens, pos, state, memory=None):
        logits, state = decode_step(params, tokens, pos, state, cfg,
                                    memory=memory)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, state
    return step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)


class Engine:
    """Small continuous-batching decode engine (host loop, CPU-friendly)."""

    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 cache_len: int, gapp=None):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.cache_len = cache_len
        self.state = init_decode_state(cfg, batch_slots, cache_len)
        self.tokens = jnp.zeros((batch_slots,), jnp.int32)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.active: list[Request | None] = [None] * batch_slots
        self._step = jax.jit(make_decode_step(cfg))
        self.gapp = gapp
        if gapp is not None:
            self.slot_wids = [gapp.register_worker(f"slot{i}", "device")
                              for i in range(batch_slots)]

    def submit(self, req: Request) -> bool:
        for i in range(self.slots):
            if self.active[i] is None:
                self.active[i] = req
                self.tokens = self.tokens.at[i].set(int(req.prompt[-1]))
                self.pos = self.pos.at[i].set(len(req.prompt) - 1)
                if self.gapp is not None:
                    self.gapp.begin(self.slot_wids[i], f"decode/req{req.rid}")
                return True
        return False

    def step(self) -> list[Request]:
        """One decode step for all active slots; returns finished requests."""
        next_tok, _, self.state = self._step(self.params, self.tokens,
                                             self.pos, self.state)
        self.tokens = next_tok
        self.pos = self.pos + 1
        done = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(next_tok[i]))
            if len(req.out) >= req.max_new:
                done.append(req)
                self.active[i] = None
                if self.gapp is not None:
                    self.gapp.end(self.slot_wids[i])
        return done

    def run(self, requests: list[Request]) -> list[Request]:
        pending = list(requests)
        finished: list[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            finished += self.step()
        return finished
