"""Serving: prefill/decode steps, engine, flash-decode."""
from repro.serve.engine import Engine, Request, make_decode_step, make_prefill_step  # noqa: F401
