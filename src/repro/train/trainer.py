"""Trainer: the instrumented host loop tying every substrate together.

The host itself is a set of GAPP workers: the step dispatcher, the data
loader (inside PrefetchLoader), and the checkpoint writer.  Any of them
stalling the others produces exactly the reduced-parallelism slices the
profiler ranks — profile a run, read the top call path, fix that.  This is
the paper's workflow (§5) transplanted onto a training job.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from repro.ckpt import checkpoint
from repro.core.session import ProfileSession
from repro.data.pipeline import PrefetchLoader, SyntheticLM
from repro.models import init_lm
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    batch_per_host: int = 8
    seq_len: int = 128
    seed: int = 0
    log_every: int = 10
    profile: bool = True
    loader_delay_s: float = 0.0      # inject data bottleneck (benchmarks)


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                 tcfg: TrainerConfig, gapp: ProfileSession | None = None,
                 step_fn: Callable | None = None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        # ``gapp`` accepts a ProfileSession or the deprecated Gapp facade
        # (both expose the same span/lifecycle surface).
        self.gapp = gapp if gapp is not None else (
            ProfileSession(dt=0.002) if tcfg.profile else None)
        self.step_fn = step_fn or jax.jit(
            make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
        front = None
        if cfg.enc_layers:
            front = (tcfg.seq_len // 2, cfg.frontend_dim)
        elif cfg.frontend_dim:
            front = (cfg.num_prefix, cfg.frontend_dim)
        self.source = SyntheticLM(cfg.vocab_size, tcfg.seq_len,
                                  tcfg.batch_per_host, tcfg.seed,
                                  frontend_shape=front)
        self.loader = PrefetchLoader(self.source, depth=2, gapp=self.gapp,
                                     delay_s=tcfg.loader_delay_s)
        self.w_train = self.gapp.register_worker("trainer", "host") \
            if self.gapp else None
        self.w_ckpt = self.gapp.register_worker("ckpt_writer", "thread") \
            if self.gapp else None
        self.history: list[dict] = []
        self._ckpt_thread = None

    def init_state(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(self.tcfg.seed)
        params = init_lm(key, self.cfg)
        opt_state = adamw.init(params)
        return params, opt_state

    def restore_or_init(self):
        step = checkpoint.latest_step(self.tcfg.ckpt_dir)
        params, opt_state = self.init_state()
        if step is not None:
            tree = checkpoint.restore(self.tcfg.ckpt_dir, step,
                                      {"params": params, "opt": opt_state})
            return tree["params"], tree["opt"], step
        return params, opt_state, 0

    def _maybe_ckpt(self, step: int, params, opt_state, final=False):
        if step % self.tcfg.ckpt_every and not final:
            return
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        tree = {"params": params, "opt": opt_state}
        self._ckpt_thread = checkpoint.save(
            self.tcfg.ckpt_dir, step, tree,
            blocking=not self.tcfg.ckpt_async,
            gapp=self.gapp, wid=self.w_ckpt)

    def run(self, start_step: int | None = None):
        if start_step in (None, 0):
            params, opt_state = self.init_state()
            step0 = 0
        else:
            params, opt_state, step0 = self.restore_or_init()
        err = None
        g = self.gapp
        if g:
            g.start()
        try:
            for step in range(step0, self.tcfg.steps):
                # blocking wait: the trainer is INACTIVE here (paper
                # semantics — a blocked thread leaves TASK_RUNNING), so a
                # slow loader runs alone and its data/generate slices are
                # the ones that turn critical
                batch = self.loader.get()
                if g:
                    g.begin(self.w_train, "train/step")
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics, err = self.step_fn(
                    params, opt_state, batch, err)
                jax.block_until_ready(metrics["loss"])
                if g:
                    g.end(self.w_train)
                self.history.append(
                    {k: float(np.asarray(v)) for k, v in metrics.items()
                     if v is not None and np.ndim(v) == 0})
                if step % self.tcfg.log_every == 0:
                    print(f"step {step:5d} loss {self.history[-1]['loss']:.4f}"
                          f" gnorm {self.history[-1].get('grad_norm', 0):.3f}",
                          flush=True)
                self._maybe_ckpt(step + 1, params, opt_state)
            self._maybe_ckpt(self.tcfg.steps, params, opt_state, final=True)
            if self._ckpt_thread is not None:
                self._ckpt_thread.join()
        finally:
            if g:
                g.stop()
            self.loader.stop()
        return params, opt_state

    def profile_report(self, top_n: int = 10):
        assert self.gapp is not None
        if hasattr(self.gapp, "snapshot"):          # ProfileSession
            return self.gapp.snapshot(top_n)
        return self.gapp.report(top_n=top_n)        # deprecated Gapp
