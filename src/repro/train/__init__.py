"""Training: step builders + instrumented trainer loop."""
from repro.train.step import make_eval_step, make_train_step  # noqa: F401
