"""Train / eval step builders (pjit-able, sharding-annotated).

``make_train_step`` returns a pure function
``(params, opt_state, batch, err) -> (params, opt_state, metrics, err)``
ready for ``jax.jit`` with the shardings produced by
``repro.sharding.params`` — the same function serves the CPU smoke tests
(no mesh binding) and the 512-chip dry-run (bound via ``use_mesh``).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import lm_loss
from repro.models.common import ModelConfig
from repro.optim import adamw, compression
from repro.sharding.api import constrain


def make_loss_fn(cfg: ModelConfig, **fw_kwargs) -> Callable:
    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg, **fw_kwargs)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    compress: str = "none", microbatch: int | None = None,
                    **fw_kwargs) -> Callable:
    """Builds the jittable step.  ``microbatch`` splits the per-step batch
    into gradient-accumulation chunks (sequential, remat-friendly)."""
    loss_fn = make_loss_fn(cfg, **fw_kwargs)

    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, {**metrics, "loss": loss}

    cgrad = compression.wrap_grad_fn(grad_fn, compress)

    def train_step(params, opt_state, batch, err):
        batch = {k: constrain(v, "batch") for k, v in batch.items()}
        if microbatch and microbatch > 1:
            def mb_body(carry, mb):
                acc, aux_acc = carry
                g, aux = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                aux_acc = jax.tree.map(jnp.add, aux_acc,
                                       {"loss": aux["loss"]})
                return (acc, aux_acc), None
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            (grads, aux_sum), _ = jax.lax.scan(
                mb_body, (zero_g, {"loss": jnp.zeros((), jnp.float32)}), mbs)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = {"loss": aux_sum["loss"] / microbatch}
            new_err = err
        else:
            grads, metrics, new_err = cgrad(params, batch, err)
            metrics = {"loss": metrics["loss"]}
        params, opt_state, opt_metrics = adamw.update(opt_cfg, grads,
                                                      opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}, new_err

    return train_step


def make_eval_step(cfg: ModelConfig, **fw_kwargs) -> Callable:
    loss_fn = make_loss_fn(cfg, **fw_kwargs)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return metrics
    return eval_step
