"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def fold_ref(dt, deltas, carry=None):
    """Reference for the carry-resumable CMetric interval fold.

    Args:
      dt:     f32[E] interval lengths; ``dt[i] = t[i+1]-t[i]`` (last entry 0).
      deltas: i32[E] +1 activate / -1 deactivate (0 allowed for padding).
      carry:  optional (count, gcm, idle) triple resuming a prior fold.

    Returns:
      n:        i32[E] active-worker count during interval i (after event i)
      gcm:      f32[E] global_cm value when event i fires (exclusive prefix)
      total_cm: f32[]  final global_cm
      idle:     f32[]  total time with n == 0
      count:    f32[]  final active-worker count (the next chunk's carry)
    """
    c0, g0, i0 = (0.0, 0.0, 0.0) if carry is None else carry
    n = jnp.cumsum(deltas.astype(jnp.int32)) + jnp.int32(c0)
    contrib = jnp.where(n > 0, dt / jnp.maximum(n, 1).astype(dt.dtype), 0.0)
    incl = jnp.cumsum(contrib)
    gcm = g0 + incl - contrib                # exclusive prefix
    idle = i0 + jnp.sum(jnp.where((n <= 0) & (dt > 0), dt, 0.0))
    return n, gcm, g0 + incl[-1], idle, n[-1].astype(jnp.float32)


def hist_ref(tags, num_bins: int):
    """Reference for the sample-tag histogram: i32[K] counts.

    Negative tags (NO_TAG / padding) are ignored.
    """
    valid = tags >= 0
    clipped = jnp.clip(tags, 0, num_bins - 1)
    onehot_sum = jnp.zeros((num_bins,), jnp.int32).at[clipped].add(
        valid.astype(jnp.int32))
    return onehot_sum


def weighted_hist_ref(tags, weights, num_bins: int):
    """Reference for the CMetric-weighted histogram (merge step): f32[K]."""
    valid = tags >= 0
    clipped = jnp.clip(tags, 0, num_bins - 1)
    return jnp.zeros((num_bins,), weights.dtype).at[clipped].add(
        jnp.where(valid, weights, 0))
