"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def fold_ref(dt, deltas):
    """Reference for the CMetric interval fold.

    Args:
      dt:     f32[E] interval lengths; ``dt[i] = t[i+1]-t[i]`` (last entry 0).
      deltas: i32[E] +1 activate / -1 deactivate (0 allowed for padding).

    Returns:
      n:        i32[E] active-worker count during interval i (after event i)
      gcm:      f32[E] global_cm value when event i fires (exclusive prefix)
      total_cm: f32[]  final global_cm
      idle:     f32[]  total time with n == 0
    """
    n = jnp.cumsum(deltas.astype(jnp.int32))
    contrib = jnp.where(n > 0, dt / jnp.maximum(n, 1).astype(dt.dtype), 0.0)
    incl = jnp.cumsum(contrib)
    gcm = incl - contrib                     # exclusive prefix
    idle = jnp.sum(jnp.where((n <= 0) & (dt > 0), dt, 0.0))
    return n, gcm, incl[-1], idle


def hist_ref(tags, num_bins: int):
    """Reference for the sample-tag histogram: i32[K] counts.

    Negative tags (NO_TAG / padding) are ignored.
    """
    valid = tags >= 0
    clipped = jnp.clip(tags, 0, num_bins - 1)
    onehot_sum = jnp.zeros((num_bins,), jnp.int32).at[clipped].add(
        valid.astype(jnp.int32))
    return onehot_sum


def weighted_hist_ref(tags, weights, num_bins: int):
    """Reference for the CMetric-weighted histogram (merge step): f32[K]."""
    valid = tags >= 0
    clipped = jnp.clip(tags, 0, num_bins - 1)
    return jnp.zeros((num_bins,), weights.dtype).at[clipped].add(
        jnp.where(valid, weights, 0))
