"""Pallas TPU kernels for the profiler's post-processing hot spots.

``cmetric_fold`` — coupled prefix scans (active count + global_cm) over the
event stream; ``tag_hist`` — sample-tag frequency / weighted-CMetric tables.
Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py``; on this CPU-only container they run with ``interpret=True``.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import cmetric_fold, compute_pallas, tag_histogram

__all__ = ["ops", "ref", "cmetric_fold", "compute_pallas", "tag_histogram"]
