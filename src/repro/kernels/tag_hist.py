"""Pallas TPU kernel: sample-tag frequency histogram (paper §4.4 merge step).

The user-space post-processing merges sampled 'instruction pointers' (here:
tag ids) into per-call-path frequency tables.  On TPU, scatter-add is the
wrong shape — instead each (1, B) block of samples is compared against the
(1, K) bin ids with a broadcast equality, reduced over the sample axis on
the VPU, and accumulated into a VMEM-resident output block across the
sequential grid.  A weighted variant (weights = slice CMetrics) computes the
cumulative-CMetric-per-tag table in the same pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _hist_kernel(tags_ref, w_ref, counts_ref, wsum_ref, *, bins_per_blk):
    # Grid is (k-blocks, sample-blocks): the sample (reduction) dimension is
    # innermost so revisits of an output block are consecutive — the TPU
    # accumulation pattern.
    kblk = pl.program_id(0)
    sblk = pl.program_id(1)

    @pl.when(sblk == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        wsum_ref[...] = jnp.zeros_like(wsum_ref)

    tags = tags_ref[...]                               # (1, B) i32
    w = w_ref[...]                                     # (1, B) f32
    base = kblk * bins_per_blk
    bins = base + jax.lax.broadcasted_iota(jnp.int32, (1, bins_per_blk), 1)
    # (B, K) one-hot comparison; negative tags (padding / NO_TAG) never match
    onehot = tags.reshape(-1, 1) == bins.reshape(1, -1)
    counts_ref[...] += jnp.sum(onehot, axis=0, dtype=jnp.int32).reshape(1, -1)
    wsum_ref[...] += jnp.sum(
        jnp.where(onehot, w.reshape(-1, 1), 0.0), axis=0).reshape(1, -1)


@functools.partial(jax.jit, static_argnames=("num_bins", "block",
                                             "bins_per_blk", "interpret"))
def hist(tags, weights=None, *, num_bins: int, block: int = 1024,
         bins_per_blk: int = 512, interpret: bool = True):
    """Histogram + weighted histogram of tag ids.

    Args:
      tags:    i32[S] tag ids; negative = ignore.
      weights: f32[S] per-sample weights (defaults to ones).
      num_bins: K (padded up to a lane multiple internally).

    Returns (counts i32[K], wsum f32[K]).
    """
    s = tags.shape[0]
    if weights is None:
        weights = jnp.ones((s,), jnp.float32)
    pad_s = (-s) % block
    kp = max(LANES, ((num_bins + bins_per_blk - 1) // bins_per_blk)
             * bins_per_blk)
    tags_p = jnp.pad(tags.astype(jnp.int32), (0, pad_s),
                     constant_values=-1).reshape(1, -1)
    w_p = jnp.pad(weights.astype(jnp.float32), (0, pad_s)).reshape(1, -1)
    nsblk = tags_p.shape[1] // block
    nkblk = kp // bins_per_blk

    counts, wsum = pl.pallas_call(
        functools.partial(_hist_kernel, bins_per_blk=bins_per_blk),
        grid=(nkblk, nsblk),
        in_specs=[
            pl.BlockSpec((1, block), lambda j, i: (0, i)),
            pl.BlockSpec((1, block), lambda j, i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bins_per_blk), lambda j, i: (0, j)),
            pl.BlockSpec((1, bins_per_blk), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, kp), jnp.int32),
            jax.ShapeDtypeStruct((1, kp), jnp.float32),
        ],
        interpret=interpret,
    )(tags_p, w_p)
    return counts[0, :num_bins], wsum[0, :num_bins]
