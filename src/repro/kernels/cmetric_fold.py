"""Pallas TPU kernels: the CMetric interval fold (paper §4.1 hot loop).

At fleet scale the profiler ingests tens of millions of events per run
(every span begin/end across hosts, stages and experts).  The fold below is
the post-processing hot spot the paper keeps fast ("PPT" column of Table 2):
for every event we need the active-worker count during the preceding
interval and the running ``global_cm`` prefix

    n[i]   = n_in  + Σ_{e<=i} delta[e]
    gcm[i] = gcm_in + Σ_{e<i}  dt[e] / max(n[e], 1) * (n[e] > 0)

i.e. two coupled prefix scans over the event stream.  TPU adaptation: the
stream is tiled into (1, B) VMEM blocks (B a multiple of 128 lanes); within a
block the scan is a Hillis–Steele shift-add ladder (log2 B vector steps on
the VPU); the inter-block carry (running count, running gcm, idle time) lives
in a small VMEM scratch accumulator that persists across the sequential TPU
grid.  HBM traffic is exactly 3 input + 2 output streams — the kernel is
memory-bound by design, matching its roofline on the VPU.

Both kernels are **carry-resumable**: the scan state enters as a small
``carry0`` input and the final state comes back in the scalars output, so a
log too large for one call (or one host) streams through in chunks —
exactly the cross-block carry trick, lifted one level up to cross-call
(see :class:`repro.core.cmetric.FoldCarry`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _ladder_cumsum(x):
    """Inclusive Hillis-Steele cumsum along the last axis of a (1, B) block.

    Unrolled log2(B) shift-add steps; every step is a full-width VPU add, so
    the ladder costs ~log2(B) vector ops per block (B must be a power of 2).
    """
    b = x.shape[-1]
    shift = 1
    while shift < b:
        shifted = jnp.pad(x, ((0, 0), (shift, 0)))[:, :b]
        x = x + shifted
        shift *= 2
    return x


def _fold_kernel(dt_ref, delta_ref, carry0_ref, n_ref, gcm_ref, carry_ref,
                 scalars_ref):
    """Grid is 1-D over event blocks; TPU executes it sequentially, so the
    carry scratch implements the cross-block prefix.  ``carry0`` seeds the
    scan (count, gcm, idle) so a chunked caller can resume a prior fold."""
    blk = pl.program_id(0)

    @pl.when(blk == 0)
    def _init():
        carry_ref[0, 0] = carry0_ref[0, 0]   # running count (f32; exact to 2^24)
        carry_ref[0, 1] = carry0_ref[0, 1]   # running gcm
        carry_ref[0, 2] = carry0_ref[0, 2]   # running idle time

    count_in = carry_ref[0, 0]
    gcm_in = carry_ref[0, 1]
    idle_in = carry_ref[0, 2]

    delta = delta_ref[...].astype(jnp.float32)
    dt = dt_ref[...]

    n = _ladder_cumsum(delta) + count_in            # inclusive count prefix
    pos = n > 0.5
    contrib = jnp.where(pos, dt / jnp.maximum(n, 1.0), 0.0)
    incl = _ladder_cumsum(contrib)
    gcm = gcm_in + incl - contrib                    # exclusive prefix
    idle_blk = jnp.sum(jnp.where((~pos) & (dt > 0), dt, 0.0))

    n_ref[...] = n.astype(jnp.int32)
    gcm_ref[...] = gcm

    carry_ref[0, 0] = n[0, -1]
    carry_ref[0, 1] = gcm_in + incl[0, -1]
    carry_ref[0, 2] = idle_in + idle_blk

    @pl.when(blk == pl.num_programs(0) - 1)
    def _finalize():
        scalars_ref[0, 0] = gcm_in + incl[0, -1]     # total_cm
        scalars_ref[0, 1] = idle_in + idle_blk       # idle
        scalars_ref[0, 2] = n[0, -1]                 # final count


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fold(dt, deltas, carry=None, *, block: int = 2048,
         interpret: bool = True):
    """Blocked, carry-resumable CMetric fold.  See
    :func:`repro.kernels.ref.fold_ref`.

    Args:
      dt:     f32[E] interval lengths (last entry 0).
      deltas: i32[E] state-change deltas (+1/-1, 0 padding).
      carry:  optional (count, gcm, idle) f32 triple resuming a prior call
              (defaults to a fresh scan).
      block:  events per VMEM tile (power of two, multiple of 128).

    Returns (n i32[E], gcm f32[E], total_cm f32, idle f32, count f32) — the
    final (total_cm, idle, count) triple is the carry for the next chunk.
    """
    assert block % LANES == 0 and block & (block - 1) == 0, block
    e = dt.shape[0]
    pad = (-e) % block
    dt_p = jnp.pad(dt.astype(jnp.float32), (0, pad)).reshape(1, -1)
    de_p = jnp.pad(deltas.astype(jnp.int32), (0, pad)).reshape(1, -1)
    nblk = dt_p.shape[1] // block
    if carry is None:
        carry = (0.0, 0.0, 0.0)
    carry0 = jnp.zeros((1, LANES), jnp.float32).at[0, :3].set(
        jnp.asarray(carry, jnp.float32))

    n, gcm, _, scalars = pl.pallas_call(
        _fold_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),  # carry seed
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),  # carry accumulator
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),  # final scalars
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, nblk * block), jnp.int32),
            jax.ShapeDtypeStruct((1, nblk * block), jnp.float32),
            jax.ShapeDtypeStruct((1, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(dt_p, de_p, carry0)
    return (n[0, :e], gcm[0, :e], scalars[0, 0], scalars[0, 1],
            scalars[0, 2])


def _cumsum_kernel(contrib_ref, idle_ref, carry0_ref, g_ref, carry_ref,
                   scalars_ref):
    """Carry-seeded dual prefix: inclusive cumsum of ``contrib`` (the
    per-event global_cm contributions, already divided by the active count
    host-side) plus a running idle total."""
    blk = pl.program_id(0)

    @pl.when(blk == 0)
    def _init():
        carry_ref[0, 0] = carry0_ref[0, 0]   # running gcm
        carry_ref[0, 1] = carry0_ref[0, 1]   # running idle

    g_in = carry_ref[0, 0]
    idle_in = carry_ref[0, 1]

    contrib = contrib_ref[...]
    incl = _ladder_cumsum(contrib)
    g_ref[...] = g_in + incl                  # inclusive: gcm *at* event i
    idle_blk = jnp.sum(idle_ref[...])

    carry_ref[0, 0] = g_in + incl[0, -1]
    carry_ref[0, 1] = idle_in + idle_blk

    @pl.when(blk == pl.num_programs(0) - 1)
    def _finalize():
        scalars_ref[0, 0] = g_in + incl[0, -1]
        scalars_ref[0, 1] = idle_in + idle_blk


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def carry_cumsum(contrib, idle_contrib, carry, *, block: int = 2048,
                 interpret: bool = True):
    """Carry-seeded blocked cumsum used by the Pallas chunked fold.

    Returns (g f32[E], gcm_end f32, idle_end f32): ``g[i]`` is the carried
    gcm value *at* event i (inclusive of event i's contribution).
    """
    assert block % LANES == 0 and block & (block - 1) == 0, block
    e = contrib.shape[0]
    pad = (-e) % block
    c_p = jnp.pad(contrib.astype(jnp.float32), (0, pad)).reshape(1, -1)
    i_p = jnp.pad(idle_contrib.astype(jnp.float32), (0, pad)).reshape(1, -1)
    nblk = c_p.shape[1] // block
    carry0 = jnp.zeros((1, LANES), jnp.float32).at[0, :2].set(
        jnp.asarray(carry, jnp.float32))

    g, _, scalars = pl.pallas_call(
        _cumsum_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, nblk * block), jnp.float32),
            jax.ShapeDtypeStruct((1, LANES), jnp.float32),
            jax.ShapeDtypeStruct((1, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(c_p, i_p, carry0)
    return g[0, :e], scalars[0, 0], scalars[0, 1]
