"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU v5e
is the compile target) and False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import cmetric_fold as _fold
from repro.kernels import tag_hist as _hist


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def cmetric_fold(times_s, deltas, carry=None, *, block: int = 2048,
                 interpret: bool | None = None):
    """Fold an event stream into (n, gcm, total_cm, idle, count).

    ``times_s`` are event times (f32 seconds, rebased); dt is derived here so
    callers hand over the raw stream.  ``carry`` optionally resumes a prior
    fold from its (count, gcm, idle) scalars — the final (total_cm, idle,
    count) triple of the return value is exactly the next chunk's carry.
    """
    interpret = default_interpret() if interpret is None else interpret
    dt = jnp.concatenate([times_s[1:] - times_s[:-1],
                          jnp.zeros((1,), times_s.dtype)])
    return _fold.fold(dt, deltas, carry, block=block, interpret=interpret)


def fold_chunk_prefix(gcm0: float, idle0: float, contrib, idle_contrib, *,
                      block: int = 2048, interpret: bool | None = None):
    """Device prefix for the chunked CMetric fold (see
    :func:`repro.core.cmetric._fold_chunk`): carry-seeded blocked cumsum of
    the per-event contributions on the Pallas scan kernel.

    Returns ``(g float64[E], idle_end float)`` where ``g[i]`` is the
    global_cm value at event ``i``.
    """
    interpret = default_interpret() if interpret is None else interpret
    g, _, idle_end = _fold.carry_cumsum(
        jnp.asarray(contrib, jnp.float32),
        jnp.asarray(idle_contrib, jnp.float32),
        jnp.asarray([gcm0, idle0], jnp.float32),
        block=block, interpret=interpret)
    return np.asarray(g, np.float64), float(idle_end)


def tag_histogram(tags, weights=None, *, num_bins: int, block: int = 1024,
                  interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _hist.hist(tags, weights, num_bins=num_bins, block=block,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_workers", "block",
                                             "interpret"))
def _fused_pipeline(times_s, workers, deltas, num_workers: int, block: int,
                    interpret: bool):
    """Fold (Pallas kernel) + pairing + segment-sum as ONE jitted program —
    the gcm prefix never leaves the device between stages."""
    from repro.core import cmetric as cmetric_lib  # avoid import cycle
    _, gcm, _, idle, _ = cmetric_fold(times_s, deltas, block=block,
                                      interpret=interpret)
    return cmetric_lib._pair_core(times_s, workers, deltas, gcm, idle,
                                  num_workers)


def compute_pallas(log, *, block: int = 2048, interpret: bool | None = None):
    """CMetric backend: the Pallas fold kernel fused with the shared pairing
    /aggregation core (see :func:`repro.core.cmetric.drive_pairing`)."""
    from repro.core import cmetric as cmetric_lib  # avoid import cycle
    interpret = default_interpret() if interpret is None else interpret
    return cmetric_lib.drive_pairing(
        log, functools.partial(_fused_pipeline, block=block,
                               interpret=interpret))
