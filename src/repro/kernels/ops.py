"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU v5e
is the compile target) and False on real TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import cmetric_fold as _fold
from repro.kernels import tag_hist as _hist


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def cmetric_fold(times_s, deltas, *, block: int = 2048,
                 interpret: bool | None = None):
    """Fold an event stream into (n, gcm, total_cm, idle).

    ``times_s`` are event times (f32 seconds, rebased); dt is derived here so
    callers hand over the raw stream.
    """
    interpret = default_interpret() if interpret is None else interpret
    dt = jnp.concatenate([times_s[1:] - times_s[:-1],
                          jnp.zeros((1,), times_s.dtype)])
    return _fold.fold(dt, deltas, block=block, interpret=interpret)


def tag_histogram(tags, weights=None, *, num_bins: int, block: int = 1024,
                  interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _hist.hist(tags, weights, num_bins=num_bins, block=block,
                      interpret=interpret)


def compute_pallas(log):
    """CMetric backend using the Pallas fold for the prefix stage and the
    shared pairing/aggregation stage for the rest."""
    from repro.core import cmetric as cmetric_lib  # avoid import cycle
    if len(log) == 0:
        return cmetric_lib.compute_numpy(log)
    t = jnp.asarray(log.slice_seconds(), jnp.float32)
    deltas = jnp.asarray(log.deltas, jnp.int32)
    _, gcm, _, idle = cmetric_fold(t, deltas)
    outs = cmetric_lib._pair_and_aggregate(
        t, jnp.asarray(log.workers), deltas, gcm, idle, log.num_workers)
    return cmetric_lib._result_from_pairing(log, t, outs)
