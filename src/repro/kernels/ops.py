"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU v5e
is the compile target) and False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import cmetric_fold as _fold
from repro.kernels import tag_hist as _hist


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def cmetric_fold(times_s, deltas, *, block: int = 2048,
                 interpret: bool | None = None):
    """Fold an event stream into (n, gcm, total_cm, idle).

    ``times_s`` are event times (f32 seconds, rebased); dt is derived here so
    callers hand over the raw stream.
    """
    interpret = default_interpret() if interpret is None else interpret
    dt = jnp.concatenate([times_s[1:] - times_s[:-1],
                          jnp.zeros((1,), times_s.dtype)])
    return _fold.fold(dt, deltas, block=block, interpret=interpret)


def tag_histogram(tags, weights=None, *, num_bins: int, block: int = 1024,
                  interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _hist.hist(tags, weights, num_bins=num_bins, block=block,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_workers", "block",
                                             "interpret"))
def _fused_pipeline(times_s, workers, deltas, num_workers: int, block: int,
                    interpret: bool):
    """Fold (Pallas kernel) + pairing + segment-sum as ONE jitted program —
    the gcm prefix never leaves the device between stages."""
    from repro.core import cmetric as cmetric_lib  # avoid import cycle
    _, gcm, _, idle = cmetric_fold(times_s, deltas, block=block,
                                   interpret=interpret)
    return cmetric_lib._pair_core(times_s, workers, deltas, gcm, idle,
                                  num_workers)


def compute_pallas(log, *, block: int = 2048, interpret: bool | None = None):
    """CMetric backend: the Pallas fold kernel fused with the shared pairing
    /aggregation core (see :func:`repro.core.cmetric.drive_pairing`)."""
    from repro.core import cmetric as cmetric_lib  # avoid import cycle
    interpret = default_interpret() if interpret is None else interpret
    return cmetric_lib.drive_pairing(
        log, functools.partial(_fused_pipeline, block=block,
                               interpret=interpret))
