"""repro: GAPP (ICPE 2020) criticality profiler + multi-pod JAX framework."""
