"""Data pipeline: synthetic LM token stream with background prefetch.

Per-host sharded generation (each host materialises only its slice of the
global batch), a bounded prefetch queue running in a worker thread, and —
because the input pipeline is a classic fleet serialization bottleneck —
first-class GAPP instrumentation: the loader thread is a registered worker
whose spans ("data/generate", "data/wait_queue") show up in the profile
when the pipeline can't keep up with the step loop.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core.session import ProfileSession


class SyntheticLM:
    """Deterministic synthetic token batches (zipfian unigram + markov-ish
    mixing so the loss actually decreases during the e2e example)."""

    def __init__(self, vocab_size: int, seq_len: int, batch_per_host: int,
                 seed: int = 0, frontend_shape: tuple | None = None):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_per_host
        self.frontend_shape = frontend_shape
        self._rng = np.random.default_rng(seed)
        ranks = np.arange(1, min(vocab_size, 4096) + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._support = min(vocab_size, 4096)

    def next_batch(self) -> dict:
        base = self._rng.choice(self._support, size=(self.batch, self.seq),
                                p=self._probs)
        # inject learnable structure: token t+1 correlates with token t
        shifted = (base + 1) % self._support
        mix = self._rng.random((self.batch, self.seq)) < 0.5
        tokens = np.where(mix, np.roll(shifted, 1, axis=1), base)
        out = {"tokens": tokens.astype(np.int32)}
        if self.frontend_shape is not None:
            out["frontend"] = self._rng.standard_normal(
                (self.batch,) + self.frontend_shape).astype(np.float32)
        return out


class PrefetchLoader:
    """Bounded-queue background prefetch around any ``next_batch`` source."""

    def __init__(self, source, depth: int = 2,
                 gapp: ProfileSession | None = None, delay_s: float = 0.0):
        self.source = source
        self.queue: queue.Queue = queue.Queue(maxsize=depth)
        self.gapp = gapp
        self.delay_s = delay_s          # artificial slowness (benchmarks)
        self._stop = threading.Event()
        self._wid = gapp.register_worker("data_loader", "thread") \
            if gapp else None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="data-loader")
        self._thread.start()

    def _run(self):
        import time
        while not self._stop.is_set():
            if self.gapp is not None:
                self.gapp.begin(self._wid, "data/generate")
            batch = self.source.next_batch()
            if self.delay_s:
                time.sleep(self.delay_s)
            if self.gapp is not None:
                self.gapp.end(self._wid)
            while not self._stop.is_set():
                try:
                    self.queue.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self) -> dict:
        return self.queue.get()

    def stop(self):
        self._stop.set()
        try:
            self.queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
