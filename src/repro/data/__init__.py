"""Data pipeline: synthetic sources + instrumented prefetch."""
from repro.data.pipeline import PrefetchLoader, SyntheticLM  # noqa: F401
