"""Pipeline parallelism (GPipe via shard_map + ppermute)."""
from repro.pipeline.gpipe import gpipe, schedule_intervals  # noqa: F401
