"""Pipeline parallelism: GPipe schedule via shard_map + collective_permute.

The layer stack is split into ``n_stages`` stages sharded over a ``stage``
mesh axis; microbatches flow stage-to-stage with ``lax.ppermute``.  The
schedule is the classic GPipe fill/steady/drain loop of length
``n_micro + n_stages - 1`` — the warm-up and drain slots are *bubbles*, i.e.
exactly the reduced-parallelism intervals GAPP's CMetric is built to expose
(see examples/pipeline_bubbles.py: the per-stage busy intervals of this
schedule are ingested into the profiler and the bubble fraction appears as
stage-0/stage-N-1 criticality).

This module is exercised by tests and examples on a host-local mesh; the
40-cell dry-run uses the assigned DP×TP mesh (no stage axis) per the
assignment.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(stage_fn, mesh: Mesh, n_stages: int, n_micro: int,
          stage_axis: str = "stage"):
    """Build a pipelined apply: (stacked_params, x) -> y.

    stage_fn: (params_for_stage, activation) -> activation, same shape.
    stacked_params: leaves with leading dim n_stages (sharded over stage).
    x: (n_micro, mb, ...) microbatched input, replicated over stage.
    Returns y of the same shape (outputs of the last stage).
    """

    def pipelined(stacked_params, x):
        def body(local_params, xloc):
            # local_params leaves: (1, ...) -> squeeze; xloc: full (replicated)
            params = jax.tree.map(lambda p: p[0], local_params)
            idx = jax.lax.axis_index(stage_axis)
            n_steps = n_micro + n_stages - 1
            mb_shape = xloc.shape[1:]
            carry = jnp.zeros(mb_shape, xloc.dtype)   # incoming activation
            outs = jnp.zeros_like(xloc)               # last-stage outputs
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            for t in range(n_steps):
                mb_id = t - idx                        # microbatch at stage
                # stage 0 ingests microbatch t (if any) from x
                feed = xloc[jnp.clip(t, 0, n_micro - 1)]
                inp = jnp.where(idx == 0, feed, carry)
                y = stage_fn(params, inp)
                active = (mb_id >= 0) & (mb_id < n_micro)
                y = jnp.where(active, y, jnp.zeros_like(y))
                # last stage banks its output at slot mb_id
                is_last = idx == n_stages - 1
                slot = jnp.clip(mb_id, 0, n_micro - 1)
                outs = jnp.where(
                    active & is_last,
                    jax.lax.dynamic_update_index_in_dim(outs, y, slot, 0),
                    outs)
                # shift activations to the next stage
                carry = jax.lax.ppermute(y, stage_axis, perm)
            # deliver outs (only the last stage's copy is meaningful):
            # masked psum broadcasts it to every stage member
            if n_stages > 1:
                outs = jax.lax.psum(
                    jnp.where(idx == n_stages - 1, outs,
                              jnp.zeros_like(outs)), stage_axis)
            return outs

        pspec = jax.tree.map(lambda _: P(stage_axis), stacked_params)
        return shard_map(
            body, mesh=mesh,
            in_specs=(pspec, P()), out_specs=P(),
            check_rep=False,
        )(stacked_params, x)

    return pipelined


def schedule_intervals(n_stages: int, n_micro: int, t_stage: float = 1.0):
    """The GPipe schedule as (stage, start, end) busy intervals — the
    ground-truth activity trace used to drive the profiler in tests and in
    examples/pipeline_bubbles.py.  Bubble fraction = (n_stages-1)/(n_micro +
    n_stages-1)."""
    out = []
    for s in range(n_stages):
        for m in range(n_micro):
            t0 = (s + m) * t_stage
            out.append((s, t0, t0 + t_stage))
    return out
