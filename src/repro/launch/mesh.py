"""Production meshes (TPU v5e target).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1, data: int | None = None,
                   stage: int | None = None):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    auto = jax.sharding.AxisType.Auto
    if stage is not None:
        return jax.make_mesh((stage,), ("stage",), axis_types=(auto,))
    data = data if data is not None else n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(auto, auto))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~quoted per-direction)
