"""Production meshes (TPU v5e target).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the real single device.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types across JAX versions.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg taking it) only
    exists in newer JAX releases — on older ones the attribute access raises
    through the deprecation machinery.  Auto is the default everywhere, so
    the kwarg is passed only when the enum is present.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None,
                   stage: int | None = None):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    if stage is not None:
        return make_mesh((stage,), ("stage",))
    data = data if data is not None else n // model
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~quoted per-direction)
