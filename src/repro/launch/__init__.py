"""Launch: meshes, dry-run, roofline, train driver."""
