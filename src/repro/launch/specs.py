"""ShapeDtypeStruct input stand-ins + shardings for every (arch × shape).

``input_specs(cfg, shape)`` returns (specs, shardings, step_kind):
  * train / prefill: {"tokens": (B,S) i32, "frontend": ... when stubbed}
  * decode: (tokens (B,), pos (B,), decode state pytree, [memory])

No device memory is allocated — decode states come from ``jax.eval_shape``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.models import init_decode_state
from repro.models.common import ModelConfig
from repro.sharding.api import ShardingRules

ENC_MEMORY_LEN = 1024   # stub encoder-memory length for enc-dec decode


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_like_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.enc_layers:
        # enc-dec: half the length budget to frames, half to text tokens
        specs["frontend"] = _sd((b, s // 2, cfg.frontend_dim), jnp.float32)
        specs["tokens"] = _sd((b, s // 2), jnp.int32)
    elif cfg.frontend_dim:
        specs["frontend"] = _sd((b, cfg.num_prefix, cfg.frontend_dim),
                                jnp.float32)
        specs["tokens"] = _sd((b, s - cfg.num_prefix), jnp.int32)
    else:
        specs["tokens"] = _sd((b, s), jnp.int32)
    return specs


def train_like_shardings(cfg: ModelConfig, specs: dict, mesh,
                         rules: ShardingRules) -> dict:
    from repro.sharding.api import filter_spec
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, filter_spec(v.shape,
                                                 rules.spec(*axes), mesh))
    return out


def decode_state_specs(cfg: ModelConfig, shape: ShapeSpec):
    b = shape.global_batch
    cache_len = shape.seq_len
    state = jax.eval_shape(lambda: init_decode_state(cfg, b, cache_len))
    tokens = _sd((b,), jnp.int32)
    pos = _sd((b,), jnp.int32)
    memory = None
    if cfg.enc_layers:
        memory = (_sd((b, ENC_MEMORY_LEN, cfg.d_model), cfg.compute_dtype),
                  _sd((b, ENC_MEMORY_LEN), jnp.int32))
    return tokens, pos, state, memory


def _state_logical_axes(path) -> tuple:
    names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    leaf = names[-1]
    if leaf in ("k", "v"):
        return ("batch", "cache_seq", "kv_heads", "head_dim")
    if leaf == "s":                       # rwkv state (B,NH,hd,hd)
        return ("batch", "rwkv_heads", None, None)
    if leaf == "h":                       # rglru state (B,R)
        return ("batch", "lru")
    if leaf == "conv":                    # (B,W-1,R)
        return ("batch", None, "lru")
    if leaf in ("prev", "cmix_prev"):     # (B,1,D)
        return ("batch", None, "embed")
    return ("batch",)


def decode_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     rules: ShardingRules):
    tokens, pos, state, memory = decode_state_specs(cfg, shape)

    def bind(path, leaf):
        axes = _state_logical_axes(path)
        axes = tuple(axes) + (None,) * (len(leaf.shape) - len(axes))
        spec = _filtered(leaf, axes[: len(leaf.shape)], mesh, rules)
        return NamedSharding(mesh, spec)

    from repro.sharding.api import filter_spec
    state_sh = jax.tree_util.tree_map_with_path(bind, state)
    tok_sh = NamedSharding(mesh, filter_spec(tokens.shape,
                                             rules.spec("batch"), mesh))
    mem_sh = None
    if memory is not None:
        mem_sh = (NamedSharding(mesh, filter_spec(
                      memory[0].shape, rules.spec("batch", None, "embed"),
                      mesh)),
                  NamedSharding(mesh, filter_spec(
                      memory[1].shape, rules.spec("batch", None), mesh)))
    return tok_sh, tok_sh, state_sh, mem_sh


def _filtered(leaf, axes, mesh, rules: ShardingRules) -> P:
    from repro.sharding.api import filter_entry
    spec = []
    used: set = set()
    for dim, name in zip(leaf.shape, axes):
        phys = rules.table.get(name) if name else None
        spec.append(filter_entry(dim, phys, mesh, used))
    return P(*spec)
