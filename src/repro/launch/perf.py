import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf iteration driver: run one hillclimb variant of a cell and diff it
against the recorded baseline.

  PYTHONPATH=src python -m repro.launch.perf --arch gemma3-1b \
      --shape train_4k --name chunked_local --local-impl chunked

Writes experiments/perf/<arch>_<shape>/<name>.json and prints the
before/after roofline terms (baseline read from experiments/dryrun/).
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402


def main(argv=None) -> int:
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--name", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--method", default="direct",
                    choices=["direct", "extrapolate"])
    ap.add_argument("--local-impl", default="mask")
    ap.add_argument("--opt-level", type=int, default=0)
    ap.add_argument("--attn-qchunk", type=int, default=0)
    ap.add_argument("--scan-layers", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--rules", default="")
    ap.add_argument("--baseline", default=None,
                    help="compare against this perf JSON instead of the "
                         "dryrun baseline")
    args = ap.parse_args(argv)

    extra = {}
    for kv in args.rules.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        extra[k] = None if v in ("None", "none", "") else (
            tuple(v.split("+")) if "+" in v else v)

    r = run_cell(args.arch, args.shape, args.mesh, method=args.method,
                 scan_layers=args.scan_layers, opt_level=args.opt_level,
                 attn_qchunk=args.attn_qchunk, local_impl=args.local_impl,
                 remat=not args.no_remat, extra_rules=extra)
    outdir = f"experiments/perf/{args.arch}_{args.shape}"
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, args.name + ".json"), "w") as f:
        json.dump(dataclasses.asdict(r), f, indent=2)
    if not r.ok:
        print(r.error)
        return 1

    base_path = args.baseline or (
        f"experiments/dryrun/{args.arch}_{args.shape}_{args.mesh}.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    def fmt(d):
        rf = d["roofline"]
        return (f"mem/chip {d['memory']['per_chip_total'] / 2**30:8.2f} GiB | "
                f"t_comp {rf['t_compute']:.3e} t_mem {rf['t_memory']:.3e} "
                f"t_coll {rf['t_collective']:.3e} | bound {rf['bottleneck']:>10s} "
                f"| useful {rf['useful_ratio']:.3f} roof "
                f"{rf['roofline_fraction']:.4f}")

    print(f"=== {args.arch} {args.shape} {args.mesh} :: {args.name} "
          f"({r.seconds:.0f}s compile) ===")
    if base and base.get("ok"):
        print("before:", fmt(base))
    print("after :", fmt(dataclasses.asdict(r)))
    if base and base.get("ok"):
        b, a = base["roofline"], r.roofline
        for k in ("t_compute", "t_memory", "t_collective"):
            if b[k] > 0:
                print(f"  {k}: {b[k]:.3e} -> {a[k]:.3e}  "
                      f"({b[k] / max(a[k], 1e-30):.2f}x)")
        print(f"  roofline_fraction: {b['roofline_fraction']:.4f} -> "
              f"{a['roofline_fraction']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
