import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the process entry point (``python -m repro.launch.dryrun``): the two
lines above run before any jax import so the 512 placeholder devices exist
when jax initialises.  For each cell the step function is lowered with
ShapeDtypeStruct inputs (no allocation), compiled for the production mesh,
and the compiled artifact's memory_analysis / cost_analysis / collective
schedule are recorded for EXPERIMENTS.md §Dry-run and §Roofline.

  train_4k      -> train_step (fwd+bwd+AdamW update)
  prefill_32k   -> prefill (full forward, last-token logits)
  decode_32k/long_500k -> serve_step (one token against the KV/recurrent
                   state at seq_len)
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roofline_lib
from repro.launch import specs as specs_lib
from repro.models import decode_step, init_lm
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.serve.engine import make_prefill_step
from repro.sharding import api as shapi
from repro.sharding import params as shparams
from repro.train.step import make_train_step

# Per-arch logical-axis overrides (see DESIGN.md §5).
ARCH_RULES: dict[str, dict] = {
    # grok: 8 experts cannot shard a 16-way axis -> TP experts over 'model',
    # FSDP-style weight sharding of the big expert tables over 'data'.
    "grok-1-314b": {"experts": None, "expert_in": "data",
                    "expert_mlp": "model"},
    # arctic: 128 experts -> EP over 'data' (8/chip-row), TP over 'model'.
    "arctic-480b": {"experts": "data", "expert_in": None,
                    "expert_mlp": "model"},
}

# Shape-kind overrides: decode shards the KV cache sequence over 'model'
# (flash-decode); training/prefill keep activations DP + heads TP.
KIND_RULES: dict[str, dict] = {
    "decode": {"cache_seq": "model"},
    "prefill": {},
    "train": {},
}


def rules_for(arch: str, kind: str, extra: dict | None = None):
    table = dict(shapi.DEFAULT_RULES)
    table.update(shparams.PARAM_LOGICAL_EXTRA)
    table.update(ARCH_RULES.get(arch, {}))
    table.update(KIND_RULES.get(kind, {}))
    table.update(extra or {})
    return shapi.ShardingRules(table)


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    error: str = ""
    memory: dict | None = None
    roofline: dict | None = None


def _mesh(name: str):
    return mesh_lib.make_production_mesh(multi_pod=(name == "multi"))


def _param_structs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))


def _opt_structs(p_struct):
    return {
        "mu": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_struct),
        "nu": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_struct),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def zero1(spec_tree, p_struct, mesh, rules):
    """ZeRO-1: additionally shard optimizer moments over the DP axes on the
    first free, divisible dimension."""
    dp = rules.table.get("batch")
    axes = dp if isinstance(dp, tuple) else (dp,)
    axes = tuple(a for a in axes if a in mesh.shape)
    dp_size = 1
    for a in axes:
        dp_size *= mesh.shape[a]

    def one(spec, struct):
        parts = list(spec) + [None] * (len(struct.shape) - len(spec))
        used = set()
        for p in parts:
            if p is not None:
                used.update(p if isinstance(p, tuple) else (p,))
        free = tuple(a for a in axes if a not in used)
        if free:
            size = 1
            for a in free:
                size *= mesh.shape[a]
            for i, (dim, cur) in enumerate(zip(struct.shape, parts)):
                if cur is None and dim % size == 0 and dim >= size:
                    parts[i] = free if len(free) > 1 else free[0]
                    break
        from jax.sharding import PartitionSpec as P
        return P(*parts)

    return jax.tree.map(one, spec_tree, p_struct)


def lower_cell(arch: str, shape_name: str, mesh_name: str, *,
               scan_layers: bool = False, zero1_opt: bool = True,
               extra_rules: dict | None = None, local_impl: str = "mask",
               opt_level: int = 0, attn_qchunk: int = 0, remat: bool = True,
               return_artifacts: bool = False, cfg: ModelConfig | None = None):
    cfg = cfg if cfg is not None else configs.get_config(arch)
    if opt_level or attn_qchunk or not remat:
        cfg = dataclasses.replace(cfg, opt_level=opt_level,
                                  attn_qchunk=attn_qchunk, remat=remat)
    shape = configs.SHAPES[shape_name]
    mesh = _mesh(mesh_name)
    rules = rules_for(arch, shape.kind, extra_rules)
    n_chips = mesh.devices.size
    from jax.sharding import NamedSharding

    with shapi.use_mesh(mesh, rules):
        p_struct = _param_structs(cfg)
        p_specs = shparams.physical_specs(p_struct, mesh, rules)
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)

        if shape.kind == "train":
            o_struct = _opt_structs(p_struct)
            o_specs = {
                "mu": zero1(p_specs, p_struct, mesh, rules) if zero1_opt
                else p_specs,
                "nu": zero1(p_specs, p_struct, mesh, rules) if zero1_opt
                else p_specs,
                "step": jax.sharding.PartitionSpec(),
            }
            o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs["mu"])
            o_sh = {"mu": o_sh, "nu": o_sh,
                    "step": NamedSharding(mesh, jax.sharding.PartitionSpec())}
            b_specs = specs_lib.train_like_specs(cfg, shape)
            b_sh = specs_lib.train_like_shardings(cfg, b_specs, mesh, rules)
            step = make_train_step(cfg, adamw.AdamWConfig(),
                                   scan_layers=scan_layers,
                                   local_impl=local_impl)
            fn = lambda p, o, b: step(p, o, b, None)[:3]
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_struct, o_struct, b_specs)
            model_flops = roofline_lib.model_flops_train(
                cfg, shape.global_batch * shape.seq_len)  # 6ND: fwd+bwd
        elif shape.kind == "prefill":
            b_specs = specs_lib.train_like_specs(cfg, shape)
            b_sh = specs_lib.train_like_shardings(cfg, b_specs, mesh, rules)
            prefill = make_prefill_step(cfg, scan_layers=scan_layers,
                                        local_impl=local_impl)
            jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_struct, b_specs)
            model_flops = roofline_lib.model_flops_prefill(
                cfg, shape.global_batch * shape.seq_len)
        else:  # decode
            tok, pos, state, memory = specs_lib.decode_state_specs(cfg, shape)
            tok_sh, pos_sh, st_sh, mem_sh = specs_lib.decode_shardings(
                cfg, shape, mesh, rules)

            def serve_step(params, tokens, position, st, mem):
                logits, new_state = decode_step(params, tokens, position, st,
                                                cfg, memory=mem)
                return jnp.argmax(logits, -1).astype(jnp.int32), new_state

            jitted = jax.jit(serve_step,
                             in_shardings=(p_sh, tok_sh, pos_sh, st_sh,
                                           mem_sh),
                             donate_argnums=(3,))
            lowered = jitted.lower(p_struct, tok, pos, state, memory)
            model_flops = roofline_lib.model_flops_decode(
                cfg, shape.global_batch)
        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    memory = {
        "argument_size": ma.argument_size_in_bytes,
        "output_size": ma.output_size_in_bytes,
        "temp_size": ma.temp_size_in_bytes,
        "generated_code_size": ma.generated_code_size_in_bytes,
        "per_chip_total": (ma.argument_size_in_bytes
                           + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes),
    }
    rf = roofline_lib.analyze(compiled, arch=arch, shape=shape_name,
                              mesh_name=mesh_name, n_chips=n_chips,
                              model_flops=model_flops)
    if return_artifacts:
        return compiled, memory, rf
    return memory, rf


def lower_cell_extrapolated(arch: str, shape_name: str, mesh_name: str,
                            **kw):
    """Two-point unrolled extrapolation for very deep configs.

    Compile the full-width model at 1 and 2 pattern-groups (unrolled, fast),
    take the per-group delta of every roofline term, and extrapolate
    linearly to the full depth:  X(G) = X(1) + (G-1)·(X(2)-X(1)).
    Exact for parameter/optimizer terms and per-layer collectives (both are
    strictly linear in depth); activations/temp extrapolate linearly in the
    saved-residual component with the constant per-group working set
    captured in the base point.  Methodology recorded in EXPERIMENTS.md.
    """
    cfg_full = configs.get_config(arch)
    gs = cfg_full.group_size
    g_full = cfg_full.num_layers / gs
    pts = []
    for g in (1, 2):
        cfg_g = dataclasses.replace(cfg_full, num_layers=g * gs)
        mem, rf = lower_cell(arch, shape_name, mesh_name, cfg=cfg_g, **kw)
        pts.append((mem, rf))
    (m1, r1), (m2, r2) = pts
    lerp = lambda a, b: a + (g_full - 1) * (b - a)
    memory = {k: lerp(m1[k], m2[k]) for k in m1}
    coll = {k: lerp(r1.coll_breakdown.get(k, 0.0),
                    r2.coll_breakdown.get(k, 0.0))
            for k in set(r1.coll_breakdown) | set(r2.coll_breakdown)}
    model_flops = (roofline_lib.model_flops_train(
        cfg_full, configs.SHAPES[shape_name].global_batch
        * configs.SHAPES[shape_name].seq_len)
        if configs.SHAPES[shape_name].kind == "train"
        else roofline_lib.model_flops_prefill(
            cfg_full, configs.SHAPES[shape_name].global_batch
            * configs.SHAPES[shape_name].seq_len)
        if configs.SHAPES[shape_name].kind == "prefill"
        else roofline_lib.model_flops_decode(
            cfg_full, configs.SHAPES[shape_name].global_batch))
    rf = roofline_lib.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name + "*",
        flops_per_chip=lerp(r1.flops_per_chip, r2.flops_per_chip),
        bytes_per_chip=lerp(r1.bytes_per_chip, r2.bytes_per_chip),
        coll_bytes_per_chip=coll.get("total", 0.0),
        coll_breakdown=coll,
        t_compute=lerp(r1.t_compute, r2.t_compute),
        t_memory=lerp(r1.t_memory, r2.t_memory),
        t_collective=lerp(r1.t_collective, r2.t_collective),
        model_flops=model_flops,
        peak_mem_bytes=lerp(r1.peak_mem_bytes, r2.peak_mem_bytes),
        n_chips=r1.n_chips,
    )
    return memory, rf


def run_cell(arch: str, shape_name: str, mesh_name: str,
             method: str = "direct", **kw) -> CellResult:
    t0 = time.time()
    try:
        if method == "extrapolate":
            memory, rf = lower_cell_extrapolated(arch, shape_name, mesh_name,
                                                 **kw)
        else:
            memory, rf = lower_cell(arch, shape_name, mesh_name, **kw)
        return CellResult(arch, shape_name, mesh_name, True,
                          time.time() - t0, memory=memory,
                          roofline=rf.to_dict())
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        return CellResult(arch, shape_name, mesh_name, False,
                          time.time() - t0,
                          error=f"{type(e).__name__}: {e}\n"
                          + traceback.format_exc(limit=8))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--scan-layers", action="store_true")
    ap.add_argument("--local-impl", default="mask",
                    choices=["mask", "chunked"])
    ap.add_argument("--rules", default="",
                    help="logical=phys overrides, comma separated "
                         "(e.g. seq=model,cache_seq=None)")
    ap.add_argument("--opt-level", type=int, default=0)
    ap.add_argument("--attn-qchunk", type=int, default=0)
    ap.add_argument("--method", default="auto",
                    choices=["auto", "direct", "extrapolate"],
                    help="auto: direct unrolled compile for small archs, "
                         "two-point extrapolation for very deep ones; "
                         "multi-pod always compiles the full graph "
                         "(scan-layers build) as the shardability proof")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    extra = {}
    for kv in args.rules.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        extra[k] = None if v in ("None", "none", "") else (
            tuple(v.split("+")) if "+" in v else v)

    archs = [args.arch] if args.arch else configs.ARCHS
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    heavy = {"qwen3-32b", "grok-1-314b", "arctic-480b"}
    results = []
    for arch in archs:
        shapes = [args.shape] if args.shape else configs.applicable_shapes(
            arch)
        for shape in shapes:
            for mesh_name in meshes:
                if args.method == "auto":
                    if mesh_name == "multi":
                        method, scan = "direct", True
                    elif arch in heavy:
                        method, scan = "extrapolate", False
                    else:
                        method, scan = "direct", False
                else:
                    method, scan = args.method, args.scan_layers
                r = run_cell(arch, shape, mesh_name, method=method,
                             scan_layers=scan, opt_level=args.opt_level,
                             attn_qchunk=args.attn_qchunk,
                             extra_rules=extra, local_impl=args.local_impl)
                results.append(r)
                status = "OK " if r.ok else "FAIL"
                mem = (f"{r.memory['per_chip_total'] / 2**30:.2f} GiB/chip"
                       if r.memory else "-")
                print(f"[{status}] {arch:22s} {shape:12s} {mesh_name:6s} "
                      f"{r.seconds:7.1f}s  {mem}", flush=True)
                if not r.ok:
                    print(r.error, file=sys.stderr, flush=True)
                tag = f"{arch}_{shape}_{mesh_name}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(dataclasses.asdict(r), f, indent=2)
    nfail = sum(not r.ok for r in results)
    print(f"\n{len(results) - nfail}/{len(results)} cells compiled")
    rows = [roofline_lib.Roofline(**{k: v for k, v in r.roofline.items()
                                     if k in {f.name for f in
                                              dataclasses.fields(
                                                  roofline_lib.Roofline)}})
            for r in results if r.ok]
    print(roofline_lib.render_table(rows))
    return 1 if nfail else 0


if __name__ == "__main__":
    sys.exit(main())
