"""Roofline accounting from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step on TPU v5e:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_wire_bytes_per_chip / ICI_bw

``compiled.cost_analysis()`` / ``memory_analysis()`` are per-chip for SPMD
executables (verified empirically — the partitioned module is one chip's
program).  Collective bytes are not in cost_analysis: we parse the optimised
HLO and sum *result* shapes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute ops, scaled per op type to wire bytes
(all-reduce moves ~2·(N-1)/N× its buffer in a ring; all-gather and
reduce-scatter (N-1)/N×; permute 1×).  N per op is read from its
replica_groups literal.
"""
from __future__ import annotations

import dataclasses
import json
import re

from repro.launch import mesh as mesh_lib

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce(?:-start)?|all-gather(?:-start)?|"
    r"reduce-scatter|all-to-all|collective-permute(?:-start)?)\(")

_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[\d,]*)\]")

_GROUP_RE = re.compile(r"replica_groups=\[(?P<rows>\d+),(?P<cols>\d+)\]")


def _shape_bytes(result: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(result):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _wire_factor(op: str, group_size: int) -> float:
    n = max(group_size, 1)
    if op.startswith("all-reduce"):
        return 2.0 * (n - 1) / n
    if op.startswith(("all-gather", "reduce-scatter")):
        return (n - 1) / n
    if op.startswith("all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind wire bytes (per chip) summed over the module."""
    out: dict[str, float] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line:
            continue
        op = m.group("op").replace("-start", "")
        g = _GROUP_RE.search(line)
        group = int(g.group("cols")) if g else 1
        b = _shape_bytes(m.group("result")) * _wire_factor(op, group)
        out[op] = out.get(op, 0.0) + b
        total += b
    out["total"] = total
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float               # 6·N·D (dense) / 6·N_active·D (MoE)
    peak_mem_bytes: float            # memory_analysis temp+args+output
    n_chips: int

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — remat/dispatch waste detector."""
        hlo_global = self.flops_per_chip * self.n_chips
        return self.model_flops / hlo_global if hlo_global > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: the score we hillclimb."""
        t_useful = self.model_flops / (self.n_chips
                                       * mesh_lib.PEAK_FLOPS_BF16)
        return t_useful / self.t_bound if self.t_bound > 0 else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(bottleneck=self.bottleneck, useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction,
                 t_bound=self.t_bound)
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, n_chips: int,
            model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=coll["total"], coll_breakdown=coll,
        t_compute=flops / mesh_lib.PEAK_FLOPS_BF16,
        t_memory=byts / mesh_lib.HBM_BW,
        t_collective=coll["total"] / mesh_lib.ICI_BW,
        model_flops=model_flops, peak_mem_bytes=float(peak),
        n_chips=n_chips,
    )


def model_flops_train(cfg, tokens: int) -> float:
    """6·N·D with N = active params (MoE: top-k experts + shared)."""
    n = active_param_count(cfg)
    return 6.0 * n * tokens


def model_flops_prefill(cfg, tokens: int) -> float:
    """Forward only: 2·N·D."""
    return 2.0 * active_param_count(cfg) * tokens


def model_flops_decode(cfg, batch: int) -> float:
    """Per decode step: 2·N_active per generated token (fwd only) — plus the
    KV-cache read is memory, not FLOPs."""
    return 2.0 * active_param_count(cfg) * batch


def active_param_count(cfg) -> float:
    """Params touched per token (MoE counts top_k of num_experts)."""
    total = cfg.param_count()
    if cfg.num_experts:
        d, f, e, k = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.top_k
        expert_params = 3 * d * f
        n_moe_layers = (cfg.block_pattern * cfg.num_groups
                        + cfg.tail_pattern).count("moe")
        total = total - n_moe_layers * e * expert_params \
            + n_moe_layers * k * expert_params
    return float(total)


def render_table(rows: list[Roofline]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':9s} {'flops/chip':>11s} "
           f"{'bytes/chip':>11s} {'coll B/chip':>11s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s} "
           f"{'roofline':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:9s} "
            f"{r.flops_per_chip:11.3e} {r.bytes_per_chip:11.3e} "
            f"{r.coll_bytes_per_chip:11.3e} {r.t_compute:9.2e} "
            f"{r.t_memory:9.2e} {r.t_collective:9.2e} "
            f"{r.bottleneck:>10s} {r.useful_ratio:7.3f} "
            f"{r.roofline_fraction:8.3f}")
    return "\n".join(lines)


def save_json(rows: list[Roofline], path: str) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=2)
