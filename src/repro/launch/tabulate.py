"""Regenerate the EXPERIMENTS.md roofline table from dry-run artifacts.

Usage: PYTHONPATH=src python -m repro.launch.tabulate [dir] [--md]
"""
from __future__ import annotations

import glob
import json
import sys

from repro import configs


def load(directory: str = "experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(f"{directory}/*.json")):
        rows.append(json.load(open(f)))
    return rows


def render(rows, md: bool = False) -> str:
    out = []
    hdr = ["arch", "shape", "mesh", "GiB/chip", "t_comp(s)", "t_mem(s)",
           "t_coll(s)", "bound", "useful", "roofline", "note"]
    if md:
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    else:
        out.append(f"{'arch':22s} {'shape':12s} {'mesh':7s} {'GiB/chip':>9s} "
                   f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
                   f"{'bound':>10s} {'useful':>7s} {'roofline':>8s}  note")
    for arch in configs.ARCHS:
        for shape in configs.SHAPES:
            skip = shape == "long_500k" and arch not in configs.SUBQUADRATIC
            for mesh in ("single", "multi"):
                key = (arch, shape, mesh)
                r = next((x for x in rows if (x["arch"], x["shape"],
                                              x["mesh"]) == key), None)
                if skip:
                    if mesh == "single":
                        cells = [arch, shape, "-", "-", "-", "-", "-", "-",
                                 "-", "-",
                                 "skipped: full-attention arch (DESIGN §4)"]
                        out.append("| " + " | ".join(cells) + " |" if md
                                   else f"{arch:22s} {shape:12s} "
                                   f"{'skipped (full-attention arch)'}")
                    continue
                if r is None:
                    continue
                if not r["ok"]:
                    line = [arch, shape, mesh, "-", "-", "-", "-", "FAIL",
                            "-", "-", r["error"].splitlines()[0][:60]]
                else:
                    rf = r["roofline"]
                    gib = r["memory"]["per_chip_total"] / 2**30
                    if mesh == "multi":
                        # scan build: cost_analysis counts the loop body
                        # once -> only memory/shardability are meaningful
                        line = [arch, shape, mesh, f"{gib:.2f}", "—", "—",
                                "—", "—", "—", "—",
                                "shardability proof (scan build)"]
                    else:
                        note = ("two-point depth extrapolation"
                                if rf["mesh"].endswith("*") else "")
                        line = [arch, shape, mesh, f"{gib:.2f}",
                                f"{rf['t_compute']:.3e}",
                                f"{rf['t_memory']:.3e}",
                                f"{rf['t_collective']:.3e}",
                                rf["bottleneck"],
                                f"{rf['useful_ratio']:.3f}",
                                f"{rf['roofline_fraction']:.4f}", note]
                if md:
                    out.append("| " + " | ".join(line) + " |")
                else:
                    out.append(f"{line[0]:22s} {line[1]:12s} {line[2]:7s} "
                               f"{line[3]:>9s} {line[4]:>9s} {line[5]:>9s} "
                               f"{line[6]:>9s} {line[7]:>10s} {line[8]:>7s} "
                               f"{line[9]:>8s}  {line[10]}")
    return "\n".join(out)


def write_experiments(path: str = "EXPERIMENTS.md",
                      directory: str = "experiments/dryrun") -> None:
    """Replace the <!-- ROOFLINE_TABLE --> block in EXPERIMENTS.md."""
    table = render(load(directory), md=True)
    text = open(path).read()
    start = text.index("<!-- ROOFLINE_TABLE -->")
    end = text.index("<!-- /ROOFLINE_TABLE -->")
    new = (text[:start] + "<!-- ROOFLINE_TABLE -->\n" + table + "\n"
           + text[end:])
    open(path, "w").write(new)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 and not sys.argv[1].startswith("--") \
        else "experiments/dryrun"
    if "--write-experiments" in sys.argv:
        write_experiments(directory=d)
        print("EXPERIMENTS.md updated")
    else:
        print(render(load(d), md="--md" in sys.argv))
