"""Training launcher: ``python -m repro.launch.train --arch <id> [--tiny]``.

Host-scale runs (this container) use the tiny config; the full configs are
exercised via the dry-run.  ``--resume`` restores the latest checkpoint;
``--restarts N`` wraps the loop in crash-restart (ft/monitor).  The GAPP
profile is printed at the end of every run — the profiler is on by default,
as in the paper ("works out of the box").
"""
from __future__ import annotations

import argparse

from repro import configs
from repro.ft.monitor import run_with_restarts
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def build_trainer(arch: str, *, tiny: bool = True, steps: int = 50,
                  batch: int = 8, seq: int = 128, ckpt_dir: str | None = None,
                  loader_delay_s: float = 0.0, profile: bool = True,
                  compress: str = "none") -> Trainer:
    cfg = configs.get_tiny(arch) if tiny else configs.get_config(arch)
    tcfg = TrainerConfig(
        steps=steps, batch_per_host=batch, seq_len=seq,
        ckpt_dir=ckpt_dir or f"/tmp/repro_ckpt_{arch}",
        ckpt_every=max(steps // 2, 1), profile=profile,
        loader_delay_s=loader_delay_s)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)
    import jax
    from repro.train.step import make_train_step
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, compress=compress),
                      donate_argnums=(0, 1))
    return Trainer(cfg, opt_cfg, tcfg, step_fn=step_fn)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=configs.ARCHS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (expect host-scale OOM; dry-run "
                         "is the full-size path)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--restarts", type=int, default=0)
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--loader-delay", type=float, default=0.0)
    args = ap.parse_args(argv)

    trainer = build_trainer(args.arch, tiny=not args.full, steps=args.steps,
                            batch=args.batch, seq=args.seq,
                            loader_delay_s=args.loader_delay,
                            compress=args.compress)

    def attempt(start_step: int) -> int:
        trainer.run(start_step=None if (start_step == 0 and not args.resume)
                    else -1)
        return trainer.tcfg.steps

    if args.restarts:
        run_with_restarts(attempt, max_restarts=args.restarts)
    else:
        attempt(0)

    if trainer.gapp is not None:
        from repro.core.report import render_text
        print(render_text(trainer.profile_report(), max_paths=5))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
