"""Sharding: logical-axis annotations + parameter partition rules."""
from repro.sharding.api import (ShardingRules, axis_size, constrain,
                                default_rules, named_sharding, use_mesh)
from repro.sharding.params import (logical_param_specs, param_shardings,
                                   physical_specs)

__all__ = [
    "ShardingRules", "axis_size", "constrain", "default_rules",
    "named_sharding", "use_mesh", "logical_param_specs", "param_shardings",
    "physical_specs",
]
