"""Parameter partition rules: param-tree paths -> logical axes -> mesh.

Rules are matched on (parent-key, leaf-key) pairs, first match wins.  Axes
whose physical size doesn't divide the dimension are dropped (e.g. grok's
8-expert axis on a 16-way model axis falls back to replication, with the
launcher instead binding ``expert_mlp`` for tensor-parallel experts).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.api import ShardingRules

# ((parent regex, leaf regex), logical axes per dim)
_RULES: list[tuple[tuple[str, str], tuple] ] = [
    ((r".*", r"embed"), ("vocab", "embed")),
    ((r".*", r"lm_head"), ("embed", "vocab")),
    ((r".*", r"(enc_)?frontend"), (None, "embed")),
    # attention (parent attn/xattn)
    ((r"attn|xattn", r"wq"), ("embed", "heads_flat")),
    ((r"attn|xattn", r"w[kv]"), ("embed", "kv_flat")),
    ((r"attn|xattn", r"bq"), ("heads_flat",)),
    ((r"attn|xattn", r"b[kv]"), ("kv_flat",)),
    ((r"attn|xattn", r"wo"), ("heads_flat", "embed")),
    ((r"attn|xattn", r"[qk]_norm"), (None,)),
    # MoE (parent ffn)
    ((r"ffn", r"router"), ("embed", "experts")),
    ((r"ffn", r"we_(gate|up)"), ("experts", "expert_in", "expert_mlp")),
    ((r"ffn", r"we_down"), ("experts", "expert_mlp", "expert_in")),
    ((r"ffn", r"dense_(gate|up)"), ("embed", "mlp")),
    ((r"ffn", r"dense_down"), ("mlp", "embed")),
    # dense MLP (parent ffn)
    ((r"ffn", r"(gate|up)"), ("embed", "mlp")),
    ((r"ffn", r"down"), ("mlp", "embed")),
    # RG-LRU (parent mix)
    ((r"mix", r"w[xy]"), ("embed", "lru")),
    ((r"mix", r"conv_w"), (None, "lru")),
    ((r"mix", r"(conv_b|gate_.*|log_lambda)"), ("lru",)),
    # RWKV time mix (parent mix)
    ((r"mix", r"w[rkvg]"), ("embed", "heads_flat")),
    ((r"mix", r"decay_w1"), ("embed", None)),
    ((r"mix", r"decay_w2"), (None, "heads_flat")),
    ((r"mix", r"bonus_u"), ("rwkv_heads", None)),
    ((r"mix", r"(mix_.*|decay_base|ln_x)"), (None,)),
    ((r"mix", r"wo"), ("heads_flat", "embed")),
    # RWKV channel mix (parent ffn)
    ((r"ffn", r"w[k]"), ("embed", "mlp")),
    ((r"ffn", r"wv"), ("mlp", "embed")),
    ((r"ffn", r"wr"), ("embed", None)),
    ((r"ffn", r"mix_.*"), (None,)),
]

PARAM_LOGICAL_EXTRA = {
    "heads_flat": "model",
    "kv_flat": "model",
}


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def _match(path_names: list[str]) -> tuple | None:
    leaf = path_names[-1]
    parents = path_names[:-1]
    for (pp, lp), axes in _RULES:
        if not re.fullmatch(lp, leaf):
            continue
        if pp == r".*" or any(re.fullmatch(pp, p) for p in parents):
            return axes
    return None


def logical_param_specs(shapes, cfg=None):
    """Pytree of logical-axis tuples matching a params(-shape) pytree."""
    def one(path, leaf):
        names = _path_names(path)
        axes = _match(names)
        if axes is None:
            return (None,) * len(leaf.shape)
        axes = tuple(axes) + (None,) * (len(leaf.shape) - len(axes))
        return axes[: len(leaf.shape)]
    return jax.tree_util.tree_map_with_path(one, shapes)


def physical_specs(shapes, mesh: Mesh, rules: ShardingRules):
    """Pytree of PartitionSpec with divisibility filtering."""
    table = dict(rules.table)
    table.update({k: v for k, v in PARAM_LOGICAL_EXTRA.items()
                  if k not in table})
    logical = logical_param_specs(shapes)

    from repro.sharding.api import filter_entry

    def bind(leaf_shape, axes):
        spec = []
        used: set = set()
        for dim, name in zip(leaf_shape.shape, axes):
            phys = table.get(name) if name else None
            spec.append(filter_entry(dim, phys, mesh, used))
        return P(*spec)

    return jax.tree.map(bind, shapes, logical)


def param_shardings(shapes, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        physical_specs(shapes, mesh, rules))
