"""Logical-axis sharding: models annotate, rules bind axes to the mesh.

Models never mention physical mesh axes.  They call
``constrain(x, "batch", "seq", "embed")`` with *logical* axis names; a
:class:`ShardingRules` table (chosen per arch × shape by the launcher) maps
logical names to physical mesh axes, and ``use_mesh`` installs the binding
for a region of code.  Outside any binding the constraints are no-ops, so
the same model code runs single-device (smoke tests) and on the production
mesh (dry-run) unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


# Default logical→physical table.  "dp" is the data-parallel super-axis
# (pod × data on the multi-pod mesh).
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),      # activation batch
    "seq": None,                   # activation sequence (set to "model" for SP)
    "resid_seq": None,             # residual stream between blocks — bind to
                                   # "model" for Megatron-style sequence
                                   # parallelism (AG at block entry, RS at
                                   # exit; intra-block tensors keep TP)
    "cache_seq": None,             # KV-cache sequence (set to "model" for
                                   # sequence-sharded flash-decode)
    "embed": None,                 # d_model — replicated
    "heads": "model",              # attention heads (TP)
    "kv_heads": None,              # kv heads — replicated unless divisible
    "head_dim": None,
    "mlp": "model",                # FFN hidden (TP)
    "vocab": "model",              # embedding/logits vocab (TP)
    "experts": "model",            # MoE expert axis of *weights* (EP)
    "experts_act": "model",        # MoE expert axis of dispatched activations
    "expert_in": None,             # per-expert FFN input dim (FSDP-style
                                   # weight sharding for huge expert tables)
    "expert_mlp": None,            # per-expert FFN hidden (TP fallback for
                                   # E < mesh 'model' size)
    "lru": "model",                # RG-LRU width
    "rwkv_heads": "model",
    "stage": "stage",              # pipeline stage (pipeline/ only)
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    table: dict

    def spec(self, *logical) -> P:
        phys = []
        for name in logical:
            if name is None:
                phys.append(None)
            else:
                phys.append(self.table.get(name))
        return P(*phys)

    def replace(self, **updates) -> "ShardingRules":
        t = dict(self.table)
        t.update(updates)
        return ShardingRules(t)


def default_rules(**updates) -> ShardingRules:
    return ShardingRules(dict(DEFAULT_RULES)).replace(**updates) \
        if updates else ShardingRules(dict(DEFAULT_RULES))


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: ShardingRules | None = None):
    prev = getattr(_state, "binding", None)
    _state.binding = (mesh, rules or default_rules()) if mesh is not None \
        else None
    try:
        yield
    finally:
        _state.binding = prev


def current_binding():
    return getattr(_state, "binding", None)


def axis_size(name: str) -> int:
    """Size of the physical axis a logical name maps to (1 if unbound)."""
    b = current_binding()
    if b is None:
        return 1
    mesh, rules = b
    phys = rules.table.get(name)
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        out = 1
        for a in phys:
            out *= mesh.shape[a]
        return out
    return mesh.shape[phys]


def filter_entry(dim: int, names, mesh, used: set | None = None) -> object:
    """Resolve one PartitionSpec entry against a mesh: drop axes the mesh
    doesn't have (e.g. 'pod' on the single-pod mesh), axes already used by
    an earlier dimension (first use wins), and the whole entry if the
    remaining axis product doesn't divide the dimension."""
    if names is None:
        return None
    ns = tuple(n for n in (names if isinstance(names, tuple) else (names,))
               if n in mesh.shape and (used is None or n not in used))
    if not ns:
        return None
    size = 1
    for n in ns:
        size *= mesh.shape[n]
    if dim <= 0 or dim % size != 0:
        return None
    if used is not None:
        used.update(ns)
    return ns if len(ns) > 1 else ns[0]


def filter_spec(shape: tuple, spec: P, mesh) -> P:
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    used: set = set()
    return P(*[filter_entry(d, n, mesh, used) for d, n in
               zip(shape, entries)])


def _filter_spec(x, spec: P) -> P | None:
    b = current_binding()
    if b is None:
        return None
    mesh, _ = b
    return filter_spec(x.shape, spec, mesh)


def constrain(x, *logical):
    """``with_sharding_constraint`` against the active binding (no-op when
    unbound or when an axis size doesn't divide)."""
    b = current_binding()
    if b is None:
        return x
    mesh, rules = b
    spec = _filter_spec(x, rules.spec(*logical))
    if spec is None or all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical) -> NamedSharding:
    b = current_binding()
    assert b is not None, "named_sharding requires an active use_mesh binding"
    mesh, rules = b
    return NamedSharding(mesh, rules.spec(*logical))
