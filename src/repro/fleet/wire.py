"""Fleet wire format — versioned, length-prefixed binary event frames.

One GAPP host streams its drained event chunks to an ingest server as a
sequence of *frames* over any reliable byte stream (TCP in
:mod:`repro.fleet.transport`, a file, a pipe).  The format is deliberately
dumb: length-prefixed frames with a fixed header, JSON payloads for the
low-rate control plane (handshake, registry sync) and the profiler's own
redaction-free columnar layout — the exact five columns the fold consumes
(``times/workers/deltas/tags/stacks``, the
:class:`~repro.core.spill.SpillStore` block layout) — for the data plane,
so decode on the server is five ``np.frombuffer`` calls and zero row loops.

Frame header (8 bytes, little-endian)::

    ┌──────┬───────┬────────────────┬─────────────┐
    │ u8   │ u8    │ u16            │ u32         │
    │ kind │ flags │ schema_version │ payload_len │
    └──────┴───────┴────────────────┴─────────────┘

``schema_version`` == :data:`WIRE_VERSION` (bump on breaking layout
changes; a decoder must reject frames with a newer major).  ``flags`` is
reserved (must be 0).

Frame kinds and payloads:

    ====== ========= ==================================================
    kind   name      payload
    ====== ========= ==================================================
    0x01   HELLO     JSON — ``{"magic": "gapp-fleet", "wire_version",
                     "host_id", "num_workers", "worker_names",
                     "t_client_ns", "clock_offset_ns"}``; first frame of
                     every connection.  ``t_client_ns`` is the host's
                     capture clock sampled immediately before send;
                     ``clock_offset_ns`` is the *declared* offset to the
                     fleet clock (``null`` ⇒ the server measures
                     ``t_server − t_client`` at receipt).
    0x02   WELCOME   JSON — ``{"host_index", "epoch",
                     "clock_offset_ns"}``; the server's reply.  ``epoch``
                     is the clock-sync generation: every CHUNK must echo
                     it, and a reconnect (new HELLO) advances it, so
                     chunks timed under a stale offset are detectable.
    0x03   CHUNK     binary — 24-byte chunk header ``<u16 host_index>
                     <u16 shard_id> <u64 epoch> <u64 seq> <u32 nrows>``
                     followed by the five columns, each ``nrows`` long, in
                     order: ``times i64 · workers i32 · deltas i8 ·
                     tags i32 · stacks i32`` (== one SpillStore block).
                     ``shard_id`` 0xFFFF means "merged across shards"
                     (what a drained tracer chunk is).  ``seq`` numbers
                     the host's chunks from 0 across the whole capture
                     (NOT reset on reconnect): the server drops
                     already-seen sequence numbers (retransmits fold
                     exactly once) and counts sequence gaps as
                     ``lost_chunks`` (loss is detected, not recovered —
                     the sink only retains its one in-flight chunk).
    0x04   TAGS      JSON — ``{"entries": [[tag_id, name, location],…]}``
                     incremental tag-registry sync; ids are host-local
                     and must be sent before any CHUNK references them.
    0x05   STACKS    JSON — ``{"entries": [[stack_id, [tag_id,…]],…]}``
                     incremental call-path registry sync (host-local tag
                     ids, caller→callee).
    0x06   BYE       JSON — ``{"rows_sent", "chunks_sent"}`` final
                     accounting; lets the server assert losslessness.
    ====== ========= ==================================================

Round-trip guarantee: ``decode_chunk(encode_chunk(c)) == c`` bit-exact for
every column (dtype-preserving) — tested in ``tests/test_fleet_wire.py``.
"""
from __future__ import annotations

import dataclasses
import json
import struct

import numpy as np

WIRE_VERSION = 1
MAGIC = "gapp-fleet"

# frame kinds
HELLO = 0x01
WELCOME = 0x02
CHUNK = 0x03
TAGS = 0x04
STACKS = 0x05
BYE = 0x06

KIND_NAMES = {HELLO: "HELLO", WELCOME: "WELCOME", CHUNK: "CHUNK",
              TAGS: "TAGS", STACKS: "STACKS", BYE: "BYE"}

# merged-across-shards sentinel for the CHUNK shard_id field
MERGED_SHARD = 0xFFFF

_FRAME_HEADER = struct.Struct("<BBHI")          # kind, flags, schema, len
_CHUNK_HEADER = struct.Struct("<HHQQI")         # host, shard, epoch, seq, n

# Column order and dtypes of one chunk — THE SpillStore block layout (one
# shared definition, so the disk and wire formats cannot drift apart).
from repro.core.spill import _COL_DTYPES as COL_DTYPES          # noqa: E402
from repro.core.spill import _ROW_BYTES as ROW_BYTES            # noqa: E402

# Refuse absurd frames before allocating (a corrupt length prefix must not
# OOM the server): 64 MiB is ~3.2M rows, far above any drain chunk.
MAX_PAYLOAD = 64 << 20


class WireError(ValueError):
    """Malformed or incompatible frame."""


@dataclasses.dataclass
class ChunkFrame:
    """One decoded CHUNK: provenance header + the five event columns."""

    host_index: int
    shard_id: int
    epoch: int
    seq: int
    times: np.ndarray      # int64[n]
    workers: np.ndarray    # int32[n]
    deltas: np.ndarray     # int8[n]
    tags: np.ndarray       # int32[n]
    stacks: np.ndarray     # int32[n]

    def __len__(self) -> int:
        return int(self.times.shape[0])

    @property
    def columns(self):
        return (self.times, self.workers, self.deltas, self.tags,
                self.stacks)


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def pack_frame(kind: int, payload: bytes) -> bytes:
    """Frame ``payload`` with the 8-byte header."""
    if len(payload) > MAX_PAYLOAD:
        raise WireError(f"payload {len(payload)}B exceeds MAX_PAYLOAD")
    return _FRAME_HEADER.pack(kind, 0, WIRE_VERSION, len(payload)) + payload


def _read_exact(stream, n: int) -> bytes:
    """Read exactly ``n`` bytes from a file-like/socket-file stream;
    returns ``b""`` on clean EOF at a frame boundary, raises on a short
    read mid-frame."""
    buf = bytearray()
    while len(buf) < n:
        part = stream.read(n - len(buf))
        if not part:
            if not buf:
                return b""
            raise WireError(f"stream truncated mid-frame "
                            f"({len(buf)}/{n} bytes)")
        buf += part
    return bytes(buf)


def read_frame(stream) -> tuple[int, bytes] | None:
    """Read one frame; ``None`` on clean EOF.  Validates header fields."""
    hdr = _read_exact(stream, _FRAME_HEADER.size)
    if not hdr:
        return None
    kind, flags, version, length = _FRAME_HEADER.unpack(hdr)
    if flags != 0:
        raise WireError(f"unknown flags 0x{flags:02x}")
    if version != WIRE_VERSION:
        raise WireError(f"wire version {version} != {WIRE_VERSION}")
    if length > MAX_PAYLOAD:
        raise WireError(f"frame length {length} exceeds MAX_PAYLOAD")
    payload = _read_exact(stream, length) if length else b""
    if length and not payload:
        raise WireError("stream truncated before payload")
    return kind, payload


# ---------------------------------------------------------------------------
# control plane (JSON payloads)
# ---------------------------------------------------------------------------

def encode_json(kind: int, obj: dict) -> bytes:
    return pack_frame(kind, json.dumps(obj, separators=(",", ":"))
                      .encode("utf-8"))


def decode_json(payload: bytes) -> dict:
    try:
        obj = json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"bad control payload: {e}") from None
    if not isinstance(obj, dict):
        raise WireError("control payload is not an object")
    return obj


def encode_hello(host_id: str, num_workers: int, worker_names: list[str],
                 t_client_ns: int, clock_offset_ns: int | None,
                 instance: str = "") -> bytes:
    """``instance`` is a per-capture nonce: a *reconnect* of the same
    capture repeats it (the server keeps the seq-dedup floor), while a
    producer *restart* sends a fresh one (the floor resets — otherwise the
    new capture's chunks would all be dropped as retransmits)."""
    return encode_json(HELLO, {
        "magic": MAGIC, "wire_version": WIRE_VERSION, "host_id": host_id,
        "num_workers": int(num_workers), "worker_names": list(worker_names),
        "t_client_ns": int(t_client_ns),
        "clock_offset_ns": (None if clock_offset_ns is None
                            else int(clock_offset_ns)),
        "instance": str(instance),
    })


def decode_hello(payload: bytes) -> dict:
    obj = decode_json(payload)
    if obj.get("magic") != MAGIC:
        raise WireError(f"bad magic {obj.get('magic')!r}")
    if obj.get("wire_version") != WIRE_VERSION:
        raise WireError(f"wire version {obj.get('wire_version')} "
                        f"!= {WIRE_VERSION}")
    return obj


def encode_welcome(host_index: int, epoch: int, clock_offset_ns: int) -> bytes:
    return encode_json(WELCOME, {"host_index": int(host_index),
                                 "epoch": int(epoch),
                                 "clock_offset_ns": int(clock_offset_ns)})


def encode_tags(entries: list[tuple[int, str, str]]) -> bytes:
    return encode_json(TAGS, {"entries": [[int(i), n, loc]
                                          for i, n, loc in entries]})


def encode_stacks(entries: list[tuple[int, tuple[int, ...]]]) -> bytes:
    return encode_json(STACKS, {"entries": [[int(i), [int(t) for t in p]]
                                            for i, p in entries]})


def encode_bye(rows_sent: int, chunks_sent: int) -> bytes:
    return encode_json(BYE, {"rows_sent": int(rows_sent),
                             "chunks_sent": int(chunks_sent)})


# ---------------------------------------------------------------------------
# data plane (columnar CHUNK payloads)
# ---------------------------------------------------------------------------

def encode_chunk(host_index: int, shard_id: int, epoch: int, seq: int,
                 times, workers, deltas, tags, stacks) -> bytes:
    """Frame one columnar event chunk (the drained-batch layout)."""
    cols = [np.ascontiguousarray(c, dt) for c, dt in
            zip((times, workers, deltas, tags, stacks), COL_DTYPES)]
    n = len(cols[0])
    for c in cols:
        if len(c) != n:
            raise WireError("chunk columns misaligned")
    payload = b"".join(
        [_CHUNK_HEADER.pack(host_index, shard_id, epoch, seq, n)]
        + [c.tobytes() for c in cols])
    return pack_frame(CHUNK, payload)


def decode_chunk(payload: bytes) -> ChunkFrame:
    """Inverse of :func:`encode_chunk` — bit-exact columns, no row loops."""
    if len(payload) < _CHUNK_HEADER.size:
        raise WireError("chunk payload shorter than its header")
    host, shard, epoch, seq, n = _CHUNK_HEADER.unpack_from(payload)
    expect = _CHUNK_HEADER.size + n * ROW_BYTES
    if len(payload) != expect:
        raise WireError(f"chunk payload {len(payload)}B != expected "
                        f"{expect}B for {n} rows")
    off = _CHUNK_HEADER.size
    cols = []
    for dt in COL_DTYPES:
        nbytes = n * np.dtype(dt).itemsize
        cols.append(np.frombuffer(payload, dt, count=n, offset=off).copy())
        off += nbytes
    return ChunkFrame(host, shard, epoch, seq, *cols)
