"""Fleet wire format — versioned, length-prefixed binary event frames.

One GAPP host streams its drained event chunks to an ingest server as a
sequence of *frames* over any reliable byte stream (TCP in
:mod:`repro.fleet.transport`, a file, a pipe).  The format is deliberately
dumb: length-prefixed frames with a fixed header, JSON payloads for the
low-rate control plane (handshake, registry sync) and the profiler's own
redaction-free columnar layout — the exact five columns the fold consumes
(``times/workers/deltas/tags/stacks``, the
:class:`~repro.core.spill.SpillStore` block layout) — for the data plane,
so decode on the server is five ``np.frombuffer`` calls and zero row loops.

Frame header (8 bytes, little-endian)::

    ┌──────┬───────┬────────────────┬─────────────┐
    │ u8   │ u8    │ u16            │ u32         │
    │ kind │ flags │ schema_version │ payload_len │
    └──────┴───────┴────────────────┴─────────────┘

``schema_version`` == :data:`WIRE_VERSION` (bump on layout changes; a
decoder accepts every version back to :data:`MIN_WIRE_VERSION` — v2 is a
pure superset of v1 — and rejects anything newer).  ``flags``:

    ====== ================ ==============================================
    bit    name             meaning
    ====== ================ ==============================================
    0x01   FLAG_COMPRESSED  the payload is ``<u32 raw_len>`` followed by
                            a zlib (RFC 1950) stream that inflates to
                            exactly ``raw_len`` bytes of the frame's
                            normal payload.  ``raw_len`` must not exceed
                            :data:`MAX_PAYLOAD` and the inflate is capped
                            at ``raw_len`` (a corrupt or hostile frame
                            can never balloon past the guard).  Senders
                            only set the bit for a codec the receiver
                            negotiated (HELLO ``codecs`` → WELCOME
                            ``codec``) and fall back to a raw frame
                            whenever compression does not shrink the
                            payload.
    ====== ================ ==============================================

Frame kinds and payloads:

    ====== ========= ==================================================
    kind   name      payload
    ====== ========= ==================================================
    0x01   HELLO     JSON — ``{"magic": "gapp-fleet", "wire_version",
                     "host_id", "num_workers", "worker_names",
                     "t_client_ns", "clock_offset_ns", "codecs"}``; first
                     frame of every connection, never compressed (it
                     precedes negotiation).  ``t_client_ns`` is the
                     host's capture clock sampled immediately before
                     send; ``clock_offset_ns`` is the *declared* offset
                     to the fleet clock (``null`` ⇒ the server measures
                     ``t_server − t_client`` at receipt).  ``codecs``
                     (v2, additive) lists the payload codecs the producer
                     can send, in preference order (subset of
                     ``["zlib", "raw"]``; absent ⇒ raw only).
    0x02   WELCOME   JSON — ``{"host_index", "epoch", "clock_offset_ns",
                     "ack_seq", "codec"}``; the server's reply.  ``epoch``
                     is the clock-sync generation: every CHUNK must echo
                     it, and a reconnect (new HELLO) advances it, so
                     chunks timed under a stale offset are detectable.
                     ``ack_seq`` (v2, additive) is the server's durable
                     receive floor — the first CHUNK ``seq`` it has NOT
                     folded for this host; a journaling producer replays
                     ``[ack_seq, next_seq)`` from its local journal
                     after every (re)connect, so producer restarts and
                     in-flight losses become recovered history.
                     ``codec`` (v2, additive) is the payload codec the
                     server selected from the HELLO offer (absent ⇒
                     raw).  ``tags_seen``/``stacks_seen`` (v2, additive)
                     are the server's per-host registry high-water
                     marks; the producer rewinds its incremental sync
                     counters to them, so registry deltas lost with a
                     dead server are retransmitted.
    0x03   CHUNK     binary — 24-byte chunk header ``<u16 host_index>
                     <u16 shard_id> <u64 epoch> <u64 seq> <u32 nrows>``
                     followed by the five columns, each ``nrows`` long, in
                     order: ``times i64 · workers i32 · deltas i8 ·
                     tags i32 · stacks i32`` (== one SpillStore block).
                     ``shard_id`` 0xFFFF means "merged across shards"
                     (what a drained tracer chunk is).  ``seq`` numbers
                     the host's chunks from 0 across the whole capture
                     (NOT reset on reconnect): the server drops
                     already-seen sequence numbers (retransmits fold
                     exactly once) and counts sequence gaps as
                     ``lost_chunks``.  A journaling producer recovers
                     gaps via the WELCOME ``ack_seq`` replay; without a
                     journal the sink only retains its one in-flight
                     chunk and loss is detected, not recovered.
    0x04   TAGS      JSON — ``{"entries": [[tag_id, name, location],…]}``
                     incremental tag-registry sync; ids are host-local
                     and must be sent before any CHUNK references them.
    0x05   STACKS    JSON — ``{"entries": [[stack_id, [tag_id,…]],…]}``
                     incremental call-path registry sync (host-local tag
                     ids, caller→callee).
    0x06   BYE       JSON — ``{"rows_sent", "chunks_sent"}`` final
                     accounting; lets the server assert losslessness.
    0x07   HEARTBEAT JSON — ``{"t_ns"}`` (v3, additive) producer
                     liveness: sent whenever the producer has been idle
                     for its heartbeat interval, so the server's per-host
                     read deadline distinguishes "alive but quiet" from
                     "silently dead" (a dead producer's stream is retired
                     so it cannot pin the merge watermark).  ``t_ns``
                     (nullable) is the capture-clock time of the last
                     event the producer has *streamed* — a safe low
                     watermark (every future row has time >= it); the
                     server only ever advances its per-host watermark
                     with it.  Producers send heartbeats only to servers
                     that advertised ``server_wire_version >= 3`` in
                     WELCOME (an older server would count the unknown
                     kind as a protocol error).
    ====== ========= ==================================================

Round-trip guarantee: ``decode_chunk(encode_chunk(c)) == c`` bit-exact for
every column (dtype-preserving), with or without compression — tested in
``tests/test_fleet_wire.py``.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib

import numpy as np

WIRE_VERSION = 3        # v3 adds HEARTBEAT + WELCOME.server_wire_version
#                         (v2 added FLAG_COMPRESSED + HELLO.codecs +
#                         WELCOME.ack_seq/codec) — all additive
MIN_WIRE_VERSION = 1    # oldest version this decoder still accepts
MAGIC = "gapp-fleet"

# payload codecs (negotiated: HELLO offers, WELCOME selects)
RAW = "raw"
ZLIB = "zlib"
SUPPORTED_CODECS = (ZLIB, RAW)      # what this build can decode/encode

FLAG_COMPRESSED = 0x01
_KNOWN_FLAGS = FLAG_COMPRESSED

_COMPRESS_MIN = 64          # don't bother deflating tiny control frames
_COMPRESS_LEVEL = 6
_RAW_LEN = struct.Struct("<I")

# frame kinds
HELLO = 0x01
WELCOME = 0x02
CHUNK = 0x03
TAGS = 0x04
STACKS = 0x05
BYE = 0x06
HEARTBEAT = 0x07

KIND_NAMES = {HELLO: "HELLO", WELCOME: "WELCOME", CHUNK: "CHUNK",
              TAGS: "TAGS", STACKS: "STACKS", BYE: "BYE",
              HEARTBEAT: "HEARTBEAT"}

# merged-across-shards sentinel for the CHUNK shard_id field
MERGED_SHARD = 0xFFFF

_FRAME_HEADER = struct.Struct("<BBHI")          # kind, flags, schema, len
_CHUNK_HEADER = struct.Struct("<HHQQI")         # host, shard, epoch, seq, n

# Column order and dtypes of one chunk — THE SpillStore block layout (one
# shared definition, so the disk and wire formats cannot drift apart).
from repro.core.spill import _COL_DTYPES as COL_DTYPES          # noqa: E402
from repro.core.spill import _ROW_BYTES as ROW_BYTES            # noqa: E402

# Refuse absurd frames before allocating (a corrupt length prefix must not
# OOM the server): 64 MiB is ~3.2M rows, far above any drain chunk.
MAX_PAYLOAD = 64 << 20


class WireError(ValueError):
    """Malformed or incompatible frame."""


@dataclasses.dataclass
class ChunkFrame:
    """One decoded CHUNK: provenance header + the five event columns."""

    host_index: int
    shard_id: int
    epoch: int
    seq: int
    times: np.ndarray      # int64[n]
    workers: np.ndarray    # int32[n]
    deltas: np.ndarray     # int8[n]
    tags: np.ndarray       # int32[n]
    stacks: np.ndarray     # int32[n]

    def __len__(self) -> int:
        return int(self.times.shape[0])

    @property
    def columns(self):
        return (self.times, self.workers, self.deltas, self.tags,
                self.stacks)


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def negotiate_codec(offered, preferred=SUPPORTED_CODECS) -> str:
    """Server-side codec pick: first of ``preferred`` the peer offered.
    An absent/empty offer (a v1 producer) or no overlap falls back to
    raw — negotiation can only ever *add* compression, never break a
    connection."""
    offered = [c for c in (offered or ()) if c in SUPPORTED_CODECS]
    for codec in preferred or ():
        if codec in offered:
            return codec
    return RAW


def pack_frame(kind: int, payload: bytes, codec: str = RAW,
               version: int = WIRE_VERSION) -> bytes:
    """Frame ``payload`` with the 8-byte header.  ``codec=ZLIB`` deflates
    the payload (flag bit set) when that actually shrinks it; small or
    incompressible payloads ship raw — the flag is per-frame, so a zlib
    connection degrades gracefully frame by frame.  ``version`` lets a
    reply to an older peer carry *that* peer's schema version (a v1
    decoder rejects v2-stamped frames); v2 fields are additive JSON keys
    a v1 decoder ignores, so the downgrade is stamp-only."""
    if len(payload) > MAX_PAYLOAD:
        raise WireError(f"payload {len(payload)}B exceeds MAX_PAYLOAD")
    if not MIN_WIRE_VERSION <= version <= WIRE_VERSION:
        raise WireError(f"cannot stamp version {version}")
    flags = 0
    if codec == ZLIB and version >= 2 and len(payload) >= _COMPRESS_MIN:
        comp = zlib.compress(payload, _COMPRESS_LEVEL)
        if _RAW_LEN.size + len(comp) < len(payload):
            payload = _RAW_LEN.pack(len(payload)) + comp
            flags = FLAG_COMPRESSED
    elif codec not in (RAW, ZLIB):
        raise WireError(f"unknown codec {codec!r}")
    return _FRAME_HEADER.pack(kind, flags, version, len(payload)) \
        + payload


def _inflate(payload: bytes) -> bytes:
    """Undo :data:`FLAG_COMPRESSED` with a hard decompressed-length guard:
    the declared ``raw_len`` is validated *before* inflating and the
    inflate is capped at it, so a corrupt length can never OOM the
    receiver."""
    if len(payload) < _RAW_LEN.size:
        raise WireError("compressed payload shorter than its length prefix")
    (raw_len,) = _RAW_LEN.unpack_from(payload)
    if raw_len > MAX_PAYLOAD:
        raise WireError(f"declared raw length {raw_len} exceeds MAX_PAYLOAD")
    if raw_len == 0:
        # our encoder never compresses sub-_COMPRESS_MIN payloads, and to
        # zlib max_length=0 means UNLIMITED — a zero here is a bomb, not
        # an empty frame
        raise WireError("compressed frame declares zero raw length")
    d = zlib.decompressobj()
    try:
        out = d.decompress(payload[_RAW_LEN.size:], raw_len)
    except zlib.error as e:
        raise WireError(f"bad zlib payload: {e}") from None
    if len(out) != raw_len or not d.eof or d.unconsumed_tail or d.unused_data:
        raise WireError(f"zlib payload inflates to {len(out)}B "
                        f"(declared {raw_len}B) or has trailing data")
    return out


def frame_from_buffer(buf) -> tuple[int, bytes, int] | None:
    """Non-blocking twin of :func:`read_frame` for event-loop receivers:
    parse ONE frame from the head of ``buf`` (bytes/bytearray/memoryview).
    Returns ``(kind, payload, consumed_bytes)`` when a complete frame is
    present, ``None`` when more bytes are needed; raises :class:`WireError`
    on a malformed header exactly like :func:`read_frame` (the caller
    drops the connection — there is no resync point in the stream)."""
    if len(buf) < _FRAME_HEADER.size:
        return None
    kind, flags, version, length = _FRAME_HEADER.unpack_from(buf)
    if flags & ~_KNOWN_FLAGS:
        raise WireError(f"unknown flags 0x{flags:02x}")
    if not MIN_WIRE_VERSION <= version <= WIRE_VERSION:
        raise WireError(f"wire version {version} outside "
                        f"[{MIN_WIRE_VERSION}, {WIRE_VERSION}]")
    if length > MAX_PAYLOAD:
        raise WireError(f"frame length {length} exceeds MAX_PAYLOAD")
    total = _FRAME_HEADER.size + length
    if len(buf) < total:
        return None
    payload = bytes(buf[_FRAME_HEADER.size:total])
    if flags & FLAG_COMPRESSED:
        payload = _inflate(payload)
    return kind, payload, total


def _read_exact(stream, n: int) -> bytes:
    """Read exactly ``n`` bytes from a file-like/socket-file stream;
    returns ``b""`` on clean EOF at a frame boundary, raises on a short
    read mid-frame."""
    buf = bytearray()
    while len(buf) < n:
        part = stream.read(n - len(buf))
        if not part:
            if not buf:
                return b""
            raise WireError("stream truncated mid-frame "
                            f"({len(buf)}/{n} bytes)")
        buf += part
    return bytes(buf)


def read_frame(stream) -> tuple[int, bytes] | None:
    """Read one frame; ``None`` on clean EOF.  Validates header fields."""
    hdr = _read_exact(stream, _FRAME_HEADER.size)
    if not hdr:
        return None
    kind, flags, version, length = _FRAME_HEADER.unpack(hdr)
    if flags & ~_KNOWN_FLAGS:
        raise WireError(f"unknown flags 0x{flags:02x}")
    if not MIN_WIRE_VERSION <= version <= WIRE_VERSION:
        raise WireError(f"wire version {version} outside "
                        f"[{MIN_WIRE_VERSION}, {WIRE_VERSION}]")
    if length > MAX_PAYLOAD:
        raise WireError(f"frame length {length} exceeds MAX_PAYLOAD")
    payload = _read_exact(stream, length) if length else b""
    if length and not payload:
        raise WireError("stream truncated before payload")
    if flags & FLAG_COMPRESSED:
        payload = _inflate(payload)
    return kind, payload


# ---------------------------------------------------------------------------
# control plane (JSON payloads)
# ---------------------------------------------------------------------------

def encode_json(kind: int, obj: dict, codec: str = RAW) -> bytes:
    return pack_frame(kind, json.dumps(obj, separators=(",", ":"))
                      .encode("utf-8"), codec)


def decode_json(payload: bytes) -> dict:
    try:
        obj = json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"bad control payload: {e}") from None
    if not isinstance(obj, dict):
        raise WireError("control payload is not an object")
    return obj


def encode_hello(host_id: str, num_workers: int, worker_names: list[str],
                 t_client_ns: int, clock_offset_ns: int | None,
                 instance: str = "",
                 codecs: tuple[str, ...] = SUPPORTED_CODECS) -> bytes:
    """``instance`` is a per-capture nonce: a *reconnect* of the same
    capture repeats it (the server keeps the seq-dedup floor), while a
    producer *restart* sends a fresh one (the floor resets — otherwise the
    new capture's chunks would all be dropped as retransmits).  A
    journal-resumed restart deliberately repeats the *saved* nonce so the
    floor survives and only the unacked tail replays.  ``codecs`` is the
    compression offer (see the module spec table); HELLO itself is always
    raw."""
    return encode_json(HELLO, {
        "magic": MAGIC, "wire_version": WIRE_VERSION, "host_id": host_id,
        "num_workers": int(num_workers), "worker_names": list(worker_names),
        "t_client_ns": int(t_client_ns),
        "clock_offset_ns": (None if clock_offset_ns is None
                            else int(clock_offset_ns)),
        "instance": str(instance),
        "codecs": [str(c) for c in codecs],
    })


def decode_hello(payload: bytes) -> dict:
    obj = decode_json(payload)
    if obj.get("magic") != MAGIC:
        raise WireError(f"bad magic {obj.get('magic')!r}")
    v = obj.get("wire_version")
    if not isinstance(v, int) or not MIN_WIRE_VERSION <= v <= WIRE_VERSION:
        raise WireError(f"wire version {v} outside "
                        f"[{MIN_WIRE_VERSION}, {WIRE_VERSION}]")
    return obj


def encode_welcome(host_index: int, epoch: int, clock_offset_ns: int,
                   ack_seq: int = 0, codec: str = RAW,
                   tags_seen: int = 0, stacks_seen: int = 0,
                   version: int = WIRE_VERSION) -> bytes:
    """``tags_seen``/``stacks_seen`` (v2, additive) are the server's
    registry high-water marks for this host: how many host-local tag /
    stack entries it currently knows.  A producer rewinds its incremental
    sync counters to them, so registry deltas lost with a dead server (or
    a server restart that restored less than the producer sent) are
    retransmitted — interning is idempotent server-side.  ``version`` is
    stamped into the frame header: replies to a v1 producer must carry
    version 1 or its decoder rejects them (the extra JSON keys are
    harmless — v1 ignores unknown keys)."""
    obj = {"host_index": int(host_index),
           "epoch": int(epoch),
           "clock_offset_ns": int(clock_offset_ns),
           "ack_seq": int(ack_seq),
           "codec": str(codec),
           "tags_seen": int(tags_seen),
           "stacks_seen": int(stacks_seen),
           # v3, additive: OUR version (the frame header is stamped with
           # the peer's) — a producer only sends HEARTBEAT frames to a
           # server that declares it can decode them
           "server_wire_version": WIRE_VERSION}
    return pack_frame(WELCOME, json.dumps(obj, separators=(",", ":"))
                      .encode("utf-8"), version=version)


def encode_heartbeat(t_ns: int | None = None, codec: str = RAW) -> bytes:
    """Producer liveness beacon (v3).  ``t_ns`` is the capture-clock time
    of the last event already streamed (a safe per-host low watermark), or
    ``None`` when the producer has streamed nothing yet."""
    return encode_json(HEARTBEAT,
                       {"t_ns": None if t_ns is None else int(t_ns)}, codec)


def encode_tags(entries: list[tuple[int, str, str]],
                codec: str = RAW) -> bytes:
    return encode_json(TAGS, {"entries": [[int(i), n, loc]
                                          for i, n, loc in entries]}, codec)


def encode_stacks(entries: list[tuple[int, tuple[int, ...]]],
                  codec: str = RAW) -> bytes:
    return encode_json(STACKS, {"entries": [[int(i), [int(t) for t in p]]
                                            for i, p in entries]}, codec)


def encode_bye(rows_sent: int, chunks_sent: int) -> bytes:
    return encode_json(BYE, {"rows_sent": int(rows_sent),
                             "chunks_sent": int(chunks_sent)})


# ---------------------------------------------------------------------------
# data plane (columnar CHUNK payloads)
# ---------------------------------------------------------------------------

def encode_chunk(host_index: int, shard_id: int, epoch: int, seq: int,
                 times, workers, deltas, tags, stacks,
                 codec: str = RAW) -> bytes:
    """Frame one columnar event chunk (the drained-batch layout)."""
    cols = [np.ascontiguousarray(c, dt) for c, dt in
            zip((times, workers, deltas, tags, stacks), COL_DTYPES)]
    n = len(cols[0])
    for c in cols:
        if len(c) != n:
            raise WireError("chunk columns misaligned")
    payload = b"".join(
        [_CHUNK_HEADER.pack(host_index, shard_id, epoch, seq, n)]
        + [c.tobytes() for c in cols])
    return pack_frame(CHUNK, payload, codec)


def frame_raw_bytes(frame: bytes) -> int:
    """What an encoded frame would cost uncompressed (header included):
    compressed frames declare their inflated size in the payload prefix,
    raw frames cost what they are.  Feeds the sender's wire-savings
    counters."""
    _k, flags, _v, _n = _FRAME_HEADER.unpack_from(frame)
    if flags & FLAG_COMPRESSED:
        (raw_len,) = _RAW_LEN.unpack_from(frame, _FRAME_HEADER.size)
        return _FRAME_HEADER.size + raw_len
    return len(frame)


def decode_chunk(payload: bytes) -> ChunkFrame:
    """Inverse of :func:`encode_chunk` — bit-exact columns, no row loops."""
    if len(payload) < _CHUNK_HEADER.size:
        raise WireError("chunk payload shorter than its header")
    host, shard, epoch, seq, n = _CHUNK_HEADER.unpack_from(payload)
    expect = _CHUNK_HEADER.size + n * ROW_BYTES
    if len(payload) != expect:
        raise WireError(f"chunk payload {len(payload)}B != expected "
                        f"{expect}B for {n} rows")
    off = _CHUNK_HEADER.size
    cols = []
    for dt in COL_DTYPES:
        nbytes = n * np.dtype(dt).itemsize
        cols.append(np.frombuffer(payload, dt, count=n, offset=off).copy())
        off += nbytes
    return ChunkFrame(host, shard, epoch, seq, *cols)
