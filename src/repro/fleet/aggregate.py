"""Multi-host aggregation — merge per-host event streams into one session.

:class:`FleetSource` implements the session's
:class:`~repro.core.session.EventSource` protocol over N per-host streams
(:class:`HostStream`), so one :class:`~repro.core.session.ProfileSession`
background worker drains and folds a whole fleet: ``snapshot()`` /
``result()`` produce a single :class:`~repro.core.detector.BottleneckReport`
whose workers — and therefore critical slices — carry host provenance
(``report.worker_hosts``).

Normalization happens at the stream edge, once per pushed chunk:

* **worker ids** become fleet-global (``host_offset + local_id``), so the
  fold's per-worker maps, the detector and the exporters see one dense id
  space;
* **timestamps** get the host's clock offset added (declared in the
  handshake or measured by the server — see
  :class:`~repro.fleet.transport.IngestServer`);
* **tag / stack ids** are remapped through the host's registry maps into
  the fleet-wide :class:`~repro.core.tracer.TagRegistry` /
  :class:`~repro.core.tracer.StackRegistry` (identity for raw spill files,
  which carry no registries).

The merge reuses the sharded tracer's tie-break semantics: one stable
``np.lexsort((workers, deltas, times))`` per emitted batch — equal
timestamps order DEACTIVATE first, then by (global) worker id.  Emission is
watermark-gated for boundedness *and* losslessness: a row is emitted only
when its timestamp is strictly below every unfinished host's low watermark
(the last timestamp that host has streamed; per-host streams are
time-ordered), so no later arrival can ever sort before an emitted row.
Consequence (tested): ``FleetSource.from_files([...])`` replayed through a
session is **bit-equal on the numpy backend** to ``detect_offline`` over
the concatenated-and-sorted remapped logs — the wire path is provably
lossless.
"""
from __future__ import annotations

import glob
import json
import os
import threading
from collections import deque
from typing import Iterator

import numpy as np

from repro.core.events import EventLog
from repro.core.session import EventSource
from repro.core.spill import SpillStore
from repro.core.tracer import StackRegistry, TagRegistry

_COLS = 5   # times, workers, deltas, tags, stacks


def write_json_atomic(path: str, obj: dict) -> None:
    """Meta sidecars are rewritten in place; neither a crash mid-write
    nor a power loss right after the rename may leave a torn or empty
    JSON (the resume paths trust it), hence the fsync before the replace.
    The tmp name carries the thread id so racing writers (overlapping
    connections of one host) cannot interleave into one tmp file."""
    tmp = f"{path}.{threading.get_ident()}.tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_json(path: str) -> dict | None:
    """Tolerant meta read: a missing, torn or non-object file is simply
    'no meta' — both the server resume and from_fleet_dir must classify
    such files identically or live and offline replay diverge.
    ValueError covers both JSONDecodeError and the UnicodeDecodeError
    a binary-corrupted file raises."""
    try:
        with open(path) as f:
            obj = json.load(f)
        return obj if isinstance(obj, dict) else None
    except (OSError, ValueError):
        return None


def _grow_idmap(arr: np.ndarray | None, idx: int) -> np.ndarray:
    """Ensure ``arr[idx]`` exists (new cells are identity-mapped)."""
    if arr is None:
        arr = np.arange(0, dtype=np.int32)
    if idx >= arr.shape[0]:
        new = np.arange(max(idx + 1, 2 * arr.shape[0] + 1), dtype=np.int32)
        new[:arr.shape[0]] = arr
        arr = new
    return arr


def restore_host_maps(host: "HostStream", tags: TagRegistry,
                      stacks: StackRegistry, tag_entries,
                      stack_entries) -> None:
    """Rebuild a host's registry maps from persisted meta entries (lists
    indexed by host-local id; ``None`` holes are skipped) by interning
    into the fleet registries — the one algorithm behind both the
    server's restart resume and :meth:`FleetSource.from_fleet_dir`."""
    for i, ent in enumerate(tag_entries or []):
        if ent is None:
            continue
        host.tag_map = _grow_idmap(host.tag_map, i)
        host.tag_map[i] = tags.intern(str(ent[0]), str(ent[1]))
    for i, path in enumerate(stack_entries or []):
        if path is None:
            continue
        fleet_path = []
        for t in path:
            host.tag_map = _grow_idmap(host.tag_map, int(t))
            fleet_path.append(int(host.tag_map[int(t)]))
        host.stack_map = _grow_idmap(host.stack_map, i)
        host.stack_map[i] = stacks.intern(tuple(fleet_path))


def _remap_ids(col: np.ndarray, idmap: np.ndarray | None) -> np.ndarray:
    """Map non-negative ids through ``idmap`` (sentinel ids < 0 pass
    through; ids beyond the map keep their value — the caller grows maps
    before referencing new ids)."""
    if idmap is None or idmap.size == 0:
        return col
    out = col.copy()
    valid = (col >= 0) & (col < idmap.shape[0])
    out[valid] = idmap[col[valid]]
    return out


class HostStream:
    """One host's normalized, time-ordered column stream.

    ``push`` applies the worker offset, clock offset and registry remaps,
    then buffers the chunk; the owning :class:`FleetSource` pops merged
    prefixes.  ``feed`` (optional) is a pull-iterator of raw column tuples
    used by the offline file path; live transports push instead.
    """

    def __init__(self, index: int, host_id: str, num_workers: int,
                 worker_offset: int, worker_names: list[str] | None = None,
                 clock_offset_ns: int = 0,
                 feed: Iterator[tuple] | None = None):
        self.index = index
        self.host_id = host_id
        self.num_workers = int(num_workers)
        self.worker_offset = int(worker_offset)
        self.worker_names = list(worker_names) if worker_names else [
            f"w{i}" for i in range(num_workers)]
        self.clock_offset_ns = int(clock_offset_ns)
        self.feed = feed
        # host-local id -> fleet id; None == identity (raw spill files)
        self.tag_map: np.ndarray | None = None
        self.stack_map: np.ndarray | None = None
        self.finished = False
        self.rows_in = 0
        self.chunks_in = 0
        self._parts: deque[tuple] = deque()     # guarded-by: FleetSource.cond
        self._buffered = 0                      # guarded-by: FleetSource.cond
        # low watermark: every future row of this host has time >= this
        # (per-host streams are time-ordered — the tracer store order)
        self.last_seen_ns: int | None = None
        # a host that went silent (no CHUNK before the server's
        # idle_release deadline) is exempted from the merge watermark so
        # it cannot pin every other host's emission; data arriving later
        # re-arms it (and may be clamped+counted, like a late HELLO)
        self.idle_exempt = False

    # -- intake --------------------------------------------------------------
    def push(self, times, workers, deltas, tags, stacks) -> int:  # guarded-by: FleetSource.cond
        """Normalize one raw chunk into the fleet domain and buffer it.
        Returns the number of rows buffered."""
        n = len(times)
        if n == 0:
            return 0
        t = np.asarray(times, np.int64)
        if self.clock_offset_ns:
            t = t + self.clock_offset_ns
        w = np.asarray(workers, np.int32) + self.worker_offset
        g = _remap_ids(np.asarray(tags, np.int32), self.tag_map)
        s = _remap_ids(np.asarray(stacks, np.int32), self.stack_map)
        self._parts.append((t, w, np.asarray(deltas, np.int8), g, s))
        self._buffered += n
        self.rows_in += n
        self.chunks_in += 1
        self.last_seen_ns = int(t[-1])
        self.idle_exempt = False        # data re-arms the watermark
        return n

    def advance_watermark(self, t_ns: int) -> None:  # guarded-by: FleetSource.cond
        """Raise the low watermark WITHOUT data (HEARTBEAT): the producer
        asserts every row it will ever stream after this has capture time
        >= ``t_ns`` (its store order guarantees it — t_ns is the last
        already-streamed row's time).  Normalized like :meth:`push`;
        never moves backwards."""
        t = int(t_ns) + self.clock_offset_ns
        if self.last_seen_ns is None or t > self.last_seen_ns:
            self.last_seen_ns = t

    def shed_oldest(self, max_rows: int) -> tuple[int, int]:  # guarded-by: FleetSource.cond
        """Load shedding: front-evict whole buffered chunks, oldest
        first, until at most ``max_rows`` rows remain buffered.  Returns
        ``(chunks, rows)`` evicted.  The stream stays time-ordered and
        the watermark is untouched, so the merge keeps advancing; only
        callers whose chunks are journaled should shed — the evicted
        prefix then degrades to "replay offline later", never loss."""
        chunks = rows = 0
        while self._parts and self._buffered > max_rows:
            part = self._parts.popleft()
            n = len(part[0])
            self._buffered -= n
            chunks += 1
            rows += n
        return chunks, rows

    def finish(self) -> None:  # guarded-by: FleetSource.cond
        self.finished = True

    def pull(self) -> bool:  # guarded-by: FleetSource.cond
        """File path: pull one raw chunk from ``feed`` into the buffer.
        Returns False (and marks the stream finished) at EOF."""
        if self.feed is None:
            return False
        try:
            cols = next(self.feed)
        except StopIteration:
            self.finished = True
            self.feed = None
            return False
        self.push(*cols)
        return True

    # -- merge side ----------------------------------------------------------
    @property
    def buffered_rows(self) -> int:
        return self._buffered

    def take_below(self, t_ns: int | None) -> list[tuple]:  # guarded-by: FleetSource.cond
        """Pop buffered rows with time strictly below ``t_ns`` (all rows
        when ``t_ns`` is None), preserving stream order."""
        out = []
        while self._parts:
            part = self._parts[0]
            if t_ns is None or part[0][-1] < t_ns:
                out.append(self._parts.popleft())
                self._buffered -= len(part[0])
                continue
            k = int(np.searchsorted(part[0], t_ns, side="left"))
            if k > 0:
                out.append(tuple(c[:k] for c in part))
                self._parts[0] = tuple(c[k:] for c in part)
                self._buffered -= k
            break
        return out


class FleetSource(EventSource):
    """K-way merge of per-host streams, as a pluggable session source.

    Offline — replay spill files copied from the hosts::

        src = FleetSource.from_files(["a.spill", "b.spill", "c.spill"])
        rep = ProfileSession(src, n_min=2.0).result()

    Live — wrap an :class:`~repro.fleet.transport.IngestServer`'s hub (the
    server constructs and feeds one)::

        server = IngestServer()
        server.start()
        with ProfileSession(server.source, n_min=2.0) as sess:
            ...                      # producers stream in
            server.wait_idle()       # all producers said BYE
        rep = sess.result()

    ``chunks()`` yields fleet-domain :class:`EventLog` batches of at most
    ``chunk_events`` rows; the merge is watermark-gated (see module
    docstring) so it is lossless and memory stays bounded by the buffered
    tail of each host.  ``times`` are clamped monotonic across emissions
    (``clock_clamped`` counts repairs).  The watermark only covers hosts
    the merge *knows about*: a host whose HELLO lands after every earlier
    host already finished (all-BYE flush), or after ``request_stop``, can
    deliver events older than the emission frontier — those are clamped
    and counted, not lost.  Register all producers before streaming (the
    acceptance tests do) for a clamp-free, oracle-exact merge.
    """

    live = False

    def __init__(self, *, tags: TagRegistry | None = None,
                 stacks: StackRegistry | None = None,
                 chunk_events: int = 1 << 16):
        self.tags = tags if tags is not None else TagRegistry()
        self.stacks = stacks if stacks is not None else StackRegistry()
        self.chunk_events = max(int(chunk_events), 1)
        self.hosts: list[HostStream] = []       # guarded-by: self.cond
        self.cond = threading.Condition()
        self.clock_clamped = 0
        # exact load-shedding ledger (incremented by the transport under
        # self.cond): shed chunks were journaled first, so they are
        # recoverable offline — the live report is approximate by exactly
        # this much
        self.shed_chunks = 0                    # guarded-by: self.cond
        self.shed_rows = 0                      # guarded-by: self.cond
        self._t_emitted: int | None = None
        self._stop = False                      # guarded-by: self.cond
        # a live transport (IngestServer) sets this while it can still
        # accept producers: the chunk stream then stays open even when
        # every current host finished (file mode leaves it False, so the
        # stream ends when the last file is drained)
        self.accepting = False
        # from_files/from_fleet_dir/from_producer_journals record their
        # inputs here so full_log() can re-open the files instead of
        # consuming the live feeds
        self._file_recipe: dict | None = None
        self._dir_recipe: dict | None = None
        self._producer_recipe: dict | None = None

    # -- host management -----------------------------------------------------
    def add_host(self, host_id: str, num_workers: int,
                 worker_names: list[str] | None = None,
                 clock_offset_ns: int = 0,
                 feed: Iterator[tuple] | None = None) -> HostStream:
        with self.cond:
            h = HostStream(len(self.hosts), host_id, num_workers,
                           self.num_workers, worker_names, clock_offset_ns,
                           feed)
            self.hosts.append(h)
            self.cond.notify_all()
        return h

    def try_grow_host(self, stream: HostStream, num_workers: int,
                      worker_names: list[str] | None = None) -> bool:
        """Grow a host's worker-id space (workers registered after its
        first handshake).  Only legal while the host owns the *tail* of
        the fleet id range — growing an interior host would collide with
        the next host's offsets.  Returns False when rejected."""
        with self.cond:
            if num_workers <= stream.num_workers:
                return True
            if (stream.worker_offset + stream.num_workers
                    != self.num_workers):
                return False
            old = stream.num_workers
            stream.num_workers = int(num_workers)
            if worker_names and len(worker_names) >= num_workers:
                stream.worker_names = list(worker_names[:num_workers])
            else:
                stream.worker_names += [
                    f"w{i}" for i in range(old, num_workers)]
            self.cond.notify_all()
        return True

    @property
    def num_workers(self) -> int:
        return sum(h.num_workers for h in self.hosts)

    @property
    def num_hosts(self) -> int:
        return len(self.hosts)

    def worker_names(self) -> list[str]:
        return [f"{h.host_id}/{n}" for h in self.hosts
                for n in h.worker_names]

    def worker_hosts(self) -> list[str]:
        return [h.host_id for h in self.hosts for _ in range(h.num_workers)]

    def stats(self) -> dict:
        return {
            "hosts": len(self.hosts),
            "rows_in": sum(h.rows_in for h in self.hosts),
            "chunks_in": sum(h.chunks_in for h in self.hosts),
            "buffered_rows": sum(h.buffered_rows for h in self.hosts),
            "clock_clamped": self.clock_clamped,
            "shed_chunks": self.shed_chunks,
            "shed_rows": self.shed_rows,
            "idle_hosts": sum(1 for h in self.hosts if h.idle_exempt),
            "accepting": self.accepting,
        }

    # -- lifecycle hooks the session drives ----------------------------------
    def request_stop(self) -> None:
        """Finalize: flush everything buffered and end the chunk stream
        (the session calls this from ``stop()``/``close()``)."""
        with self.cond:
            self._stop = True
            self.cond.notify_all()

    def notify(self) -> None:
        with self.cond:
            self.cond.notify_all()

    # -- construction helpers ------------------------------------------------
    @classmethod
    def from_files(cls, paths: list[str], *,
                   host_names: list[str] | None = None,
                   num_workers: list[int] | None = None,
                   tags: TagRegistry | None = None,
                   stacks: StackRegistry | None = None,
                   clock_offsets_ns: list[int] | None = None,
                   chunk_events: int = 1 << 16) -> "FleetSource":
        """Offline ingest: one spill file per host (copied off the hosts),
        k-way merged exactly like the live path.  ``num_workers`` per host
        is pre-scanned from the file when not given (one extra pass)."""
        src = cls(tags=tags, stacks=stacks, chunk_events=chunk_events)
        resolved_nw = []
        for i, path in enumerate(paths):
            store = SpillStore.open_readonly(path, chunk_events)
            nw = (num_workers[i] if num_workers is not None
                  else _scan_num_workers(store))
            resolved_nw.append(nw)
            name = (host_names[i] if host_names is not None
                    else _default_host_name(path, i))
            off = (clock_offsets_ns[i] if clock_offsets_ns is not None
                   else 0)
            src.add_host(name, nw, clock_offset_ns=off,
                         feed=_file_feed(store, nw))
        src._file_recipe = {
            "paths": list(paths),
            "host_names": [h.host_id for h in src.hosts],
            "num_workers": resolved_nw,
            "clock_offsets_ns": [h.clock_offset_ns for h in src.hosts],
            "chunk_events": chunk_events,
        }
        return src

    @classmethod
    def from_fleet_dir(cls, fleet_dir: str, *,
                       tags: TagRegistry | None = None,
                       stacks: StackRegistry | None = None,
                       chunk_events: int = 1 << 16,
                       window_ns: tuple[int, int] | None = None) \
            -> "FleetSource":
        """Re-open an :class:`~repro.fleet.transport.IngestServer`'s
        durable per-host stores (``IngestServer(fleet_dir=...)``): one
        journal + meta sidecar per host.  The meta carries everything the
        raw spill blocks don't — host identity and order, worker table,
        clock offset, and the host-local tag/stack registry entries — so
        the replayed merge resolves names and normalizes exactly like the
        live ingest did: the merged log is the union of everything the
        server accepted.

        ``window_ns=(lo, hi)`` (inclusive, fleet time) restricts the
        replay to that capture-time window: each journal's block index
        seeks directly to the intersecting blocks — a windowed query over
        a long-running fleet_dir never re-reads the full history."""
        metas = []
        for mp in sorted(glob.glob(os.path.join(str(fleet_dir),
                                                "*.meta.json"))):
            m = load_json(mp)
            if m and m.get("journal"):
                m["_journal_path"] = os.path.join(os.path.dirname(mp),
                                                  m["journal"])
                metas.append(m)
        metas.sort(key=lambda m: int(m.get("host_index", 0)))
        src = cls(tags=tags, stacks=stacks, chunk_events=chunk_events)
        for m in metas:
            if not journal_on_disk(m["_journal_path"]):
                # a silent skip would drop the host's every row and void
                # the merged-journals == live-report equality unnoticed
                raise FileNotFoundError(
                    f"fleet_dir meta for host {m.get('host_id')!r} "
                    f"references missing journal {m['_journal_path']!r}")
            store = SpillStore.open_readonly(m["_journal_path"],
                                             chunk_events)
            nw = int(m.get("num_workers", 0))
            off = int(m.get("clock_offset_ns", 0))
            h = src.add_host(str(m.get("host_id", "host")), nw,
                             m.get("worker_names"),
                             clock_offset_ns=off,
                             feed=_file_feed(store, nw, window_ns, off))
            restore_host_maps(h, src.tags, src.stacks, m.get("tags"),
                              m.get("stacks"))
        src._dir_recipe = {"fleet_dir": str(fleet_dir),
                           "chunk_events": chunk_events,
                           "window_ns": window_ns}
        return src

    @classmethod
    def from_producer_journals(cls, paths: list[str], *,
                               tags: TagRegistry | None = None,
                               stacks: StackRegistry | None = None,
                               clock_offsets_ns: list[int] | None = None,
                               chunk_events: int = 1 << 16) -> "FleetSource":
        """Offline ingest over PRODUCER-side durable journals
        (``RemoteSink(journal=...)``) — the union of everything each
        producer ever captured, independent of what any server received.
        Each path's ``.meta.json`` sidecar supplies the host identity,
        worker table and registry entries (the same resume state a sink
        restart reads).  Hosts are ordered as given: pass the paths in
        the server's ``host_index`` order to reproduce the live fleet's
        worker-id layout, making this the ground-truth oracle the chaos
        harness compares recovered merges against."""
        src = cls(tags=tags, stacks=stacks, chunk_events=chunk_events)
        for i, path in enumerate(paths):
            meta = load_json(str(path) + ".meta.json") or {}
            store = SpillStore.open_readonly(path, chunk_events)
            nw = int(meta.get("num_workers") or 0) \
                or _scan_num_workers(store)
            off = (clock_offsets_ns[i] if clock_offsets_ns is not None
                   else int(meta.get("clock_offset_ns") or 0))
            h = src.add_host(
                str(meta.get("host_id") or _default_host_name(path, i)),
                nw, meta.get("worker_names"), clock_offset_ns=off,
                feed=_file_feed(store, nw))
            restore_host_maps(h, src.tags, src.stacks, meta.get("tags"),
                              meta.get("stacks"))
        src._producer_recipe = {
            "paths": [str(p) for p in paths],
            "clock_offsets_ns": (None if clock_offsets_ns is None
                                 else list(clock_offsets_ns)),
            "chunk_events": chunk_events,
        }
        return src

    def full_log(self) -> EventLog:
        """Materialize the merged fleet log.  File-backed sources re-open
        their files (repeatable, like LogSource/SpillSource — the session's
        feeds are untouched); a live ingest stream has no rewind."""
        if self._file_recipe is not None:
            fresh = FleetSource.from_files(**self._file_recipe)
        elif self._dir_recipe is not None:
            # share the registries: intern is name-keyed, so the re-read
            # produces identical fleet tag/stack ids
            fresh = FleetSource.from_fleet_dir(
                **self._dir_recipe, tags=self.tags, stacks=self.stacks)
        elif self._producer_recipe is not None:
            fresh = FleetSource.from_producer_journals(
                **self._producer_recipe, tags=self.tags, stacks=self.stacks)
        else:
            raise RuntimeError("full_log(): live ingest streams have no "
                               "rewind (only FleetSource.from_files / "
                               "from_fleet_dir sources can re-materialize)")
        parts = list(fresh.chunks())
        if not parts:
            from repro.fleet.wire import COL_DTYPES
            return EventLog(*[np.zeros(0, dt) for dt in COL_DTYPES],
                            num_workers=self.num_workers)
        cols = zip(*[(p.times, p.workers, p.deltas, p.tags, p.stacks)
                     for p in parts])
        return EventLog(*[np.concatenate(list(c)) for c in cols],
                        num_workers=self.num_workers)

    # -- the merge -----------------------------------------------------------
    def chunks(self) -> Iterator[EventLog]:
        while True:
            with self.cond:
                batch, done = self._gather_locked()
            if batch is not None:
                yield from self._emit(batch)
            if done:
                return
            if batch is None:
                with self.cond:
                    if not self._stop and not self._progress_possible():
                        self.cond.wait(0.05)

    def _progress_possible(self) -> bool:  # guarded-by: self.cond
        """Under the lock: can the next gather round move without waiting
        for a live push?  (Any unfinished file host can always pull.)"""
        return any(h.feed is not None and not h.finished
                   for h in self.hosts)

    def _gather_locked(self) -> tuple[list[tuple] | None, bool]:  # guarded-by: self.cond
        """One merge round under the lock.  Returns ``(parts, done)``:
        ``parts`` is the host-ordered list of safe column tuples (None when
        nothing could be emitted), ``done`` means the stream is over."""
        while True:
            # file-backed hosts refill so every unfinished host constrains
            # the watermark with real data
            for h in self.hosts:
                while (h.feed is not None and not h.finished
                       and h.buffered_rows == 0):
                    if not h.pull():
                        break
            unfinished = [h for h in self.hosts if not h.finished]
            all_done = bool(self.hosts) and not unfinished
            if self._stop or (all_done and not self.accepting):
                # finalize: file feeds are finite — read them to the end
                # (losslessness); live hosts contribute what they buffered
                for h in self.hosts:
                    while h.feed is not None and not h.finished:
                        h.pull()
                parts = [p for h in self.hosts for p in h.take_below(None)]
                return (parts or None), True
            if all_done:
                # every current host said BYE but the transport may still
                # accept more: emit everything, keep the stream open
                parts = [p for h in self.hosts for p in h.take_below(None)]
                return (parts or None), False
            # idle-exempt hosts (silent past the server's idle_release
            # deadline) do not gate the watermark: a producer that
            # handshook and then died must not pin every healthy host's
            # emission.  If they wake up late, their rows clamp like any
            # late-HELLO host's.
            gating = [h for h in unfinished if not h.idle_exempt]
            if not gating:
                # every live host is idle: flush what is buffered (idle
                # hosts buffer nothing new by definition), keep streaming
                parts = [p for h in self.hosts for p in h.take_below(None)]
                return (parts or None), False
            if not self.hosts or any(h.last_seen_ns is None
                                     for h in gating):
                return None, False  # a host has not produced yet: no floor
            watermark = min(h.last_seen_ns for h in gating)
            parts = [p for h in self.hosts for p in h.take_below(watermark)]
            if parts:
                return parts, False
            # all buffered rows sit at/over the watermark: advance the file
            # host(s) pinning it (a live host advances by pushing)
            advanced = False
            for h in unfinished:
                if h.feed is not None and h.last_seen_ns <= watermark:
                    advanced |= h.pull()
            if not advanced and not any(h.finished for h in unfinished):
                return None, False

    def _emit(self, parts: list[tuple]) -> Iterator[EventLog]:
        """Merge-sort gathered parts and yield chunk_events-bounded logs."""
        cols = [np.concatenate([p[i] for p in parts]) for i in range(_COLS)]
        times, workers, deltas = cols[0], cols[1], cols[2]
        if len(parts) > 1 or np.any(np.diff(times) < 0):
            # shard-merge tie-break semantics: DEACTIVATE first, then
            # worker id; stable, so within-host stream order is preserved
            order = np.lexsort((workers, deltas, times))
            cols = [c[order] for c in cols]
            times = cols[0]
        if self._t_emitted is not None and times[0] < self._t_emitted:
            clamped = times < self._t_emitted
            self.clock_clamped += int(clamped.sum())
            cols[0] = times = np.maximum(times, self._t_emitted)
        self._t_emitted = int(times[-1])
        nw = self.num_workers
        ce = self.chunk_events
        for lo in range(0, len(times), ce):
            yield EventLog(*[c[lo:lo + ce] for c in cols], num_workers=nw)


# ---------------------------------------------------------------------------
# file-feed helpers
# ---------------------------------------------------------------------------

def _file_feed(store: SpillStore, num_workers: int,
               window_ns: tuple[int, int] | None = None,
               clock_offset_ns: int = 0) -> Iterator[tuple]:
    """Replay a spill file as host-local column tuples.  ``window_ns``
    (inclusive, FLEET time — i.e. post clock-offset) restricts the replay
    to events in ``[lo, hi]``: the store's capture-time block index seeks
    straight to the intersecting blocks (nothing outside the window is
    decoded) and boundary blocks are row-trimmed here, in host-local time
    (``HostStream.push`` re-applies the offset on the way in)."""
    if window_ns is None:
        for log in store.iter_chunks(num_workers):
            yield (log.times, log.workers, log.deltas, log.tags, log.stacks)
        return
    lo = int(window_ns[0]) - int(clock_offset_ns)
    hi = int(window_ns[1]) - int(clock_offset_ns)
    for cols in store.iter_block_columns_window(lo, hi):
        t = cols[0]
        a = int(np.searchsorted(t, lo, "left"))
        b = int(np.searchsorted(t, hi, "right"))
        if a < b:
            yield tuple(c[a:b] for c in cols)


def journal_on_disk(path: str) -> bool:
    """True when a journal left anything on disk: its base (active) file
    or any sealed rotation segment — full rotation can retire the base
    file entirely, leaving only ``<path>.g*.seg`` history."""
    return bool(os.path.exists(str(path))
                or glob.glob(glob.escape(str(path)) + ".g*.seg"))


def fleet_dir_time_span(fleet_dir: str) -> tuple[int, int] | None:
    """Capture-time span ``(t_min, t_max)`` of a fleet_dir in FLEET time
    (each host's journal bounds shifted by its recorded clock offset), or
    ``None`` when no journal holds events.  O(blocks) header seeks per
    journal — the anchor a serving layer needs to resolve "last N seconds"
    into an absolute window without reading any payload."""
    lo = hi = None
    for mp in sorted(glob.glob(os.path.join(str(fleet_dir),
                                            "*.meta.json"))):
        m = load_json(mp)
        if not m or not m.get("journal"):
            continue
        jp = os.path.join(os.path.dirname(mp), m["journal"])
        if not journal_on_disk(jp):
            continue
        b = SpillStore.open_readonly(jp).time_bounds()
        if b is None:
            continue
        off = int(m.get("clock_offset_ns", 0))
        lo = b[0] + off if lo is None else min(lo, b[0] + off)
        hi = b[1] + off if hi is None else max(hi, b[1] + off)
    return None if lo is None else (lo, hi)


def _scan_num_workers(store: SpillStore) -> int:
    """Worker count of a raw spill file (no header carries it): one pass
    over the blocks' worker column."""
    top = -1
    for cols in store._read_blocks(store._read_limit()):
        if cols[1].size:
            top = max(top, int(cols[1].max()))
    return top + 1


def _default_host_name(path: str, index: int) -> str:
    base = os.path.basename(str(path))
    stem = base.rsplit(".", 1)[0] if "." in base else base
    return stem or f"host{index}"
