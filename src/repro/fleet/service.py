"""Continuous-profiling service: the fleet's live HTTP query surface.

:class:`ProfilerService` turns a running :class:`ProfileSession` (most
usefully one reading an :class:`~repro.fleet.transport.IngestServer`'s
FleetSource) into an always-on observability endpoint — the "point a
browser at a running fleet" product shape over everything the durable
``fleet_dir`` already records:

* ``GET /``            — no-dependency HTML dashboard (inline JS);
* ``GET /api/report``  — the live snapshot as schema-versioned JSON,
  byte-identical to ``session.export("json")``;
* ``GET /api/top?n=&window=`` — ranked bottlenecks with deltas vs the
  previous poll; ``window=<seconds>`` answers from an incremental
  re-fold of only the journal blocks whose capture-time bounds intersect
  the window (the SpillStore block index — never a full history read);
* ``GET /api/whatif?tag=&shrink=`` — causal what-if: a counterfactual
  re-fold with the selected target's critical slices shrunk/removed
  (``host=`` / ``worker=`` / ``path=<rank>`` select too), byte-identical
  to ``report.what_if(...).to_json()`` on the same capture;
* ``GET /api/hosts`` / ``GET /api/hosts/<id>`` — per-host lanes from
  ``BottleneckReport.per_host()`` plus stream/journal/ingest health;
* ``GET /api/stream`` — chunked JSON-lines push of the same payload the
  ``watch`` exporter delivers (one builder: :mod:`repro.obs.payload`);
* ``GET /metrics``     — Prometheus text exposition of the profiler's
  self-telemetry (fold rate, snapshot latency, queue depths, shed/lost/
  duplicate chunks, journal bytes).

Like the ingest side, the server is ONE selector thread — the handler
must never block on disk or the session's locks longer than a snapshot
takes, and the loop-blocking lint walks every handler from the
``# lint: event-loop`` root to keep it that way.  Retention is the one
deliberately-blocking job (segment unlinks are disk metadata I/O), so it
runs on its own sweeper thread, driven by :class:`RetentionPolicy`
against the same ``retain_blocks``/ack-floor pruning primitives the
journals already expose.

Wiring::

    server = IngestServer(fleet_dir="fleet/")          # producers connect
    sess = ProfileSession(server.source, n_min=2.0)
    sess.start()
    svc = sess.serve(("0.0.0.0", 9100), server=server,
                     retention=RetentionPolicy(max_age_s=3600))
    ...
    svc.close()

Offline, over a finished fleet_dir::

    svc = ProfilerService.from_fleet_dir("fleet/", ("127.0.0.1", 9100))
"""
from __future__ import annotations

import dataclasses
import glob as glob_lib
import json
import os
import selectors
import socket
import threading
import time

from repro.core.report import path_entries
from repro.core.session import ProfileSession
from repro.core.spill import SpillStore
from repro.fleet.aggregate import (FleetSource, fleet_dir_time_span,
                                   journal_on_disk, load_json)
from repro.obs import http
from repro.obs import payload as payload_lib
from repro.obs import prom
from repro.obs.dashboard import DASHBOARD_HTML

#: /api/top responses and /api/stream frames share the payload schema
#: version from :mod:`repro.obs.payload`.
TOP_SCHEMA_VERSION = 1


@dataclasses.dataclass
class RetentionPolicy:
    """Wall-clock age budget driving journal pruning.

    Every ``sweep_interval_s`` the service walks the fleet journals and
    calls :meth:`SpillStore.prune_before_time` with ``newest event time −
    max_age_s`` — whole sealed segments older than the budget are
    deleted; the active file and any block inside the budget survive.

    ``respect_ack=False`` (the default here, unlike the SpillStore
    primitive) because the server-side ``fleet_dir`` journals have no
    acking consumer — the server IS the consumer; flip it on when
    pointing retention at producer journals, where the ack floor marks
    what the aggregator has durably received and an unacked block must
    outlive any age budget.

    ``keep_window_s`` additionally pins every block needed by windowed
    queries up to that span; the service also tracks the largest
    ``window=`` it has actually served and holds retention back by it,
    so an ``/api/top?window=600`` can never have its blocks pruned out
    from under a 300s age budget.
    """
    max_age_s: float
    sweep_interval_s: float = 5.0
    respect_ack: bool = False
    keep_window_s: float | None = None


class _HttpConn:
    """One HTTP connection's event-loop state (loop-thread-owned)."""

    __slots__ = ("sock", "rbuf", "wbuf", "mask", "closed", "last_rx",
                 "responded", "stream_every", "stream_top_n",
                 "stream_next")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.rbuf = bytearray()
        self.wbuf = bytearray()
        self.mask = selectors.EVENT_READ
        self.closed = False
        self.last_rx = time.monotonic()
        self.responded = False          # a complete response is queued
        self.stream_every: float | None = None  # /api/stream cadence
        self.stream_top_n: int | None = None
        self.stream_next = 0.0

    def fileno(self) -> int:
        return self.sock.fileno()


class ProfilerService:
    """Single-thread selector HTTP server over a :class:`ProfileSession`.

    ``server=`` (an :class:`IngestServer`) unlocks ingest health in
    ``/api/hosts``//``/metrics`` and live journal access; ``fleet_dir=``
    (defaulted from the server's) unlocks time-windowed ``/api/top``
    queries and retention.  Constructing binds the socket (``address``
    is final immediately); :meth:`start` spins the loop.
    """

    #: Idle half-open connections (no complete request) are reaped after
    #: this many seconds.
    CONN_IDLE_S = 30.0

    def __init__(self, session: ProfileSession,
                 addr: tuple[str, int] = ("127.0.0.1", 0), *,
                 server=None, fleet_dir: str | None = None,
                 retention: "RetentionPolicy | float | None" = None,
                 top_n: int | None = None, backlog: int = 16):
        self.session = session
        self.server = server
        if fleet_dir is None and server is not None:
            fleet_dir = server.fleet_dir
        self.fleet_dir = str(fleet_dir) if fleet_dir else None
        if isinstance(retention, (int, float)):
            retention = RetentionPolicy(max_age_s=float(retention))
        self.retention = retention
        self.top_n = int(top_n) if top_n is not None else session.top_n
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(tuple(addr))
        self._sock.listen(backlog)
        self._sock.setblocking(False)
        self.address: tuple[str, int] = self._sock.getsockname()[:2]
        self._loop_thread: threading.Thread | None = None
        self._ret_thread: threading.Thread | None = None
        self._sel: selectors.BaseSelector | None = None
        self._wake_r: socket.socket | None = None
        self._wake_w: socket.socket | None = None
        self._shutdown = threading.Event()
        self._conns: set[_HttpConn] = set()     # loop-thread-owned
        # previous /api/top answer per query key and the /metrics fold-
        # rate anchor: only the loop thread touches these
        self._prev_top: dict = {}               # loop-thread-owned
        self._rate_prev = (time.monotonic(), 0)  # loop-thread-owned
        # leaf lock for everything shared with stats()/close()/retention;
        # never held across a session or store call
        self._lock = threading.Lock()
        self._conn_socks: set = set()       # guarded-by: self._lock
        self._requests: dict = {}           # guarded-by: self._lock -- per-route counts
        self._connections = 0               # guarded-by: self._lock
        self._open_conns = 0                # guarded-by: self._lock
        self._http_errors = 0               # guarded-by: self._lock
        self._stream_clients = 0            # guarded-by: self._lock
        self._snap_count = 0                # guarded-by: self._lock
        self._snap_seconds_sum = 0.0        # guarded-by: self._lock
        self._snap_seconds_last = 0.0       # guarded-by: self._lock
        self._window_folds = 0              # guarded-by: self._lock
        self._window_fold_seconds_sum = 0.0  # guarded-by: self._lock
        self._whatif_folds = 0              # guarded-by: self._lock
        self._whatif_fold_seconds_sum = 0.0  # guarded-by: self._lock
        self._max_window_s = 0.0            # guarded-by: self._lock
        self._retention_pruned = 0          # guarded-by: self._lock
        self._retention_errors = 0          # guarded-by: self._lock

    # -- construction helpers ------------------------------------------------
    @classmethod
    def from_fleet_dir(cls, fleet_dir: str,
                       addr: tuple[str, int] = ("127.0.0.1", 0), *,
                       n_min: float | None = None,
                       fold_backend: str = "numpy",
                       **kw) -> "ProfilerService":
        """Post-hoc browsing: fold a finished ``fleet_dir`` once (inline,
        before binding handlers) and serve the sealed report — every
        endpoint works, including windowed ``/api/top`` re-folds over the
        journal history."""
        src = FleetSource.from_fleet_dir(fleet_dir)
        sess = ProfileSession(src, n_min=n_min, fold_backend=fold_backend)
        sess.result()
        return cls(sess, addr, fleet_dir=fleet_dir, **kw)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ProfilerService":
        if self._loop_thread is None:
            self._sel = selectors.DefaultSelector()
            self._wake_r, self._wake_w = socket.socketpair()
            self._wake_r.setblocking(False)
            self._wake_w.setblocking(False)
            self._sel.register(self._sock, selectors.EVENT_READ, "accept")
            self._sel.register(self._wake_r, selectors.EVENT_READ, "wake")
            self._loop_thread = threading.Thread(
                target=self._loop, daemon=True, name="gapp-service")
            self._loop_thread.start()
            if self.retention is not None and self._ret_thread is None:
                self._ret_thread = threading.Thread(
                    target=self._retention_loop, daemon=True,
                    name="gapp-retention")
                self._ret_thread.start()
        return self

    def __enter__(self) -> "ProfilerService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _wake(self) -> None:
        w = self._wake_w
        if w is not None:
            try:
                w.send(b"x")
            except OSError:
                pass

    def close(self) -> None:
        """Stop serving: join the loop + retention threads, close every
        socket.  The session is NOT touched — it outlives its service."""
        self._shutdown.set()
        self._wake()
        for t in (self._loop_thread, self._ret_thread):
            if t is not None:
                t.join(timeout=5.0)
        self._loop_thread = self._ret_thread = None
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            socks = list(self._conn_socks)
        for s in socks:
            try:
                s.close()
            except OSError:
                pass
        if self._sel is not None:
            try:
                self._sel.close()
            except OSError:
                pass
            self._sel = None
        for w in (self._wake_r, self._wake_w):
            if w is not None:
                try:
                    w.close()
                except OSError:
                    pass
        self._wake_r = self._wake_w = None

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        """Service self-telemetry.  Keys are pinned by
        ``tests/test_stats_schema.py`` (the ``/metrics`` names derive
        from them):

        * ``address`` — bound ``[host, port]``;
        * ``requests`` — per-route request counts (route label ->
          count);
        * ``connections`` / ``open_connections`` — accepted ever / now;
        * ``http_errors`` — 4xx/5xx responses sent;
        * ``stream_clients`` — currently-attached ``/api/stream``
          subscribers;
        * ``snapshot_count`` / ``snapshot_seconds_sum`` /
          ``snapshot_seconds_last`` — report-building latency (the
          ``/metrics`` "snapshot latency" series);
        * ``window_folds`` / ``window_fold_seconds_sum`` — windowed
          ``/api/top`` incremental re-folds;
        * ``whatif_folds`` / ``whatif_fold_seconds_sum`` —
          counterfactual ``/api/whatif`` re-folds;
        * ``max_window_s`` — largest window ever served (retention holds
          at least this much history);
        * ``retention_pruned_blocks`` / ``retention_errors`` — age-based
          pruning outcomes.
        """
        with self._lock:
            return {
                "address": list(self.address),
                "requests": dict(self._requests),
                "connections": self._connections,
                "open_connections": self._open_conns,
                "http_errors": self._http_errors,
                "stream_clients": self._stream_clients,
                "snapshot_count": self._snap_count,
                "snapshot_seconds_sum": self._snap_seconds_sum,
                "snapshot_seconds_last": self._snap_seconds_last,
                "window_folds": self._window_folds,
                "window_fold_seconds_sum": self._window_fold_seconds_sum,
                "whatif_folds": self._whatif_folds,
                "whatif_fold_seconds_sum": self._whatif_fold_seconds_sum,
                "max_window_s": self._max_window_s,
                "retention_pruned_blocks": self._retention_pruned,
                "retention_errors": self._retention_errors,
            }

    # -- event loop ----------------------------------------------------------
    def _loop(self) -> None:  # lint: event-loop
        """The selector loop: accept, read, route, write, stream sweep —
        one thread serves every client."""
        while not self._shutdown.is_set():
            try:
                events = self._sel.select(0.05)
            except OSError:
                return
            for key, mask in events:
                data = key.data
                if data == "accept":
                    self._do_accept()
                elif data == "wake":
                    try:
                        while self._wake_r.recv(4096):
                            pass
                    except (BlockingIOError, OSError):
                        pass
                else:
                    conn = data
                    if mask & selectors.EVENT_WRITE and not conn.closed:
                        self._flush_wbuf(conn)
                    if mask & selectors.EVENT_READ and not conn.closed:
                        self._do_read(conn)
            self._sweep(time.monotonic())

    def _do_accept(self) -> None:
        while True:
            try:
                s, _ = self._sock.accept()
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                return
            s.setblocking(False)
            conn = _HttpConn(s)
            self._conns.add(conn)
            self._sel.register(s, selectors.EVENT_READ, conn)
            with self._lock:
                self._connections += 1
                self._open_conns += 1
                self._conn_socks.add(s)

    def _do_read(self, conn: _HttpConn) -> None:
        try:
            data = conn.sock.recv(1 << 16)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._close_conn(conn)
            return
        if not data:
            self._close_conn(conn)
            return
        conn.rbuf += data
        conn.last_rx = time.monotonic()
        if conn.responded:
            return                      # pipelined extras are ignored
        try:
            got = http.parse_request(bytes(conn.rbuf))
        except http.HttpError as e:
            self._count_error()
            self._respond(conn, http.error_response(e.status, e.message))
            return
        if got is None:
            return
        req, consumed = got
        del conn.rbuf[:consumed]
        self._dispatch(conn, req)

    def _dispatch(self, conn: _HttpConn, req: http.Request) -> None:
        label = self._route_label(req)
        with self._lock:
            self._requests[label] = self._requests.get(label, 0) + 1
        try:
            out = self._route(req)
        except http.HttpError as e:
            self._count_error()
            self._respond(conn, http.error_response(e.status, e.message))
            return
        except Exception as e:  # noqa: BLE001 — a handler bug must 500, not kill the loop
            self._count_error()
            self._respond(conn, http.error_response(
                500, f"{type(e).__name__}: {e}"))
            return
        if out == "stream":
            conn.stream_every = min(max(
                req.query_float("every", 0.5) or 0.5, 0.05), 60.0)
            conn.stream_top_n = req.query_int("n", self.top_n, lo=1,
                                              hi=1000)
            conn.stream_next = 0.0      # first frame on the next sweep
            with self._lock:
                self._stream_clients += 1
            self._send_conn(conn, http.stream_head())
        else:
            self._respond(conn, out)

    @staticmethod
    def _route_label(req: http.Request) -> str:
        path = req.path.rstrip("/") or "/"
        if path.startswith("/api/hosts/"):
            return "/api/hosts/<id>"
        if path in ("/", "/api/report", "/api/top", "/api/whatif",
                    "/api/hosts", "/api/stream", "/metrics"):
            return path
        return "<other>"

    def _route(self, req: http.Request):
        if req.method != "GET":
            raise http.HttpError(405, f"{req.method} not supported "
                                 "(GET-only service)")
        path = req.path.rstrip("/") or "/"
        if path == "/":
            return http.response(200, DASHBOARD_HTML,
                                 "text/html; charset=utf-8")
        if path == "/api/report":
            return http.response(200, self._report_json())
        if path == "/api/top":
            return http.json_response(200, self._top_doc(req))
        if path == "/api/whatif":
            return http.json_response(200, self._whatif_doc(req))
        if path == "/api/hosts":
            return http.json_response(200, self._hosts_doc())
        if path.startswith("/api/hosts/"):
            return http.json_response(
                200, self._host_doc(path[len("/api/hosts/"):]))
        if path == "/metrics":
            return http.response(
                200, self._metrics_text(),
                "text/plain; version=0.0.4; charset=utf-8")
        if path == "/api/stream":
            return "stream"
        raise http.HttpError(404, f"no route {req.path!r}")

    # -- write side ----------------------------------------------------------
    def _respond(self, conn: _HttpConn, data: bytes) -> None:
        conn.responded = True
        self._send_conn(conn, data)

    def _send_conn(self, conn: _HttpConn, data: bytes) -> None:
        conn.wbuf += data
        self._flush_wbuf(conn)

    def _flush_wbuf(self, conn: _HttpConn) -> None:
        if conn.wbuf and not conn.closed:
            try:
                n = conn.sock.send(conn.wbuf)
                del conn.wbuf[:n]
            except (BlockingIOError, InterruptedError):
                pass
            except OSError:
                self._close_conn(conn)
                return
        if not conn.wbuf and conn.responded \
                and conn.stream_every is None:
            self._close_conn(conn)      # Connection: close, drained
            return
        self._update_interest(conn)

    def _update_interest(self, conn: _HttpConn) -> None:
        if conn.closed:
            return
        mask = selectors.EVENT_READ     # always read: detect client EOF
        if conn.wbuf:
            mask |= selectors.EVENT_WRITE
        if mask == conn.mask:
            return
        try:
            self._sel.modify(conn.sock, mask, conn)
        except (KeyError, ValueError, OSError):
            self._close_conn(conn)
            return
        conn.mask = mask

    def _close_conn(self, conn: _HttpConn) -> None:
        if conn.closed:
            return
        conn.closed = True
        try:
            self._sel.unregister(conn.sock)
        except (KeyError, ValueError, OSError):
            pass
        conn.mask = 0
        try:
            conn.sock.close()
        except OSError:
            pass
        self._conns.discard(conn)
        with self._lock:
            self._open_conns -= 1
            self._conn_socks.discard(conn.sock)
            if conn.stream_every is not None:
                self._stream_clients -= 1

    def _sweep(self, now: float) -> None:
        """Per-iteration housekeeping: push due stream frames (one
        payload build per distinct ``n`` per tick, shared across
        subscribers) and reap idle half-open connections."""
        cache: dict = {}
        for conn in list(self._conns):
            if conn.closed:
                continue
            if conn.stream_every is not None:
                if now < conn.stream_next:
                    continue
                conn.stream_next = now + conn.stream_every
                key = conn.stream_top_n
                line = cache.get(key)
                if line is None:
                    try:
                        rep = self._snapshot_timed(key)
                        doc = payload_lib.build_watch_payload(
                            self.session, rep, key)
                        line = json.dumps(doc) + "\n"
                    except Exception:  # noqa: BLE001 — a bad tick skips a frame, not the client
                        line = ""
                    cache[key] = line
                if line:
                    self._send_conn(conn, http.chunk(line))
            elif not conn.responded \
                    and now - conn.last_rx > self.CONN_IDLE_S:
                self._close_conn(conn)

    def _count_error(self) -> None:
        with self._lock:
            self._http_errors += 1

    # -- report building -----------------------------------------------------
    def _snapshot_timed(self, top_n: int | None):
        t0 = time.perf_counter()
        rep = self.session.snapshot(top_n)
        dt = time.perf_counter() - t0
        with self._lock:
            self._snap_count += 1
            self._snap_seconds_sum += dt
            self._snap_seconds_last = dt
        return rep

    def _report_json(self) -> bytes:
        """The ``/api/report`` body — literally ``session.export("json")``
        (same exporter, same snapshot path), so byte-equality with the
        pull API is structural, not aspirational."""
        t0 = time.perf_counter()
        body = self.session.export("json").encode("utf-8")
        dt = time.perf_counter() - t0
        with self._lock:
            self._snap_count += 1
            self._snap_seconds_sum += dt
            self._snap_seconds_last = dt
        return body

    def _top_doc(self, req: http.Request) -> dict:
        n = req.query_int("n", self.top_n, lo=1, hi=1000)
        window_s = req.query_float("window")
        window_ns = None
        if window_s is None:
            key = "full"
            rep = self._snapshot_timed(n)
        else:
            if window_s <= 0:
                raise http.HttpError(400, "window must be > 0 seconds")
            if not self.fleet_dir:
                raise http.HttpError(
                    400, "window queries need durable journals "
                    "(IngestServer(fleet_dir=...) or from_fleet_dir)")
            key = f"w:{window_s:g}"
            span = fleet_dir_time_span(self.fleet_dir)
            if span is None:
                return {"schema_version": TOP_SCHEMA_VERSION, "n": n,
                        "window_s": window_s, "window_ns": None,
                        "baseline": False, "entries": []}
            hi = span[1]
            lo = hi - int(window_s * 1e9)
            window_ns = [lo, hi]
            with self._lock:
                self._max_window_s = max(self._max_window_s, window_s)
            rep = self._windowed_report(lo, hi, n)
        entries = path_entries(rep, n)
        prev = self._prev_top.get(key)
        for e in entries:
            got = prev.get(e["path"]) if prev else None
            e["delta_cmetric_s"] = (e["cmetric_s"] - got[0]
                                    if got else None)
            e["prev_rank"] = got[1] if got else None
        self._prev_top[key] = {e["path"]: (e["cmetric_s"], e["rank"])
                               for e in entries}
        return {"schema_version": TOP_SCHEMA_VERSION, "n": n,
                "window_s": window_s, "window_ns": window_ns,
                "baseline": prev is not None, "entries": entries}

    def _windowed_report(self, lo: int, hi: int, top_n: int):
        """Incremental re-fold of exactly the journal blocks intersecting
        ``[lo, hi]`` (fleet time): a fresh FleetSource over the fleet_dir
        with ``window_ns`` set folds through a throwaway offline session
        — same merge, same fold, same detector as the live path."""
        t0 = time.perf_counter()
        src = FleetSource.from_fleet_dir(
            self.fleet_dir, window_ns=(lo, hi),
            chunk_events=self.session.chunk_events)
        sub = ProfileSession(src, n_min=self.session._resolved_n_min(),
                             fold_backend=self.session.fold_backend,
                             top_n=top_n)
        rep = sub.result(top_n)
        dt = time.perf_counter() - t0
        with self._lock:
            self._window_folds += 1
            self._window_fold_seconds_sum += dt
        return rep

    def _whatif_doc(self, req: http.Request) -> dict:
        """``GET /api/whatif?tag=&shrink=`` (or ``host=`` / ``worker=`` /
        ``path=<rank>``): one counterfactual re-fold over the session's
        capture.  The body is exactly ``report.what_if(...).to_doc()``
        through the same ``json.dumps(doc, indent=2)`` as the offline
        ``to_json()``, so the wire bytes match an offline what-if on the
        same fleet_dir byte-for-byte."""
        shrink = req.query_float("shrink", 0.0)
        if shrink is None or not 0.0 <= shrink <= 1.0:
            raise http.HttpError(400, "shrink must be in [0, 1]")
        tag = req.query.get("tag")
        host = req.query.get("host")
        worker = req.query.get("worker")
        path_rank = req.query_int("path")
        if sum(v is not None for v in (tag, host, worker, path_rank)) != 1:
            raise http.HttpError(
                400, "select exactly one target: tag=, host=, worker= "
                "or path=<rank>")
        top_n = req.query_int("n", self.top_n, lo=1, hi=1000)
        rep = self._snapshot_timed(None)
        t0 = time.perf_counter()
        try:
            wi = rep.what_if(tag, shrink=shrink, host=host, worker=worker,
                             path=path_rank, top_n=top_n)
        except ValueError as e:
            raise http.HttpError(404, str(e)) from None
        except RuntimeError as e:
            raise http.HttpError(400, str(e)) from None
        dt = time.perf_counter() - t0
        with self._lock:
            self._whatif_folds += 1
            self._whatif_fold_seconds_sum += dt
        return wi.to_doc()

    def _hosts_doc(self) -> dict:
        rep = self._snapshot_timed(None)
        p = payload_lib.build_watch_payload(self.session, rep)
        doc = {
            "schema_version": payload_lib.PAYLOAD_SCHEMA_VERSION,
            "mode": p["mode"],
            "events_folded": p["events_folded"],
            "worker_hosts": p["worker_hosts"],
            "health": p["health"],
            "hosts": p["per_host"],
        }
        if self.server is not None:
            doc["ingest"] = self.server.stats()
        return doc

    def _host_doc(self, host_id: str) -> dict:
        rep = self._snapshot_timed(None)
        if not rep.worker_hosts:
            raise http.HttpError(
                404, "no host lanes (single-host session)")
        per = rep.per_host()
        if host_id not in per:
            raise http.HttpError(404, f"unknown host {host_id!r}")
        doc = {"schema_version": payload_lib.PAYLOAD_SCHEMA_VERSION,
               "host_id": host_id, **per[host_id]}
        doc["worker_lanes"] = [
            {"name": rep.worker_names[i],
             "cmetric_s": float(rep.per_worker[i])}
            for i, h in enumerate(rep.worker_hosts) if h == host_id
        ]
        src = self.session.source
        if isinstance(src, FleetSource):
            with src.cond:
                h = next((h for h in src.hosts
                          if h.host_id == host_id), None)
                if h is not None:
                    doc["stream"] = {
                        "rows_in": h.rows_in,
                        "chunks_in": h.chunks_in,
                        "buffered_rows": h.buffered_rows,
                        "finished": h.finished,
                        "idle_exempt": h.idle_exempt,
                        "clock_offset_ns": h.clock_offset_ns,
                        "last_seen_ns": h.last_seen_ns,
                    }
        store = self._journal_stores().get(host_id)
        if store is not None:
            tb = store.time_bounds()
            doc["journal"] = {
                "blocks": store.blocks,
                "first_block": store.first_block,
                "segments": store.segments,
                "rows_on_disk": store.rows_on_disk,
                "bytes": store.spilled_nbytes,
                "pruned_blocks": store.pruned_blocks,
                "time_bounds_ns": list(tb) if tb else None,
            }
        return doc

    def _metrics_text(self) -> str:
        samples: list = []
        svc = self.stats()
        svc.pop("address", None)
        for route, count in sorted(svc.pop("requests", {}).items()):
            samples.append(("gapp_service_requests", {"route": route},
                            float(count)))
        samples.extend(prom.flatten_stats("gapp_service", svc))
        st = self.session.stats()
        source = st.pop("source", None)
        sinks = st.pop("sinks", None)
        samples.extend(prom.flatten_stats("gapp_session", st))
        if isinstance(source, dict):
            samples.extend(prom.flatten_stats("gapp_fleet", source))
        for s in sinks or []:
            samples.extend(prom.flatten_stats(
                "gapp_sink", s, {"host": str(s.get("host_id", "?"))}))
        if self.server is not None:
            srv = self.server.stats()
            if isinstance(source, dict):
                for k in list(srv):
                    if k in source:
                        srv.pop(k)      # already exported as gapp_fleet_*
            samples.extend(prom.flatten_stats("gapp_ingest", srv))
        for hid, store in self._journal_stores().items():
            labels = {"host": hid}
            samples.append(("gapp_journal_bytes", labels,
                            float(store.spilled_nbytes)))
            samples.append(("gapp_journal_blocks", labels,
                            float(store.blocks)))
            samples.append(("gapp_journal_segments", labels,
                            float(store.segments)))
            samples.append(("gapp_journal_pruned_blocks", labels,
                            float(store.pruned_blocks)))
        # fold rate across scrapes (loop-thread-owned anchor)
        now = time.monotonic()
        folded = int(st.get("events_folded", 0))
        prev_t, prev_f = self._rate_prev
        rate = (folded - prev_f) / (now - prev_t) if now > prev_t else 0.0
        self._rate_prev = (now, folded)
        samples.append(("gapp_service_fold_events_per_s", None,
                        max(rate, 0.0)))
        return prom.render_metrics(samples, help_text={
            "gapp_service_fold_events_per_s":
                "events folded per second since the previous scrape",
            "gapp_service_snapshot_seconds_last":
                "latency of the most recent report snapshot",
            "gapp_journal_bytes":
                "durable journal bytes on disk per host",
        })

    # -- retention -----------------------------------------------------------
    def _journal_stores(self) -> dict:
        """host_id -> journal SpillStore: the live server's open journals
        when attached, else read-only opens over the fleet_dir."""
        if self.server is not None:
            return self.server.host_journals()
        if not self.fleet_dir:
            return {}
        out: dict = {}
        for mp in sorted(glob_lib.glob(os.path.join(self.fleet_dir,
                                                    "*.meta.json"))):
            m = load_json(mp)
            if not m or not m.get("journal"):
                continue
            jp = os.path.join(os.path.dirname(mp), m["journal"])
            if journal_on_disk(jp):
                out[str(m.get("host_id", mp))] = \
                    SpillStore.open_readonly(jp)
        return out

    def _retention_loop(self) -> None:
        interval = max(float(self.retention.sweep_interval_s), 0.05)
        while not self._shutdown.wait(interval):
            try:
                self.retention_sweep()
            except Exception:  # noqa: BLE001 — sweeper must survive transient fs races
                with self._lock:
                    self._retention_errors += 1

    def retention_sweep(self) -> int:
        """One retention pass (also callable directly, e.g. from tests or
        a cron shell): for every journal, prune sealed segments older
        than ``max_age_s`` — measured against that journal's NEWEST
        event, so a quiet fleet never prunes on wall-clock drift alone —
        while always keeping at least the largest query window served
        (and ``keep_window_s``).  Returns blocks pruned."""
        pol = self.retention
        if pol is None:
            return 0
        with self._lock:
            guard_s = max(self._max_window_s, pol.keep_window_s or 0.0)
        hold_ns = int(max(float(pol.max_age_s), guard_s) * 1e9)
        pruned = 0
        for store in self._journal_stores().values():
            tb = store.time_bounds()
            if tb is None:
                continue
            pruned += store.prune_before_time(
                tb[1] - hold_ns, respect_ack=pol.respect_ack)
        if pruned:
            with self._lock:
                self._retention_pruned += pruned
        return pruned
