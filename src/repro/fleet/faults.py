"""Deterministic fault injection for the fleet transport.

Chaos testing the ingest path with real kill -9s and packet loss makes
every failure a flaky race.  :class:`FaultPlan` instead injects failures
*beneath* :mod:`repro.fleet.wire` / :mod:`repro.fleet.transport` through
two shims, so each failure mode is an ordinary, reproducible unit test:

* **wire shim** — :meth:`FaultPlan.wrap_producer` wraps the producer's
  connection file object; every ``write()`` is one frame (the sink writes
  whole frames), so rules trigger on exact frame counts: kill the
  connection at frame N (``drop``), write a byte-truncated frame then die
  (``truncate`` — the server sees a torn frame that never completes),
  flip a frame-header byte (``corrupt`` — byte 2 is the schema version,
  which every decoder hard-rejects, so corruption is *detected*, never
  silently folded), sleep before a frame (``stall``) or before every
  frame (``slow``).  Connect attempts are gated too
  (``refuse_connect`` — a partition is "drop the connection, then refuse
  the next K dials").
* **journal shim** — :meth:`FaultPlan.wrap_journal` proxies a
  :class:`~repro.core.spill.SpillStore`; ``disk_full`` makes
  ``append_block`` raise ``OSError(ENOSPC)`` for the next K attempts once
  the store reaches a given block, exercising both journal-full policies
  (producer: shed the chunk before it consumes a seq; server: refuse the
  chunk so the reconnect replay re-delivers it).

Determinism: rules fire on frame/block/attempt counts, never timers, and
every injected fault is appended to :attr:`FaultPlan.events` —
``(host_id, kind, detail)`` in injection order — so a test can assert the
exact fault sequence it scripted.  The optional ``seed`` feeds
:attr:`FaultPlan.rng`, the *only* randomness source a chaos harness
should use to scatter rules, making a whole 64-producer chaos run
replayable from one integer.
"""
from __future__ import annotations

import errno
import random
import threading
import time


class _Rule:
    __slots__ = ("kind", "conn", "frame", "arg", "remaining")

    def __init__(self, kind, conn, frame, arg, remaining=1):
        self.kind = kind
        self.conn = conn        # connection index (per host) or None = any
        self.frame = frame      # frame/block index or None = any
        self.arg = arg
        self.remaining = remaining


class FaultPlan:
    """A scripted, seedable schedule of transport/journal faults.

    Rules are keyed by ``host_id`` (use ``"*"`` to match every host).
    Frame and connection indices are 0-based and count per host:
    connection 0 is the host's first dial, frame 0 its first write on
    that connection (HELLO).  All methods are thread-safe — one plan is
    shared across every producer/server thread of a chaos run.
    """

    ANY = "*"

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.seed = seed
        self.events: list[tuple[str, str, str]] = []
        self._lock = threading.Lock()
        self._rules: dict[str, list[_Rule]] = {}
        self._conns: dict[str, int] = {}       # successful dials per host
        self._schedules: dict[str, list[int]] = {}

    # -- scripting API -------------------------------------------------------
    def _add(self, host: str, rule: _Rule) -> "FaultPlan":
        with self._lock:
            self._rules.setdefault(str(host), []).append(rule)
        return self

    def drop(self, host: str, *, frame: int,
             conn: int | None = None) -> "FaultPlan":
        """Kill the connection (ConnectionResetError) instead of writing
        frame ``frame``."""
        return self._add(host, _Rule("drop", conn, int(frame), None))

    def truncate(self, host: str, *, frame: int, keep: int = 4,
                 conn: int | None = None) -> "FaultPlan":
        """Write only the first ``keep`` bytes of frame ``frame``, then
        kill the connection — the peer holds a torn frame forever."""
        return self._add(host, _Rule("truncate", conn, int(frame),
                                     max(int(keep), 0)))

    def corrupt(self, host: str, *, frame: int, offset: int = 2,
                conn: int | None = None) -> "FaultPlan":
        """Flip one byte of frame ``frame`` before writing it.  The
        default offset 2 is the frame header's schema-version byte, which
        every decoder rejects — corruption surfaces as a protocol error,
        never as silently-wrong data."""
        return self._add(host, _Rule("corrupt", conn, int(frame),
                                     int(offset)))

    def stall(self, host: str, *, frame: int, seconds: float,
              conn: int | None = None) -> "FaultPlan":
        """Sleep ``seconds`` before writing frame ``frame`` (one-shot
        latency spike)."""
        return self._add(host, _Rule("stall", conn, int(frame),
                                     float(seconds)))

    def slow(self, host: str, *, per_frame: float) -> "FaultPlan":
        """Sleep ``per_frame`` seconds before EVERY frame on every
        connection of ``host`` — a persistently slow producer."""
        return self._add(host, _Rule("slow", None, None, float(per_frame),
                                     remaining=1 << 62))

    def refuse_connect(self, host: str, *, times: int = 1) -> "FaultPlan":
        """Refuse the host's next ``times`` dials
        (ConnectionRefusedError).  ``drop`` + ``refuse_connect`` scripts a
        network partition of bounded length."""
        return self._add(host, _Rule("refuse", None, None, None,
                                     remaining=int(times)))

    def disk_full(self, host: str, *, at_block: int,
                  failures: int = 1) -> "FaultPlan":
        """Once the wrapped journal holds ``at_block`` blocks, the next
        ``failures`` ``append_block`` attempts raise
        ``OSError(ENOSPC)`` — then the disk "recovers"."""
        return self._add(host, _Rule("disk_full", None, int(at_block), None,
                                     remaining=int(failures)))

    # generic step schedules (server kills/restarts etc.): the chaos
    # driver polls `due(name, step)` with its progress counter; each
    # threshold fires exactly once, in order
    def schedule(self, name: str, at_steps) -> "FaultPlan":
        with self._lock:
            self._schedules.setdefault(str(name), []).extend(
                sorted(int(s) for s in at_steps))
        return self

    def due(self, name: str, step: int) -> bool:
        with self._lock:
            pending = self._schedules.get(str(name))
            if pending and step >= pending[0]:
                pending.pop(0)
                self.events.append((name, "due", f"step={step}"))
                return True
            return False

    # -- shims ---------------------------------------------------------------
    def connect(self, host: str) -> int:
        """Gate one dial attempt; returns this connection's index (counts
        only successful dials).  Raises ConnectionRefusedError while a
        ``refuse_connect`` budget remains."""
        with self._lock:
            rule = self._find(host, "refuse")
            if rule is not None:
                rule.remaining -= 1
                self.events.append((host, "refuse", ""))
                raise ConnectionRefusedError(
                    errno.ECONNREFUSED, f"fault plan refused {host}")
            idx = self._conns.get(host, 0)
            self._conns[host] = idx + 1
            return idx

    def wrap_producer(self, host: str, fileobj, conn: int = 0):
        """Wrap a connection's file object so writes pass through the
        frame-fault rules (one ``write()`` == one frame)."""
        return _FaultedFile(self, str(host), int(conn), fileobj)

    def wrap_journal(self, host: str, store):
        """Proxy a SpillStore so ``append_block`` honors ``disk_full``
        rules; everything else delegates untouched."""
        return _FaultedJournal(self, str(host), store)

    # -- matching (internal) -------------------------------------------------
    def _find(self, host: str, kind: str, conn: int | None = None,
              frame: int | None = None) -> _Rule | None:
        """Caller holds the lock.  First live rule matching host ('*'
        matches any), kind, and — when the rule pins them — conn/frame."""
        for key in (host, self.ANY):
            for r in self._rules.get(key, ()):
                if r.kind != kind or r.remaining <= 0:
                    continue
                if r.conn is not None and r.conn != conn:
                    continue
                if r.frame is not None and frame is not None \
                        and r.frame != frame:
                    continue
                return r
        return None

    def _on_write(self, host: str, conn: int, frame: int,
                  data: bytes) -> bytes | None:
        """Apply write-side rules to one frame.  Returns the (possibly
        mutated) bytes to write, or raises to kill the connection.  A
        ``truncate`` rule writes its prefix itself and then raises, so
        ``None`` is never returned to the caller."""
        with self._lock:
            slow = self._find(host, "slow", conn, None)
            stall = self._find(host, "stall", conn, frame)
            drop = self._find(host, "drop", conn, frame)
            trunc = self._find(host, "truncate", conn, frame)
            corr = self._find(host, "corrupt", conn, frame)
            for r in (stall, drop, trunc, corr):
                if r is not None:
                    r.remaining -= 1
        delay = (slow.arg if slow is not None else 0.0) \
            + (stall.arg if stall is not None else 0.0)
        if delay:
            if stall is not None:
                with self._lock:
                    self.events.append((host, "stall",
                                        f"conn={conn} frame={frame} "
                                        f"s={delay}"))
            time.sleep(delay)
        if drop is not None:
            with self._lock:
                self.events.append((host, "drop",
                                    f"conn={conn} frame={frame}"))
            raise ConnectionResetError(
                errno.ECONNRESET, f"fault plan dropped {host} @{frame}")
        if trunc is not None:
            with self._lock:
                self.events.append((host, "truncate",
                                    f"conn={conn} frame={frame} "
                                    f"keep={trunc.arg}"))
            return data[:trunc.arg]     # caller writes this, then dies
        if corr is not None:
            with self._lock:
                self.events.append((host, "corrupt",
                                    f"conn={conn} frame={frame} "
                                    f"offset={corr.arg}"))
            mutated = bytearray(data)
            if mutated:
                mutated[min(corr.arg, len(mutated) - 1)] ^= 0xFF
            return bytes(mutated)
        return data

    def _truncates(self, host: str, conn: int, frame: int) -> bool:
        """Peek (without consuming) whether frame ``frame`` is a truncate
        target — the wrapper must kill the connection after the partial
        write."""
        with self._lock:
            for key in (host, self.ANY):
                for r in self._rules.get(key, ()):
                    if r.kind == "truncate" and r.remaining == 0 \
                            and (r.conn is None or r.conn == conn) \
                            and r.frame == frame:
                        return True
        return False

    def _on_append(self, host: str, blocks: int) -> None:
        with self._lock:
            rule = None
            for key in (host, self.ANY):
                for r in self._rules.get(key, ()):
                    if r.kind == "disk_full" and r.remaining > 0 \
                            and blocks >= r.frame:
                        rule = r
                        break
                if rule is not None:
                    break
            if rule is None:
                return
            rule.remaining -= 1
            self.events.append((host, "disk_full", f"block={blocks}"))
        raise OSError(errno.ENOSPC,
                      f"fault plan: no space on {host} journal @{blocks}")


class _FaultedFile:
    """File-object shim: one ``write()`` == one frame (the sink's
    contract), reads/flush/close delegate."""

    def __init__(self, plan: FaultPlan, host: str, conn: int, raw):
        self._plan = plan
        self._host = host
        self._conn = conn
        self._raw = raw
        self.frames = 0

    def write(self, data):
        frame = self.frames
        self.frames += 1        # dropped frames still count: determinism
        out = self._plan._on_write(self._host, self._conn, frame, data)
        n = self._raw.write(out)
        if len(out) < len(data) \
                and self._plan._truncates(self._host, self._conn, frame):
            # a torn frame must actually reach the peer before this side
            # dies, or the test degenerates into a plain drop
            self._raw.flush()
            raise ConnectionResetError(
                errno.ECONNRESET,
                f"fault plan truncated {self._host} @{frame}")
        return n

    def read(self, *a, **kw):
        return self._raw.read(*a, **kw)

    def readinto(self, *a, **kw):
        return self._raw.readinto(*a, **kw)

    def flush(self):
        return self._raw.flush()

    def close(self):
        return self._raw.close()

    def __getattr__(self, name):
        return getattr(self._raw, name)


class _FaultedJournal:
    """SpillStore proxy: ``append_block`` honors ``disk_full`` rules."""

    def __init__(self, plan: FaultPlan, host: str, store):
        self._plan = plan
        self._host = host
        self._store = store

    def append_block(self, *cols, sync: bool = False) -> int:
        self._plan._on_append(self._host, self._store.blocks)
        return self._store.append_block(*cols, sync=sync)

    def __getattr__(self, name):
        return getattr(self._store, name)
