"""Socket transport: stream drained chunks off-host, ingest N producers.

Producer side — :class:`RemoteSink` attaches to a live
:class:`~repro.core.session.ProfileSession` (``attach_remote(session,
addr)`` or ``session.export("remote", addr=...)``) as a tracer *sink*:
every drained+folded chunk the tracer appends to its store is also handed
to the sink, which frames it (:mod:`repro.fleet.wire`) and ships it from a
background sender thread.  The capture hot path never blocks on the
network: the hand-off is a bounded queue, and only when the queue is full
does the *drain* (not the probes) wait — backpressure — or, with
``drop_when_full=True``, the chunk is dropped and counted like a full BPF
ring.  The sender reconnects with backoff on socket errors; a reconnect
re-handshakes, bumping the clock-sync epoch, and never loses the chunk it
was holding.

**Durable mode** (``journal=path``): every chunk is appended to a local
:class:`~repro.core.spill.SpillStore`-layout journal — block index ==
chunk ``seq`` — *before* it is queued for send, and every (re)connect
replays ``[ack_seq, …)`` from that journal (the WELCOME ``ack_seq`` is
the server's durable receive floor).  In-flight chunks lost to a broken
connection, and even whole producer restarts, become recovered history:
a fresh sink opened on the same journal resumes the capture's instance
nonce, seq numbering and tag/stack id space (registries are re-seeded
from the journal's meta sidecar), so the server folds exactly-once with
zero ``lost_chunks``.

Consumer side — :class:`IngestServer` accepts any number of producer
connections, performs the HELLO/WELCOME handshake (allocating the host
index, the clock offset — declared by the producer, or measured as
``t_server − t_client`` — and the payload compression codec), remaps
host-local tag/stack ids into the fleet-wide registries via the
incremental TAGS/STACKS sync frames, and pushes normalized chunks into
its :class:`~repro.fleet.aggregate.FleetSource` hub — which a
:class:`~repro.core.session.ProfileSession` drains like any other source.
One server + one session = a fleet-wide
:class:`~repro.core.detector.BottleneckReport` with host provenance.

With ``fleet_dir=`` the server is durable too: every accepted chunk is
journaled to a per-host SpillStore under that directory (host-local
columns, pre-normalization) next to a meta sidecar carrying the host's
identity, dedup floor, worker table, clock offset and registry entries.
A *restarted* server re-opens a reconnecting host's journal, restores the
dedup floor (so the WELCOME ``ack_seq`` survives the restart) and
backfills the merge with the journaled history; offline,
:meth:`~repro.fleet.aggregate.FleetSource.from_fleet_dir` replays the
whole directory bit-equal to the live merge.
"""
from __future__ import annotations

import hashlib
import os
import random
import re
import selectors
import socket
import struct
import threading
import time
import uuid
from collections import deque

import numpy as np

from repro.core.exporters import register_exporter
from repro.core.spill import SpillStore
from repro.fleet import wire
from repro.fleet.aggregate import (FleetSource, HostStream, load_json,
                                   restore_host_maps, write_json_atomic)
from repro.fleet.aggregate import _grow_idmap as _grow_map


def _set_entry(lst: list, idx: int, val) -> None:
    """Sparse list assignment (registry entries keyed by host-local id)."""
    while len(lst) <= idx:
        lst.append(None)
    lst[idx] = val


# ---------------------------------------------------------------------------
# producer: RemoteSink
# ---------------------------------------------------------------------------

class RemoteSink:
    """Stream a session's drained chunks to an :class:`IngestServer`.

    Attach via :func:`attach_remote` / ``session.export("remote", ...)``;
    or hand-construct and append to ``tracer.sinks``.  ``clock_offset_ns``
    is the *declared* offset of this host's capture clock to the fleet
    clock; the default ``None`` lets the server measure one from the
    handshake — capture clocks (``perf_counter_ns``) have unrelated bases
    across machines, so declaring 0 is only correct for co-located
    producers sharing a clock (tests/benchmarks pass it explicitly).

    ``journal=path`` turns on durable mode: chunks are journaled (flushed
    to the OS — durable against a process crash; pass
    ``journal_fsync=True`` to fsync every block and extend that to power
    loss, at a per-chunk fsync cost) before they are queued, reconnects
    replay the server-unacked tail (WELCOME ``ack_seq``), and a sink
    re-opened on the same journal resumes the capture — instance nonce,
    seq numbering and the tag/stack id space all persist in
    ``path + ".meta.json"``.
    Note: with ``drop_when_full=True`` an over-budget chunk is shed
    *before* it is journaled — it never consumes a seq, so shedding is
    visible only as ``dropped_chunks``, never as a server-side gap;
    durable captures should keep the default backpressure.  ``codecs`` is the compression offer
    for the HELLO→WELCOME negotiation (the server picks; per frame, raw
    is the automatic fallback when deflate does not shrink the payload).
    """

    _CLOSE = object()

    def __init__(self, addr: tuple[str, int], host_id: str, *,
                 num_workers=0, worker_names=None, tags=None, stacks=None,
                 clock=time.perf_counter_ns,
                 clock_offset_ns: int | None = None,
                 max_buffer_chunks: int = 256, drop_when_full: bool = False,
                 reconnect_delay: float = 0.05, max_reconnects: int = 64,
                 backoff_max: float = 1.0, backoff_seed: int | None = None,
                 heartbeat_interval: float | None = 5.0,
                 connect_timeout: float = 5.0, journal: str | None = None,
                 journal_fsync: bool = False,
                 journal_rotate_bytes: int | None = None,
                 journal_rotate_age_s: float | None = None,
                 journal_retain_blocks: int | None = None,
                 fault_plan=None,
                 codecs: tuple[str, ...] = wire.SUPPORTED_CODECS):
        self.addr = tuple(addr)
        self.host_id = str(host_id)
        self._num_workers = num_workers          # int or () -> int
        self._worker_names = worker_names        # list or () -> list
        self.tags = tags
        self.stacks = stacks
        self.clock = clock
        self.clock_offset_ns = clock_offset_ns
        self.drop_when_full = drop_when_full
        self.reconnect_delay = float(reconnect_delay)
        self.max_reconnects = int(max_reconnects)
        # reconnect backoff: exponential, capped at backoff_max, with FULL
        # jitter — after an aggregator restart a whole fleet redials, and
        # deterministic delays would thunder back in lockstep forever
        self.backoff_max = float(backoff_max)
        self._backoff_rng = random.Random(backoff_seed)
        # liveness beacons while idle (only to servers that advertised
        # wire v3+); None disables
        self.heartbeat_interval = (None if heartbeat_interval is None
                                   else float(heartbeat_interval))
        self.connect_timeout = float(connect_timeout)
        self.fault_plan = fault_plan
        self.codecs = tuple(codecs)
        self.codec = wire.RAW       # negotiated per connection (WELCOME)
        self.ack_seq: int | None = None     # server floor, last WELCOME
        self._q: deque = deque()    # guarded-by: self._lock
        self._q_cap = max(int(max_buffer_chunks), 1)
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._drained = threading.Condition(self._lock)
        self._pending = 0           # guarded-by: self._lock
        self._closing = False       # guarded-by: self._lock
        self._thread: threading.Thread | None = None
        self.host_index: int | None = None
        self.epoch: int | None = None
        self.server_wire_version = 1    # learned from WELCOME (v3+ servers)
        self._last_sent_t: int | None = None    # capture time, last row sent
        self._cur_sock: socket.socket | None = None
        self._abort = False
        self._next_seq = 0          # guarded-by: self._lock
        #                             chunk sequence, NOT reset on reconnect:
        #                             the server dedups retransmits by it
        self.instance = uuid.uuid4().hex    # capture nonce (see wire HELLO)
        self._tags_sent = 0
        self._stacks_sent = 0
        self._meta_counts = (-1, -1)
        # counters
        self.rows_sent = 0
        self.chunks_sent = 0
        self.dropped_chunks = 0     # guarded-by: self._lock
        self.reconnects = 0
        self.send_errors = 0
        self.replayed_chunks = 0
        self.replayed_rows = 0
        self.heartbeats_sent = 0
        self.journal_errors = 0     # journal appends that raised (disk full)
        self.wire_bytes = 0         # bytes actually written to the socket
        self.raw_bytes = 0          # what the same frames cost uncompressed
        self.last_error: Exception | None = None
        self.failed = False         # guarded-by: self._lock
        # durable journal: every chunk lands here (flushed) before it is
        # queued; block index == seq, so a reconnect can replay exactly
        # the server's unacked tail
        self.journal_path = str(journal) if journal else None
        self.journal_fsync = bool(journal_fsync)
        self._journal: SpillStore | None = None
        self._meta_path: str | None = None
        self._journal_workers: tuple[int, list[str]] = (0, [])
        self._journal_kw = dict(rotate_bytes=journal_rotate_bytes,
                                rotate_age_s=journal_rotate_age_s,
                                retain_blocks=journal_retain_blocks)
        if self.journal_path is not None:
            self._meta_path = self.journal_path + ".meta.json"
            self._journal = SpillStore.open_append(self.journal_path,
                                                   **self._journal_kw)
            meta = load_json(self._meta_path)
            if meta and meta.get("instance"):
                # RESUME a previous incarnation of this capture: repeat its
                # instance nonce (the server keeps the dedup floor — a
                # fresh nonce would reset it and re-fold the history),
                # continue the seq numbering after the journaled blocks,
                # and re-seed empty registries so the new process's
                # tag/stack ids extend the old id space instead of
                # colliding with it
                self.instance = str(meta["instance"])
                self._seed_registries(meta)
                self._journal_workers = (
                    int(meta.get("num_workers", 0)),
                    [str(n) for n in meta.get("worker_names") or []])
            elif self._journal.blocks:
                # orphaned blocks with no meta are NOT resumable: without
                # the old nonce the server treats us as a fresh capture
                # (ack 0), and replaying the old blocks would fold a dead
                # capture's events into this one.  Rotate the history
                # aside (never destroy a durable capture; the fresh nonce
                # keeps successive orphans from clobbering each other) and
                # start clean
                self._journal.close()
                suffix = f".orphaned-{self.instance[:8]}"
                for _first, seg in self._journal._segment_paths():
                    os.replace(seg, seg + suffix)
                if os.path.exists(self.journal_path):
                    os.replace(self.journal_path,
                               self.journal_path + suffix)
                self._journal = SpillStore(self.journal_path,
                                           **self._journal_kw)
            self._next_seq = self._journal.blocks
            if self.fault_plan is not None:
                self._journal = self.fault_plan.wrap_journal(self.host_id,
                                                             self._journal)
            self._write_meta()

    # -- durable journal helpers ---------------------------------------------
    def _worker_table(self) -> tuple[int, list[str]]:
        """The worker table to declare: the union of the live session's
        workers and the journaled incarnation's (``_journal_workers``) —
        the replayed history's worker ids must all be inside the HELLO
        range or the server filters its rows as ``bad_rows``."""
        nw = int(self._resolve(self._num_workers, 0))
        names = list(self._resolve(self._worker_names,
                                   [f"w{i}" for i in range(nw)]))
        jnw, jnames = self._journal_workers
        for i in range(nw, jnw):
            names.append(jnames[i] if i < len(jnames) else f"w{i}")
        return max(nw, jnw), names

    def _seed_registries(self, meta: dict) -> None:
        if self.tags is not None and len(self.tags.names) == 0:
            for name, loc in meta.get("tags") or []:
                self.tags.intern(str(name), str(loc))
        if self.stacks is not None and len(self.stacks.paths) == 0:
            for path in meta.get("stacks") or []:
                self.stacks.intern(tuple(int(t) for t in path))

    def _registry_counts(self) -> tuple[int, int]:
        # locations/paths are the fully-published high-water marks (see
        # _sync_registries)
        t = (min(len(self.tags.names), len(self.tags.locations))
             if self.tags is not None else 0)
        s = len(self.stacks.paths) if self.stacks is not None else 0
        return t, s

    def _write_meta(self) -> None:
        """Persist the resume state next to the journal: instance nonce,
        the registry entries the journaled chunks reference, and the
        worker table (a resumed session that registers fewer workers must
        still HELLO the union, or the replayed history's rows for the
        missing workers are filtered server-side as bad_rows)."""
        if self._meta_path is None:
            return
        nt, ns = self._registry_counts()
        tags = ([[self.tags.names[i], self.tags.locations[i]]
                 for i in range(nt)] if self.tags is not None else [])
        stacks = ([[int(t) for t in self.stacks.paths[i]]
                   for i in range(ns)] if self.stacks is not None else [])
        nw, names = self._worker_table()
        write_json_atomic(self._meta_path, {
            "host_id": self.host_id, "instance": self.instance,
            "next_seq": self._next_seq, "tags": tags, "stacks": stacks,
            "num_workers": nw, "worker_names": names,
            "clock_offset_ns": self.clock_offset_ns,
        })
        self._meta_counts = (nt, ns)

    # -- store-interface intake (called under the tracer's fold lock) --------
    def append_columns(self, times, workers, deltas, tags, stacks) -> None:
        if len(times) == 0:
            return
        item = tuple(np.asarray(c) for c in
                     (times, workers, deltas, tags, stacks))
        with self._lock:
            if self._closing:
                self.dropped_chunks += 1
                return
            if (self.drop_when_full and not self.failed
                    and len(self._q) >= self._q_cap):
                # shed BEFORE the journal: a dropped chunk must never
                # consume a seq — the contiguous ack-replay window could
                # not recover it, and the resulting permanent gap would
                # read as in-flight loss server-side.  Dropped is dropped,
                # and it is counted here
                self.dropped_chunks += 1
                return
            seq = None
            if self._journal is not None:
                # durable first — and the meta BEFORE the block: the block
                # may reference tags interned since the last meta write,
                # and a crash between the two writes must not leave
                # journaled history whose ids a resume cannot resolve
                if self._registry_counts() != self._meta_counts:
                    self._write_meta()
                try:
                    seq = self._journal.append_block(*item,
                                                     sync=self.journal_fsync)
                except OSError as e:
                    # disk full: the failed append consumed NO block (the
                    # store truncates the partial frame), so dropping the
                    # chunk whole keeps seq == block-index intact — the
                    # chunk exists on NEITHER side, which the accounting
                    # (journal_errors + dropped_chunks) states exactly
                    self.journal_errors += 1
                    self.dropped_chunks += 1
                    self.last_error = e
                    return
                self._next_seq = seq + 1
            while len(self._q) >= self._q_cap and not self.failed:
                self._not_full.wait(0.05)       # backpressure on the drain
            if self.failed:
                self.dropped_chunks += 1
                return
            if seq is None:
                seq = self._next_seq
                self._next_seq = seq + 1
            self._q.append((seq, item))
            self._pending += 1
            self._not_empty.notify()

    def __len__(self) -> int:
        return self.rows_sent

    @property
    def nbytes(self) -> int:
        with self._lock:
            return sum(sum(c.nbytes for c in item[1]) for item in self._q
                       if item is not self._CLOSE)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "RemoteSink":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name=f"gapp-sink-{self.host_id}")
            self._thread.start()
        return self

    def spill(self) -> None:
        """Flush barrier (store-interface parity): block until every
        enqueued chunk has been sent (or the sink failed/closed)."""
        self.flush()

    def flush(self, timeout: float | None = 10.0) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._pending > 0 and not self.failed:
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self._drained.wait(0.05 if rem is None else min(rem, 0.05))
            return not self.failed

    def close(self, timeout: float | None = 10.0) -> None:
        """Flush, send BYE, stop the sender; seal the journal."""
        with self._lock:
            if self._closing:
                pass
            else:
                self._closing = True
                self._q.append(self._CLOSE)
                self._not_empty.notify()
        if self._thread is not None:
            self._thread.join(timeout)
        with self._lock:
            if self._journal is not None:
                self._write_meta()
                self._journal.close()
                self._journal = None

    def abort(self) -> None:
        """Ungraceful kill (chaos/testing): sever the socket mid-stream —
        no flush, no BYE — and stop the sender, like the process died.
        Queued chunks are discarded; a journaled capture loses nothing
        (a new sink opened on the same journal resumes the instance and
        the reconnect replay re-delivers whatever the server missed)."""
        self._abort = True
        with self._lock:
            self._closing = True
            self._q.clear()
            self._pending = 0
            self._not_empty.notify_all()
            self._not_full.notify_all()
            self._drained.notify_all()
        sock = self._cur_sock
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(2.0)
        with self._lock:
            if self._journal is not None:
                # seal for fd hygiene only — no meta write: the journal is
                # crash-consistent by construction, and resume trusts the
                # block count, not this process's dying breath
                self._journal.close()
                self._journal = None

    def stats(self) -> dict:
        return {"host_id": self.host_id, "rows_sent": self.rows_sent,
                "chunks_sent": self.chunks_sent,
                "dropped_chunks": self.dropped_chunks,
                "pending": self._pending,
                "reconnects": self.reconnects,
                "send_errors": self.send_errors, "failed": self.failed,
                "codec": self.codec,
                "replayed_chunks": self.replayed_chunks,
                "replayed_rows": self.replayed_rows,
                "heartbeats_sent": self.heartbeats_sent,
                "journal_errors": self.journal_errors,
                "server_wire_version": self.server_wire_version,
                "wire_bytes": self.wire_bytes, "raw_bytes": self.raw_bytes,
                "journal": self.journal_path}

    # -- sender thread -------------------------------------------------------
    def _resolve(self, v, default):
        if v is None:
            return default
        return v() if callable(v) else v

    def _connect(self):
        conn_idx = 0
        if self.fault_plan is not None:
            conn_idx = self.fault_plan.connect(self.host_id)
        sock = socket.create_connection(self.addr,
                                        timeout=self.connect_timeout)
        sock.settimeout(self.connect_timeout)
        f = sock.makefile("rwb")
        if self.fault_plan is not None:
            f = self.fault_plan.wrap_producer(self.host_id, f, conn_idx)
        nw, names = self._worker_table()
        self._send(f, wire.encode_hello(
            self.host_id, nw, names, t_client_ns=int(self.clock()),
            clock_offset_ns=self.clock_offset_ns, instance=self.instance,
            codecs=self.codecs))
        f.flush()
        frame = wire.read_frame(f)
        if frame is None or frame[0] != wire.WELCOME:
            raise wire.WireError("no WELCOME after HELLO")
        w = wire.decode_json(frame[1])
        self.host_index = int(w["host_index"])
        self.epoch = int(w["epoch"])
        self.server_wire_version = int(w.get("server_wire_version", 1))
        ack = w.get("ack_seq")              # absent on a v1 server
        self.ack_seq = None if ack is None else int(ack)
        if self._journal is not None and self.ack_seq is not None:
            # acked blocks are durable server-side: release them to the
            # journal's retention policy (no-op without retain_blocks=)
            self._journal.set_ack_floor(self.ack_seq)
        codec = w.get("codec", wire.RAW)    # server's pick from our offer
        self.codec = codec if codec in self.codecs else wire.RAW
        # rewind the registry sync counters to the server's high-water
        # marks: deltas committed against a server that then died (or
        # restored less from its meta) must retransmit
        ts, ss = w.get("tags_seen"), w.get("stacks_seen")
        if ts is not None:
            self._tags_sent = min(self._tags_sent, int(ts))
        if ss is not None:
            self._stacks_sent = min(self._stacks_sent, int(ss))
        return sock, f

    def _send(self, f, frame: bytes) -> None:
        f.write(frame)
        self.wire_bytes += len(frame)
        self.raw_bytes += wire.frame_raw_bytes(frame)

    def _replay(self, f, inflight) -> None:
        """Resend the journal blocks the server has not acked — run right
        after every (re)connect, before any queued chunk, so the stream
        the server folds is gapless.  [ack_seq, floor) covers exactly the
        chunks that are neither server-acked nor still queued locally
        (the queue and the in-flight item re-send themselves)."""
        if self._journal is None or self.ack_seq is None:
            return
        with self._lock:
            if inflight is not None and inflight is not self._CLOSE:
                floor = inflight[0]
            else:
                head = next((it for it in self._q
                             if it is not self._CLOSE), None)
                floor = head[0] if head is not None else self._next_seq
        if self.ack_seq >= floor:
            return
        tags_n, stacks_n = self._sync_registries(f)
        seq = self.ack_seq
        for cols in self._journal.iter_block_columns(skip=self.ack_seq):
            if seq >= floor:
                break
            self._send(f, wire.encode_chunk(
                self.host_index or 0, wire.MERGED_SHARD, self.epoch or 0,
                seq, *cols, codec=self.codec))
            self.replayed_chunks += 1
            self.replayed_rows += len(cols[0])
            if len(cols[0]):
                self._last_sent_t = int(cols[0][-1])
            seq += 1
        f.flush()
        # same commit rule as the live path: a flush that raised re-runs
        # the whole replay (and the registry deltas) after reconnect
        self._tags_sent, self._stacks_sent = tags_n, stacks_n

    def _sync_registries(self, f) -> tuple[int, int]:
        """Write any registry deltas; returns the (tags, stacks) high-water
        marks to COMMIT only after the whole batch flushes — a frame lost
        to a mid-send failure must be retransmitted after reconnect."""
        tags_n, stacks_n = self._tags_sent, self._stacks_sent
        if self.tags is not None:
            # lock-free read of the live registry: locations is appended
            # *second* under the registry lock, so its length is the safe
            # fully-published high-water mark
            n = min(len(self.tags.names), len(self.tags.locations))
            if n > tags_n:
                self._send(f, wire.encode_tags(
                    [(i, self.tags.names[i], self.tags.locations[i])
                     for i in range(tags_n, n)], codec=self.codec))
                tags_n = n
        if self.stacks is not None:
            n = len(self.stacks.paths)
            if n > stacks_n:
                self._send(f, wire.encode_stacks(
                    [(i, self.stacks.paths[i])
                     for i in range(stacks_n, n)], codec=self.codec))
                stacks_n = n
        return tags_n, stacks_n

    def _backoff(self, attempts: int) -> None:
        """Full-jitter exponential backoff: sleep uniform(0, min(cap,
        base * 2^attempts)).  Jitter decorrelates a fleet of producers
        redialing a restarted aggregator — fixed delays would keep the
        whole fleet thundering in lockstep."""
        cap = min(self.backoff_max,
                  self.reconnect_delay * (1 << min(attempts, 16)))
        delay = self._backoff_rng.uniform(0.0, cap)
        if delay > 0:
            time.sleep(delay)

    def _run(self) -> None:
        sock = f = None
        item = None
        attempts = 0
        last_io = time.monotonic()
        while not self._abort:
            try:
                if f is None:       # connect eagerly: handshake ASAP so the
                    #                 server learns this host before data
                    if attempts > 0:
                        self._backoff(attempts)
                    sock, f = self._connect()
                    self._cur_sock = sock
                    last_io = time.monotonic()
                    # journaled sinks replay the server's unacked tail
                    # before anything queued — seq gaps (lost in-flight
                    # chunks, producer restarts) become recovered history.
                    # Registry maps survive either way: a live server keeps
                    # them in memory, a restarted fleet_dir server restores
                    # them from the host's meta sidecar.
                    self._replay(f, item)
                    if (item is not None and item is not self._CLOSE
                            and self.ack_seq is not None
                            and item[0] < self.ack_seq):
                        # the server read the in-flight chunk before the
                        # connection died (our flush just never returned):
                        # resending it would only count a duplicate
                        self.rows_sent += len(item[1][0])
                        self.chunks_sent += 1
                        with self._lock:
                            self._pending -= 1
                            self._drained.notify_all()
                        item = None
                    if attempts > 0:
                        self.reconnects += 1
                    attempts = 0
                if item is None:
                    with self._lock:
                        if not self._q:
                            self._not_empty.wait(0.25)
                        if self._q:
                            item = self._q.popleft()
                            self._not_full.notify_all()
                    if item is None:
                        # idle: beacon liveness (and the safe watermark of
                        # the last streamed row) to v3+ servers so a quiet
                        # host neither trips the server's read deadline
                        # nor pins the fleet merge
                        if (self.heartbeat_interval is not None
                                and self.server_wire_version >= 3
                                and time.monotonic() - last_io
                                >= self.heartbeat_interval):
                            self._send(f, wire.encode_heartbeat(
                                self._last_sent_t, codec=self.codec))
                            f.flush()
                            self.heartbeats_sent += 1
                            last_io = time.monotonic()
                        continue
                if item is self._CLOSE:
                    self._send(f, wire.encode_bye(self.rows_sent,
                                                  self.chunks_sent))
                    f.flush()
                    # Delivery barrier.  flush() only proves the kernel
                    # buffered the bytes — a server that died mid-close can
                    # eat the whole tail of the stream (chunks AND the BYE)
                    # without the writer ever seeing an error.  The server
                    # closes the connection after it has *read* the BYE, so
                    # a clean EOF here proves every prior byte was consumed
                    # (the FIN is ordered after them); an RST (close with
                    # our unread data pending) or a timeout means delivery
                    # is uncertain — go around: reconnect, replay the
                    # unacked journal tail, and BYE again.
                    if f.read(1) != b"":
                        raise wire.WireError("unexpected data after BYE")
                    break
                seq, cols = item
                tags_n, stacks_n = self._sync_registries(f)
                self._send(f, wire.encode_chunk(
                    self.host_index or 0, wire.MERGED_SHARD, self.epoch or 0,
                    seq, *cols, codec=self.codec))
                f.flush()
                # commit only after the flush: a flush() that raised is
                # retransmitted whole after reconnect — the CHUNK with the
                # SAME seq (server dedups), the registry deltas again
                # (interning is idempotent server-side)
                self._tags_sent, self._stacks_sent = tags_n, stacks_n
                self.rows_sent += len(cols[0])
                self.chunks_sent += 1
                if len(cols[0]):
                    self._last_sent_t = int(cols[0][-1])
                last_io = time.monotonic()
                with self._lock:
                    self._pending -= 1
                    self._drained.notify_all()
                item = None
            except (OSError, wire.WireError) as e:   # reconnect w/ backoff
                if self._abort:
                    return
                self.send_errors += 1
                self.last_error = e
                if f is not None:
                    try:
                        f.close()
                        sock.close()
                    except OSError:
                        pass
                    f = sock = None
                    self._cur_sock = None
                attempts += 1
                if attempts > self.max_reconnects:
                    self._fail()
                    return
            except Exception as e:      # noqa: BLE001 — a sender-thread bug
                # must not leave the sink half-alive: a dead thread with
                # failed=False would let backpressured append_columns spin
                # forever under the tracer's fold lock
                self.send_errors += 1
                self.last_error = e
                self._fail()
                return
        self._cur_sock = None
        if f is not None:
            try:
                f.close()
                sock.close()
            except OSError:
                pass
        with self._lock:
            self._drained.notify_all()

    def _fail(self) -> None:
        with self._lock:
            self.failed = True
            self._pending = 0
            self._q.clear()
            self._not_full.notify_all()
            self._drained.notify_all()


def attach_remote(session, addr: tuple[str, int], *, host_id: str | None = None,
                  **kw) -> RemoteSink:
    """Wire a live session's drain output to an :class:`IngestServer`.

    The sink is appended to the tracer's ``sinks`` (every drained chunk is
    forwarded after it lands in the local store) and started.  Register all
    workers *before* attaching, so the HELLO worker table is complete.
    Returns the sink; call ``sink.close()`` after ``session.close()`` to
    flush and say BYE.

    ``host_id`` must be unique per logical producer (the server treats a
    repeated id as the same host reconnecting and retires its previous
    stream); the default is collision-proof.

    ``journal=path`` makes the sink durable (see :class:`RemoteSink`):
    attach it BEFORE the workload interns tags, so a resumed journal can
    seed the session's still-empty registries, and pass a stable
    ``host_id`` so the server folds both incarnations as one host.
    """
    tracer = session._live()
    sink = RemoteSink(
        addr,
        host_id or f"{socket.gethostname()}:{uuid.uuid4().hex[:10]}",
        num_workers=lambda: tracer.total_count,
        worker_names=lambda: tracer.worker_names(),
        tags=tracer.tags, stacks=tracer.stacks, clock=tracer.clock,
        **kw)
    sink.start()
    tracer.sinks.append(sink)
    return sink


@register_exporter("remote", capabilities={"subscription", "push", "live",
                                           "fleet"})
def _export_remote(rep, *, session=None, addr=None, **kw):
    """``session.export("remote", addr=(host, port))`` — subscription
    exporter: attaches a :class:`RemoteSink` and returns it (no report is
    consumed)."""
    if session is None or addr is None:
        raise ValueError("remote exporter needs session= and addr=")
    return attach_remote(session, addr, **kw)


# ---------------------------------------------------------------------------
# consumer: IngestServer
# ---------------------------------------------------------------------------

class _RefuseChunk(Exception):
    """Internal: a chunk could not be journaled (disk full) — the server
    refuses it WITHOUT advancing the dedup floor and drops the
    connection, so the producer's reconnect replay re-delivers it once
    the disk recovers.  Not a protocol error."""


class _HostState:
    """Server-side per-host bookkeeping (maps live on the HostStream)."""

    def __init__(self, stream: HostStream, instance: str):
        self.stream = stream
        self.instance = instance        # guarded-by: self.lock
        self.epoch = 0                  # guarded-by: self.lock
        self.next_seq = 0               # guarded-by: self.lock
        # BYE bookkeeping lives under the SERVER lock (wait_idle reads it
        # through the _idle condition, which wraps IngestServer._lock)
        self.rows_declared: int | None = None   # guarded-by: IngestServer._lock
        self.got_bye = False                    # guarded-by: IngestServer._lock
        self.open_conns = 0             # loop-thread-owned
        self.last_activity = time.monotonic()   # any frame from this host
        self.codec = wire.RAW           # guarded-by: self.lock
        # fleet_dir durability: per-host journal + resume meta
        self.journal: SpillStore | None = None  # guarded-by: self.lock
        self.meta_path: str | None = None       # guarded-by: self.lock
        self.tag_entries: list = []     # guarded-by: self.lock
        self.stack_entries: list = []   # guarded-by: self.lock
        self.meta_sizes = (-1, -1)      # guarded-by: self.lock
        self.pending_backfill = False   # guarded-by: self.lock
        # serializes frame handling across overlapping connections of the
        # same host (an old handler may still drain its socket while the
        # reconnect's handler is live): epoch/seq check-and-commit and the
        # stream push must be one atomic step or a retransmit can fold
        # twice / out of order
        self.lock = threading.Lock()


class _Conn:
    """One producer connection's event-loop state (owned by the loop
    thread; no lock)."""

    __slots__ = ("sock", "rbuf", "wbuf", "st", "last_rx", "paused",
                 "closed", "mask")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.rbuf = bytearray()
        self.wbuf = bytearray()
        self.st: _HostState | None = None   # set by HELLO
        self.last_rx = time.monotonic()
        self.paused = False     # read interest shed (flow control)
        self.closed = False
        self.mask = selectors.EVENT_READ

    def fileno(self) -> int:
        return self.sock.fileno()


class IngestServer:
    """Event-loop ingest endpoint: N producer connections → one
    FleetSource, served by ONE selector thread (the thread-per-connection
    model stopped scaling past a few dozen producers, and its fixed 30s
    blocking reads let a silently-dead producer pin the merge watermark
    for that long).

    ::

        server = IngestServer()            # binds 127.0.0.1:<ephemeral>
        server.start()
        sess = ProfileSession(server.source, n_min=2.0)
        sess.start()
        ...                                 # RemoteSinks connect & stream
        server.wait_idle()                  # every producer said BYE
        rep = sess.result()                 # fleet-wide report
        server.close()

    Liveness & degradation knobs:

    * ``read_deadline`` — a connection that delivers NO bytes for this
      long is closed (``deadline_closed``).  v3 producers heartbeat while
      idle, so only dead peers trip it.
    * ``idle_release`` — a host with no frame activity for this long is
      exempted from the merge watermark (``idle_released``;
      ``source.stats()["idle_hosts"]``) so it cannot stall every healthy
      host's emission; data arriving later re-arms gating (and clamps,
      like any late joiner).
    * ``max_pending_rows`` — per-host merge-buffer budget.  Journaled
      hosts (``fleet_dir=``) shed their OLDEST buffered chunks over
      budget (``shed_chunks``/``shed_rows`` — recoverable offline via
      ``from_fleet_dir``, so overload degrades the live report, never
      history); non-journaled hosts are read-paused instead (lossless
      TCP backpressure back to the producer).
    """

    def __init__(self, addr: tuple[str, int] = ("127.0.0.1", 0), *,
                 source: FleetSource | None = None, tags=None, stacks=None,
                 chunk_events: int = 1 << 16, backlog: int = 16,
                 clock=time.time_ns, fleet_dir: str | None = None,
                 fleet_fsync: bool = False,
                 fleet_rotate_bytes: int | None = None,
                 read_deadline: float | None = 30.0,
                 idle_release: float | None = 30.0,
                 max_pending_rows: int | None = None,
                 fault_plan=None,
                 compression: str | None = wire.ZLIB):
        self.source = source if source is not None else FleetSource(
            tags=tags, stacks=stacks, chunk_events=chunk_events)
        self.clock = clock
        self.read_deadline = (None if read_deadline is None
                              else float(read_deadline))
        self.idle_release = (None if idle_release is None
                             else float(idle_release))
        self.max_pending_rows = (None if max_pending_rows is None
                                 else max(int(max_pending_rows), 1))
        self.fleet_rotate_bytes = fleet_rotate_bytes
        self.fault_plan = fault_plan
        # durable per-host stores: journal + meta sidecar per host under
        # this directory; a restarted server restores dedup floors and
        # backfills reconnecting hosts' history from them
        self.fleet_dir = str(fleet_dir) if fleet_dir else None
        self.fleet_fsync = bool(fleet_fsync)    # fsync per journaled chunk
        if self.fleet_dir:
            os.makedirs(self.fleet_dir, exist_ok=True)
        self._journal_names: dict[str, str] = {}
        # preferred payload codec (None => raw); the handshake can only
        # ever select a codec the producer offered
        self.compression = compression
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(tuple(addr))
        self._sock.listen(backlog)
        self._sock.setblocking(False)
        self.address: tuple[str, int] = self._sock.getsockname()[:2]
        self._loop_thread: threading.Thread | None = None
        self._sel: selectors.BaseSelector | None = None
        self._wake_r: socket.socket | None = None
        self._wake_w: socket.socket | None = None
        self._conns: set[_Conn] = set()     # loop-thread-owned
        self._conn_socks: set[socket.socket] = set()    # guarded-by: self._lock
        self._hosts: dict[str, _HostState] = {}         # guarded-by: self._lock
        self._lock = threading.Lock()
        # leaf lock for bare counters: safe to take under st.lock (taking
        # self._lock there would ABBA-deadlock with _register_host, which
        # holds self._lock and then takes st.lock)
        self._stats_lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._open_conns = 0                # guarded-by: self._lock
        self._stopped = threading.Event()   # stop accepting
        self._shutdown = threading.Event()  # stop the loop entirely
        # counters
        self.connections = 0                # guarded-by: self._lock
        self.stale_chunks = 0               # guarded-by: self._stats_lock
        self.duplicate_chunks = 0           # guarded-by: self._stats_lock
        self.lost_chunks = 0                # guarded-by: self._stats_lock
        self.bad_rows = 0                   # guarded-by: self._stats_lock
        self.proto_errors = 0               # guarded-by: self._stats_lock
        self.worker_growth_rejected = 0     # guarded-by: self._lock
        self.backfilled_chunks = 0          # guarded-by: self._stats_lock
        self.backfilled_rows = 0            # guarded-by: self._stats_lock
        self.deadline_closed = 0            # guarded-by: self._stats_lock
        self.idle_released = 0              # guarded-by: self._stats_lock
        self.shed_chunks = 0                # guarded-by: self._stats_lock
        self.shed_rows = 0                  # guarded-by: self._stats_lock
        self.journal_errors = 0             # guarded-by: self._stats_lock
        self.heartbeats = 0                 # guarded-by: self._stats_lock

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "IngestServer":
        if self._loop_thread is None:
            self.source.accepting = True
            self._sel = selectors.DefaultSelector()
            self._wake_r, self._wake_w = socket.socketpair()
            self._wake_r.setblocking(False)
            self._wake_w.setblocking(False)
            self._sel.register(self._sock, selectors.EVENT_READ, "accept")
            self._sel.register(self._wake_r, selectors.EVENT_READ, "wake")
            self._loop_thread = threading.Thread(
                target=self._loop, daemon=True, name="gapp-ingest")
            self._loop_thread.start()
        return self

    def _wake(self) -> None:
        w = self._wake_w
        if w is not None:
            try:
                w.send(b"x")
            except OSError:
                pass

    def __enter__(self) -> "IngestServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def stop(self) -> None:
        """Stop accepting; existing connections keep draining.  The fleet
        chunk stream can then end once every host finished."""
        self._stopped.set()
        self._wake()
        self.source.accepting = False
        self.source.notify()

    def close(self) -> None:
        self.stop()
        self._shutdown.set()
        self._wake()
        t = self._loop_thread
        if t is not None:
            t.join(timeout=5.0)
            self._loop_thread = None
        try:
            self._sock.close()
        except OSError:
            pass
        # sever any socket the loop left open — ABORTIVELY (SO_LINGER 0
        # makes close send RST, never FIN).  A graceful shutdown here
        # would be a lie: the loop is gone and anything still buffered in
        # these sockets (or parked unparsed in a conn's rbuf) was
        # discarded unread, but a FIN reads as "everything before it was
        # consumed" — it would pass the sinks' BYE delivery barrier and
        # turn a recoverable server death into silent loss.  The RST
        # tells producers delivery is uncertain; they reconnect and
        # replay their unacked journal tail.
        with self._lock:
            socks = list(self._conn_socks)
        for c in socks:
            try:
                c.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                             struct.pack("ii", 1, 0))
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        if self._sel is not None:
            try:
                self._sel.close()
            except OSError:
                pass
            self._sel = None
        for w in (self._wake_r, self._wake_w):
            if w is not None:
                try:
                    w.close()
                except OSError:
                    pass
        self._wake_r = self._wake_w = None
        with self._lock:
            hosts = list(self._hosts.values())
        for st in hosts:        # seal the durable per-host stores
            with st.lock:
                if st.journal is not None:
                    st.journal.close()
                    if st.journal.blocks == 0 and st.stream.rows_in == 0:
                        # a host that handshook but never delivered a
                        # chunk must not leak an empty journal + meta
                        # (from_fleet_dir would replay a ghost host)
                        for p in (st.journal.path, st.meta_path):
                            if p:
                                try:
                                    os.remove(p)
                                except OSError:
                                    pass
                        st.journal = None
                    else:
                        self._write_host_meta(st)
        self.source.notify()

    def finish_host(self, host_id: str) -> bool:
        """Operator override: retire a host that died without BYE (its
        unfinished stream otherwise pins the merge watermark and healthy
        hosts' chunks buffer until ``request_stop``)."""
        with self._lock:
            st = self._hosts.get(host_id)
        if st is None:
            return False
        # finish() flips merge-gating state the gather loop reads under
        # the fleet condition: an unlocked flip can be missed by a
        # concurrent _gather_locked and stall the watermark a full poll
        with self.source.cond:
            st.stream.finish()
            self.source.cond.notify_all()
        return True

    def wait_idle(self, timeout: float | None = 10.0) -> bool:
        """Block until every host that ever connected said BYE and no
        connection remains open.  True on success."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while True:
                done = (self._open_conns == 0 and self._hosts
                        and all(h.got_bye for h in self._hosts.values()))
                if done:
                    return True
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self._idle.wait(0.05 if rem is None else min(rem, 0.05))

    def stats(self) -> dict:
        with self._lock:
            out = {
                "address": list(self.address),
                "connections": self.connections,
                "open_connections": self._open_conns,
                "hosts": len(self._hosts),
                "stale_chunks": self.stale_chunks,
                "duplicate_chunks": self.duplicate_chunks,
                "lost_chunks": self.lost_chunks,
                "bad_rows": self.bad_rows,
                "proto_errors": self.proto_errors,
                "backfilled_chunks": self.backfilled_chunks,
                "backfilled_rows": self.backfilled_rows,
                "deadline_closed": self.deadline_closed,
                "idle_released": self.idle_released,
                "shed_chunks": self.shed_chunks,
                "shed_rows": self.shed_rows,
                "journal_errors": self.journal_errors,
                "heartbeats": self.heartbeats,
                "fleet_dir": self.fleet_dir,
            }
        out.update(self.source.stats())
        return out

    def host_journals(self) -> dict[str, SpillStore]:
        """Snapshot of the durable per-host journals (``fleet_dir=`` mode;
        empty otherwise) — the hook a retention driver or metrics scrape
        walks.  Locks are taken per entry and released before return, so
        callers may do slow work (pruning) against the returned stores
        without holding any server lock."""
        with self._lock:
            hosts = list(self._hosts.items())
        out: dict[str, SpillStore] = {}
        for host_id, st in hosts:
            with st.lock:
                if st.journal is not None:
                    out[host_id] = st.journal
        return out

    # -- event loop ----------------------------------------------------------
    def _loop(self) -> None:  # lint: event-loop
        """The selector loop: accepts, reads, frame dispatch, writes, and
        the deadline/idle/flow-control sweep — one thread for the whole
        fleet."""
        listener_on = True
        while not self._shutdown.is_set():
            if self._stopped.is_set() and listener_on:
                try:
                    self._sel.unregister(self._sock)
                except (KeyError, ValueError):
                    pass
                listener_on = False
            try:
                events = self._sel.select(0.05)
            except OSError:
                return
            for key, mask in events:
                data = key.data
                if data == "accept":
                    self._do_accept()
                elif data == "wake":
                    try:
                        while self._wake_r.recv(4096):
                            pass
                    except (BlockingIOError, OSError):
                        pass
                else:
                    conn = data
                    if mask & selectors.EVENT_WRITE and not conn.closed:
                        self._flush_wbuf(conn)
                    if mask & selectors.EVENT_READ and not conn.closed:
                        self._do_read(conn)
            self._sweep(time.monotonic())

    def _do_accept(self) -> None:
        while True:
            try:
                s, _ = self._sock.accept()
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                return
            s.setblocking(False)
            conn = _Conn(s)
            self._conns.add(conn)
            self._sel.register(s, selectors.EVENT_READ, conn)
            with self._idle:
                self.connections += 1
                self._open_conns += 1
                self._conn_socks.add(s)

    def _do_read(self, conn: _Conn) -> None:
        try:
            data = conn.sock.recv(1 << 18)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._close_conn(conn)
            return
        if not data:
            self._close_conn(conn)      # EOF (a torn rbuf tail dies with it)
            return
        conn.rbuf += data
        conn.last_rx = time.monotonic()
        if conn.st is not None:
            conn.st.last_activity = conn.last_rx
        self._parse_rbuf(conn)

    def _parse_rbuf(self, conn: _Conn) -> None:
        """Dispatch every complete frame buffered on ``conn`` (until a
        flow-control pause or an error closes it).  Also called when a
        paused connection resumes: frames that arrived before the pause
        must not wait for new bytes."""
        try:
            while not conn.closed and not conn.paused:
                got = wire.frame_from_buffer(conn.rbuf)
                if got is None:
                    break
                kind, payload, consumed = got
                del conn.rbuf[:consumed]
                self._dispatch(conn, kind, payload)
        except (wire.WireError, KeyError, ValueError):
            with self._stats_lock:
                self.proto_errors += 1
            self._close_conn(conn)
        except _RefuseChunk:
            self._close_conn(conn)
        except OSError:
            self._close_conn(conn)

    def _dispatch(self, conn: _Conn, kind: int, payload: bytes) -> None:
        if conn.st is None:
            if kind != wire.HELLO:
                raise wire.WireError("expected HELLO")
            hello = wire.decode_hello(payload)
            st = self._register_host(hello)
            conn.st = st
            st.open_conns += 1
            st.last_activity = time.monotonic()
            with st.lock:
                ack, codec = st.next_seq, st.codec
                tags_seen = len(st.tag_entries)
                stacks_seen = len(st.stack_entries)
            # reply stamped with the PEER's schema version: a v1 decoder
            # rejects v2/v3-stamped frames (the extra keys are harmless)
            self._send_conn(conn, wire.encode_welcome(
                st.stream.index, st.epoch, st.stream.clock_offset_ns,
                ack_seq=ack, codec=codec, tags_seen=tags_seen,
                stacks_seen=stacks_seen,
                version=int(hello["wire_version"])))
            return
        st = conn.st
        if kind == wire.CHUNK:
            self._on_chunk(conn, st, wire.decode_chunk(payload))
        elif kind == wire.TAGS:
            self._on_tags(st, wire.decode_json(payload))
        elif kind == wire.STACKS:
            self._on_stacks(st, wire.decode_json(payload))
        elif kind == wire.HEARTBEAT:
            self._on_heartbeat(st, wire.decode_json(payload))
        elif kind == wire.BYE:
            bye = wire.decode_json(payload)
            with self._lock:
                st.rows_declared = int(bye.get("rows_sent", -1))
                st.got_bye = True
            with self.source.cond:
                st.stream.finish()
                self.source.cond.notify_all()
            self._close_conn(conn)
        else:
            raise wire.WireError(
                f"unexpected {wire.KIND_NAMES.get(kind, kind)}")

    def _send_conn(self, conn: _Conn, data: bytes) -> None:
        conn.wbuf += data
        self._flush_wbuf(conn)

    def _flush_wbuf(self, conn: _Conn) -> None:
        if conn.wbuf and not conn.closed:
            try:
                n = conn.sock.send(conn.wbuf)
                del conn.wbuf[:n]
            except (BlockingIOError, InterruptedError):
                pass
            except OSError:
                self._close_conn(conn)
                return
        self._update_interest(conn)

    def _update_interest(self, conn: _Conn) -> None:
        if conn.closed:
            return
        mask = 0
        if not conn.paused:
            mask |= selectors.EVENT_READ
        if conn.wbuf:
            mask |= selectors.EVENT_WRITE
        if mask == conn.mask:
            return
        try:
            if conn.mask == 0 and mask:
                self._sel.register(conn.sock, mask, conn)
            elif mask == 0:
                self._sel.unregister(conn.sock)
            else:
                self._sel.modify(conn.sock, mask, conn)
        except (KeyError, ValueError, OSError):
            self._close_conn(conn)
            return
        conn.mask = mask

    def _close_conn(self, conn: _Conn) -> None:
        if conn.closed:
            return
        conn.closed = True
        if conn.mask:
            try:
                self._sel.unregister(conn.sock)
            except (KeyError, ValueError, OSError):
                pass
            conn.mask = 0
        try:
            conn.sock.close()
        except OSError:
            pass
        if conn.st is not None:
            conn.st.open_conns -= 1
        self._conns.discard(conn)
        with self._idle:
            self._open_conns -= 1
            self._conn_socks.discard(conn.sock)
            self._idle.notify_all()
        self.source.notify()

    def _sweep(self, now: float) -> None:
        """Per-iteration housekeeping: read deadlines, flow-control
        resume, idle-host watermark release."""
        for conn in list(self._conns):
            if conn.closed:
                continue
            if (self.read_deadline is not None
                    and now - conn.last_rx > self.read_deadline):
                # a peer that writes NOTHING for the whole deadline is
                # dead or partitioned (v3 producers heartbeat while
                # idle): reclaim the fd; a live peer reconnects
                with self._stats_lock:
                    self.deadline_closed += 1
                self._close_conn(conn)
                continue
            if conn.paused and conn.st is not None \
                    and self.max_pending_rows is not None \
                    and (conn.st.stream.buffered_rows
                         <= self.max_pending_rows // 2):
                conn.paused = False      # drained below low-water: resume
                self._parse_rbuf(conn)   # frames buffered during the pause
                self._update_interest(conn)
        if self.idle_release is None:
            return
        with self._lock:
            hosts = list(self._hosts.values())
        for st in hosts:
            if st.stream.finished or st.stream.idle_exempt:
                continue
            if now - st.last_activity > self.idle_release:
                with self.source.cond:
                    st.stream.idle_exempt = True
                    self.source.cond.notify_all()
                with self._stats_lock:
                    self.idle_released += 1

    def _register_host(self, hello: dict) -> _HostState:
        host_id = str(hello["host_id"])
        instance = str(hello.get("instance", ""))
        declared = hello.get("clock_offset_ns")
        offset = (int(declared) if declared is not None
                  else int(self.clock()) - int(hello["t_client_ns"]))
        codec = (wire.negotiate_codec(hello.get("codecs"),
                                      (self.compression,))
                 if self.compression else wire.RAW)
        with self._lock:
            st = self._hosts.get(host_id)
            if st is None:
                stream = self.source.add_host(
                    host_id, int(hello["num_workers"]),
                    hello.get("worker_names"), clock_offset_ns=offset)
                st = self._hosts[host_id] = _HostState(stream, instance)
                if self.fleet_dir:
                    self._open_host_journal(st, instance)
            else:                       # reconnect: new clock-sync epoch
                with st.lock:
                    st.epoch += 1
                    st.stream.clock_offset_ns = offset
                    st.got_bye = False
                    st.stream.finished = False
                    if instance != st.instance:
                        # producer RESTART, not a reconnect: a fresh
                        # capture numbers its chunks from 0 again — reset
                        # the dedup floor or every new chunk would drop as
                        # a retransmit.  (A journal-resumed restart repeats
                        # the old instance and lands in the branch above.)
                        st.instance = instance
                        st.next_seq = 0
                        if st.journal is not None:
                            # rotate the durable store: the old capture's
                            # journal must not pollute the new capture
                            st.journal.close()
                            st.journal = self._wrap_journal(
                                st.stream.host_id,
                                SpillStore(st.journal.path,
                                           rotate_bytes=self.fleet_rotate_bytes))
                            st.tag_entries = []
                            st.stack_entries = []
                # workers registered since the first HELLO: grow the host's
                # id space when it still owns the tail of the fleet range
                # (growth of an interior host would collide with the next
                # host's offsets — counted, rows filtered as bad_rows)
                nw = int(hello["num_workers"])
                if nw > st.stream.num_workers and not \
                        self.source.try_grow_host(
                            st.stream, nw, hello.get("worker_names")):
                    self.worker_growth_rejected += 1
            with st.lock:
                st.codec = codec
                if st.meta_path is not None:
                    self._write_host_meta(st)   # fresh index/offset/workers
        if st.pending_backfill:
            # replay the journaled history OUTSIDE the server lock (it can
            # be a long disk read — other hosts' handshakes, stats() and
            # close() must not stall behind it); st.lock keeps the host's
            # own frame handlers out until the history is fully pushed, so
            # within-host stream order is preserved
            with st.lock:
                if st.pending_backfill:
                    st.pending_backfill = False
                    self._backfill(st)
        return st

    # -- fleet_dir durability ------------------------------------------------
    def _journal_base(self, host_id: str) -> str:
        safe = re.sub(r"[^A-Za-z0-9._-]+", "_", host_id).strip("._") or "host"
        owner = self._journal_names.get(safe)
        if owner is None:
            # across a server restart the in-memory map is empty: the
            # on-disk meta records which host_id owns this filename
            meta = load_json(os.path.join(self.fleet_dir,
                                           safe + ".meta.json"))
            if meta:
                owner = meta.get("host_id")
        if owner is not None and owner != host_id:
            # two distinct ids sanitize to the same filename: disambiguate
            # (deterministic, so the same host finds its journal again)
            safe += "-" + hashlib.sha1(host_id.encode()).hexdigest()[:8]
        self._journal_names[safe] = host_id
        return safe

    # lint: disable=guarded-by(first-HELLO construction: the caller holds IngestServer._lock for the whole branch, so no frame handler can reach this _HostState through self._hosts yet)
    def _open_host_journal(self, st: _HostState, instance: str) -> None:
        """First HELLO of a host on this server: open its durable store.
        When a meta sidecar from a previous server run matches the
        producer's capture instance, this server RESUMED: restore the
        dedup floor (the WELCOME ack_seq survives the restart), rebuild
        the registry maps from the persisted entries, and backfill the
        merge with the journaled history — the host reconnects *with*
        history instead of starting a hole."""
        base = self._journal_base(st.stream.host_id)
        jpath = os.path.join(self.fleet_dir, base + ".spill")
        st.meta_path = os.path.join(self.fleet_dir, base + ".meta.json")
        meta = load_json(st.meta_path)
        if (meta and instance and meta.get("instance") == instance
                and os.path.exists(jpath)):
            st.journal = SpillStore.open_append(
                jpath, rotate_bytes=self.fleet_rotate_bytes)
            # block index == accepted seq (every accepted chunk journals
            # exactly one block; accepted seq GAPS journal empty fillers),
            # so the complete-block count IS the dedup floor — no reliance
            # on the meta's possibly-stale next_seq
            st.next_seq = st.journal.blocks
            self._restore_maps(st, meta)
            st.pending_backfill = st.journal.blocks > 0
        else:
            # fresh capture: truncate
            st.journal = SpillStore(jpath,
                                    rotate_bytes=self.fleet_rotate_bytes)
        st.journal = self._wrap_journal(st.stream.host_id, st.journal)

    def _wrap_journal(self, host_id: str, store):
        if self.fault_plan is not None:
            return self.fault_plan.wrap_journal(host_id, store)
        return store

    def _restore_maps(self, st: _HostState, meta: dict) -> None:
        for i, ent in enumerate(meta.get("tags") or []):
            if ent is not None:
                _set_entry(st.tag_entries, i, [str(ent[0]), str(ent[1])])
        for i, path in enumerate(meta.get("stacks") or []):
            if path is not None:
                _set_entry(st.stack_entries, i, [int(t) for t in path])
        restore_host_maps(st.stream, self.source.tags, self.source.stacks,
                          st.tag_entries, st.stack_entries)

    def _backfill(self, st: _HostState) -> None:
        """Feed a resumed host's journaled history into the merge (the
        maps are already restored, so push normalizes it exactly like the
        live chunks it preceded)."""
        for cols in st.journal.iter_block_columns():
            if len(cols[0]) == 0:
                continue
            with self.source.cond:
                st.stream.push(*cols)
                self.source.cond.notify_all()
            with self._stats_lock:
                self.backfilled_chunks += 1
                self.backfilled_rows += len(cols[0])

    def _write_host_meta(self, st: _HostState) -> None:  # guarded-by: _HostState.lock
        if st.meta_path is None:
            return
        st.meta_sizes = (len(st.tag_entries), len(st.stack_entries))
        s = st.stream
        write_json_atomic(st.meta_path, {
            "host_id": s.host_id, "instance": st.instance,
            "host_index": s.index, "next_seq": st.next_seq,
            "num_workers": s.num_workers, "worker_names": s.worker_names,
            "clock_offset_ns": s.clock_offset_ns,
            "journal": (os.path.basename(st.journal.path)
                        if st.journal is not None else None),
            "tags": st.tag_entries, "stacks": st.stack_entries,
        })

    # -- frame handlers (serialized per host via st.lock) --------------------
    def _on_tags(self, st: _HostState, obj: dict) -> None:
        stream = st.stream
        with st.lock:
            for tid, name, loc in obj["entries"]:
                stream.tag_map = _grow_map(stream.tag_map, int(tid))
                stream.tag_map[int(tid)] = self.source.tags.intern(
                    str(name), str(loc))
                _set_entry(st.tag_entries, int(tid), [str(name), str(loc)])
            # persist only real growth (registry rewrites are full-file;
            # a delta frame that interned nothing new must not pay one)
            if len(st.tag_entries) != st.meta_sizes[0]:
                self._write_host_meta(st)

    def _on_stacks(self, st: _HostState, obj: dict) -> None:
        stream = st.stream
        with st.lock:
            for sid, path in obj["entries"]:
                fleet_path = []
                for t in path:
                    stream.tag_map = _grow_map(stream.tag_map, int(t))
                    fleet_path.append(int(stream.tag_map[int(t)]))
                stream.stack_map = _grow_map(stream.stack_map, int(sid))
                stream.stack_map[int(sid)] = self.source.stacks.intern(
                    tuple(fleet_path))
                _set_entry(st.stack_entries, int(sid),
                           [int(t) for t in path])
            if len(st.stack_entries) != st.meta_sizes[1]:
                self._write_host_meta(st)

    def _on_heartbeat(self, st: _HostState, obj: dict) -> None:
        """HEARTBEAT (wire v3): "I am alive; everything up to t_ns has
        been sent."  Advances the host's merge watermark so an idle-but-
        healthy producer never pins the fleet fold, and marks a host that
        has NO data yet (``t_ns`` null) watermark-exempt — alive-but-
        dataless must not stall the merge either (its first real chunk
        re-arms gating)."""
        with self._stats_lock:
            self.heartbeats += 1
        t_ns = obj.get("t_ns")
        with self.source.cond:
            if t_ns is not None:
                st.stream.advance_watermark(int(t_ns))
            elif st.stream.last_seen_ns is None:
                st.stream.idle_exempt = True
            self.source.cond.notify_all()

    def _on_chunk(self, conn: _Conn, st: _HostState,
                  chunk: wire.ChunkFrame) -> None:
        with st.lock:
            # epoch/seq check + commit + push are one atomic step: an old
            # connection's handler racing a reconnect's handler must not
            # fold a retransmit twice or interleave pushes out of order
            if chunk.epoch != st.epoch:
                with self._stats_lock:
                    self.stale_chunks += 1
                return
            if chunk.seq < st.next_seq:  # retransmit of a delivered chunk
                with self._stats_lock:
                    self.duplicate_chunks += 1
                return
            gap = int(chunk.seq - st.next_seq)
            w = chunk.workers
            bad = (w < 0) | (w >= st.stream.num_workers)
            nbad = int(bad.sum())
            if nbad:                   # worker registered after HELLO
                keep = ~bad
                cols = tuple(c[keep] for c in chunk.columns)
            else:
                cols = chunk.columns
            if st.journal is not None:
                # durable BEFORE commit/push: block index == seq is the
                # resume-floor invariant, so every accepted seq must
                # journal exactly one block (even an all-filtered one),
                # and an accepted GAP journals empty filler blocks — a
                # restarted server's floor (journal.blocks) then never
                # re-accepts a seq it already folded.  Raw host-local
                # columns — normalization replays at read time (backfill
                # push / from_fleet_dir), like the live path.  The filler
                # loop keys on the journal's ACTUAL block count, so a
                # disk-full retry never double-appends fillers.
                empty = [np.zeros(0, dt) for dt in wire.COL_DTYPES]
                try:
                    while st.journal.blocks < chunk.seq:
                        st.journal.append_block(*empty)
                    st.journal.append_block(*cols, sync=self.fleet_fsync)
                except OSError as e:
                    # journal full: REFUSE the chunk (close the conn
                    # without committing) — the floor is unchanged, so
                    # the producer's reconnect replay re-delivers it once
                    # the disk recovers.  Accepting it un-journaled would
                    # silently break the blocks == seq invariant.
                    with self._stats_lock:
                        self.journal_errors += 1
                    raise _RefuseChunk() from e
            if gap:
                # a gap means chunks committed producer-side (flush reached
                # the kernel) never arrived — e.g. lost in a reset before
                # the server read them.  A journaling producer recovers
                # them on its next reconnect (ack replay); otherwise count
                # them loudly: delivery is at-most-once with loss
                # DETECTION, not recovery (the sink only retains the one
                # in-flight chunk)
                with self._stats_lock:
                    self.lost_chunks += gap
            if nbad:
                with self._stats_lock:
                    self.bad_rows += nbad
            st.next_seq = chunk.seq + 1
            if len(cols[0]) == 0:
                return
            with self.source.cond:
                st.stream.push(*cols)
                if (self.max_pending_rows is not None
                        and st.stream.buffered_rows > self.max_pending_rows):
                    if st.journal is not None:
                        # overload, durable host: shed the OLDEST buffered
                        # parts — they are journaled, so from_fleet_dir
                        # recovers them offline; the live report counts
                        # them as shed, never silently drops them
                        chunks, rows = st.stream.shed_oldest(
                            self.max_pending_rows)
                        if chunks:
                            self.source.shed_chunks += chunks
                            self.source.shed_rows += rows
                            with self._stats_lock:
                                self.shed_chunks += chunks
                                self.shed_rows += rows
                    else:
                        # no journal → shedding would LOSE data: apply
                        # backpressure instead (stop reading this conn
                        # until the merge drains below the low-water mark)
                        conn.paused = True
                self.source.cond.notify_all()
        if conn.paused:
            self._update_interest(conn)
