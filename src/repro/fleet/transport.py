"""Socket transport: stream drained chunks off-host, ingest N producers.

Producer side — :class:`RemoteSink` attaches to a live
:class:`~repro.core.session.ProfileSession` (``attach_remote(session,
addr)`` or ``session.export("remote", addr=...)``) as a tracer *sink*:
every drained+folded chunk the tracer appends to its store is also handed
to the sink, which frames it (:mod:`repro.fleet.wire`) and ships it from a
background sender thread.  The capture hot path never blocks on the
network: the hand-off is a bounded queue, and only when the queue is full
does the *drain* (not the probes) wait — backpressure — or, with
``drop_when_full=True``, the chunk is dropped and counted like a full BPF
ring.  The sender reconnects with backoff on socket errors; a reconnect
re-handshakes, bumping the clock-sync epoch, and never loses the chunk it
was holding.

Consumer side — :class:`IngestServer` accepts any number of producer
connections, performs the HELLO/WELCOME handshake (allocating the host
index and the clock offset: declared by the producer, or measured as
``t_server − t_client``), remaps host-local tag/stack ids into the
fleet-wide registries via the incremental TAGS/STACKS sync frames, and
pushes normalized chunks into its :class:`~repro.fleet.aggregate.FleetSource`
hub — which a :class:`~repro.core.session.ProfileSession` drains like any
other source.  One server + one session = a fleet-wide
:class:`~repro.core.detector.BottleneckReport` with host provenance.
"""
from __future__ import annotations

import socket
import threading
import time
import uuid
from collections import deque

import numpy as np

from repro.core.exporters import register_exporter
from repro.fleet import wire
from repro.fleet.aggregate import FleetSource, HostStream


def _grow_map(arr: np.ndarray | None, idx: int) -> np.ndarray:
    """Ensure ``arr[idx]`` exists (new cells are identity-mapped)."""
    if arr is None:
        arr = np.arange(0, dtype=np.int32)
    if idx >= arr.shape[0]:
        new = np.arange(max(idx + 1, 2 * arr.shape[0] + 1), dtype=np.int32)
        new[:arr.shape[0]] = arr
        arr = new
    return arr


# ---------------------------------------------------------------------------
# producer: RemoteSink
# ---------------------------------------------------------------------------

class RemoteSink:
    """Stream a session's drained chunks to an :class:`IngestServer`.

    Attach via :func:`attach_remote` / ``session.export("remote", ...)``;
    or hand-construct and append to ``tracer.sinks``.  ``clock_offset_ns``
    is the *declared* offset of this host's capture clock to the fleet
    clock; the default ``None`` lets the server measure one from the
    handshake — capture clocks (``perf_counter_ns``) have unrelated bases
    across machines, so declaring 0 is only correct for co-located
    producers sharing a clock (tests/benchmarks pass it explicitly).
    """

    _CLOSE = object()

    def __init__(self, addr: tuple[str, int], host_id: str, *,
                 num_workers=0, worker_names=None, tags=None, stacks=None,
                 clock=time.perf_counter_ns,
                 clock_offset_ns: int | None = None,
                 max_buffer_chunks: int = 256, drop_when_full: bool = False,
                 reconnect_delay: float = 0.05, max_reconnects: int = 64,
                 connect_timeout: float = 5.0):
        self.addr = tuple(addr)
        self.host_id = str(host_id)
        self._num_workers = num_workers          # int or () -> int
        self._worker_names = worker_names        # list or () -> list
        self.tags = tags
        self.stacks = stacks
        self.clock = clock
        self.clock_offset_ns = clock_offset_ns
        self.drop_when_full = drop_when_full
        self.reconnect_delay = float(reconnect_delay)
        self.max_reconnects = int(max_reconnects)
        self.connect_timeout = float(connect_timeout)
        self._q: deque = deque()
        self._q_cap = max(int(max_buffer_chunks), 1)
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._drained = threading.Condition(self._lock)
        self._pending = 0           # chunks enqueued or in-flight
        self._closing = False
        self._thread: threading.Thread | None = None
        self.host_index: int | None = None
        self.epoch: int | None = None
        self._seq = 0               # chunk sequence, NOT reset on reconnect:
        #                             the server dedups retransmits by it
        self.instance = uuid.uuid4().hex    # capture nonce (see wire HELLO)
        self._tags_sent = 0
        self._stacks_sent = 0
        # counters
        self.rows_sent = 0
        self.chunks_sent = 0
        self.dropped_chunks = 0
        self.reconnects = 0
        self.send_errors = 0
        self.last_error: Exception | None = None
        self.failed = False

    # -- store-interface intake (called under the tracer's fold lock) --------
    def append_columns(self, times, workers, deltas, tags, stacks) -> None:
        if len(times) == 0:
            return
        item = tuple(np.asarray(c) for c in
                     (times, workers, deltas, tags, stacks))
        with self._lock:
            if self._closing:
                self.dropped_chunks += 1
                return
            while len(self._q) >= self._q_cap and not self.failed:
                if self.drop_when_full:
                    self.dropped_chunks += 1
                    return
                self._not_full.wait(0.05)       # backpressure on the drain
            if self.failed:
                self.dropped_chunks += 1
                return
            self._q.append(item)
            self._pending += 1
            self._not_empty.notify()

    def __len__(self) -> int:
        return self.rows_sent

    @property
    def nbytes(self) -> int:
        with self._lock:
            return sum(sum(c.nbytes for c in item) for item in self._q
                       if item is not self._CLOSE)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "RemoteSink":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name=f"gapp-sink-{self.host_id}")
            self._thread.start()
        return self

    def spill(self) -> None:
        """Flush barrier (store-interface parity): block until every
        enqueued chunk has been sent (or the sink failed/closed)."""
        self.flush()

    def flush(self, timeout: float | None = 10.0) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._pending > 0 and not self.failed:
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self._drained.wait(0.05 if rem is None else min(rem, 0.05))
            return not self.failed

    def close(self, timeout: float | None = 10.0) -> None:
        """Flush, send BYE, stop the sender."""
        with self._lock:
            if self._closing:
                pass
            else:
                self._closing = True
                self._q.append(self._CLOSE)
                self._not_empty.notify()
        if self._thread is not None:
            self._thread.join(timeout)

    def stats(self) -> dict:
        return {"host_id": self.host_id, "rows_sent": self.rows_sent,
                "chunks_sent": self.chunks_sent,
                "dropped_chunks": self.dropped_chunks,
                "reconnects": self.reconnects,
                "send_errors": self.send_errors, "failed": self.failed}

    # -- sender thread -------------------------------------------------------
    def _resolve(self, v, default):
        if v is None:
            return default
        return v() if callable(v) else v

    def _connect(self):
        sock = socket.create_connection(self.addr,
                                        timeout=self.connect_timeout)
        sock.settimeout(self.connect_timeout)
        f = sock.makefile("rwb")
        nw = int(self._resolve(self._num_workers, 0))
        names = list(self._resolve(self._worker_names,
                                   [f"w{i}" for i in range(nw)]))
        f.write(wire.encode_hello(self.host_id, nw, names,
                                  t_client_ns=int(self.clock()),
                                  clock_offset_ns=self.clock_offset_ns,
                                  instance=self.instance))
        f.flush()
        frame = wire.read_frame(f)
        if frame is None or frame[0] != wire.WELCOME:
            raise wire.WireError("no WELCOME after HELLO")
        w = wire.decode_json(frame[1])
        self.host_index = int(w["host_index"])
        self.epoch = int(w["epoch"])
        return sock, f

    def _sync_registries(self, f) -> tuple[int, int]:
        """Write any registry deltas; returns the (tags, stacks) high-water
        marks to COMMIT only after the whole batch flushes — a frame lost
        to a mid-send failure must be retransmitted after reconnect."""
        tags_n, stacks_n = self._tags_sent, self._stacks_sent
        if self.tags is not None:
            # lock-free read of the live registry: locations is appended
            # *second* under the registry lock, so its length is the safe
            # fully-published high-water mark
            n = min(len(self.tags.names), len(self.tags.locations))
            if n > tags_n:
                f.write(wire.encode_tags(
                    [(i, self.tags.names[i], self.tags.locations[i])
                     for i in range(tags_n, n)]))
                tags_n = n
        if self.stacks is not None:
            n = len(self.stacks.paths)
            if n > stacks_n:
                f.write(wire.encode_stacks(
                    [(i, self.stacks.paths[i])
                     for i in range(stacks_n, n)]))
                stacks_n = n
        return tags_n, stacks_n

    def _run(self) -> None:
        sock = f = None
        item = None
        attempts = 0
        while True:
            try:
                if f is None:       # connect eagerly: handshake ASAP so the
                    #                 server learns this host before data
                    if attempts > 0:
                        time.sleep(min(self.reconnect_delay * attempts, 1.0))
                    sock, f = self._connect()
                    if attempts > 0:
                        self.reconnects += 1
                        # the server keeps the per-host registry maps, but a
                        # fresh server would not: stay incremental (same
                        # server) — a lost server is a failed sink anyway
                    attempts = 0
                if item is None:
                    with self._lock:
                        if not self._q:
                            self._not_empty.wait(0.25)
                        if self._q:
                            item = self._q.popleft()
                            self._not_full.notify_all()
                    if item is None:
                        continue
                if item is self._CLOSE:
                    f.write(wire.encode_bye(self.rows_sent, self.chunks_sent))
                    f.flush()
                    break
                tags_n, stacks_n = self._sync_registries(f)
                f.write(wire.encode_chunk(self.host_index or 0,
                                          wire.MERGED_SHARD, self.epoch or 0,
                                          self._seq, *item))
                f.flush()
                # commit only after the flush: a flush() that raised is
                # retransmitted whole after reconnect — the CHUNK with the
                # SAME seq (server dedups), the registry deltas again
                # (interning is idempotent server-side)
                self._tags_sent, self._stacks_sent = tags_n, stacks_n
                self._seq += 1
                self.rows_sent += len(item[0])
                self.chunks_sent += 1
                with self._lock:
                    self._pending -= 1
                    self._drained.notify_all()
                item = None
            except (OSError, wire.WireError) as e:   # reconnect w/ backoff
                self.send_errors += 1
                self.last_error = e
                if f is not None:
                    try:
                        f.close()
                        sock.close()
                    except OSError:
                        pass
                    f = sock = None
                attempts += 1
                if attempts > self.max_reconnects:
                    self._fail()
                    return
            except Exception as e:      # noqa: BLE001 — a sender-thread bug
                # must not leave the sink half-alive: a dead thread with
                # failed=False would let backpressured append_columns spin
                # forever under the tracer's fold lock
                self.send_errors += 1
                self.last_error = e
                self._fail()
                return
        try:
            f.close()
            sock.close()
        except OSError:
            pass
        with self._lock:
            self._drained.notify_all()

    def _fail(self) -> None:
        with self._lock:
            self.failed = True
            self._pending = 0
            self._q.clear()
            self._not_full.notify_all()
            self._drained.notify_all()


def attach_remote(session, addr: tuple[str, int], *, host_id: str | None = None,
                  **kw) -> RemoteSink:
    """Wire a live session's drain output to an :class:`IngestServer`.

    The sink is appended to the tracer's ``sinks`` (every drained chunk is
    forwarded after it lands in the local store) and started.  Register all
    workers *before* attaching, so the HELLO worker table is complete.
    Returns the sink; call ``sink.close()`` after ``session.close()`` to
    flush and say BYE.

    ``host_id`` must be unique per logical producer (the server treats a
    repeated id as the same host reconnecting and retires its previous
    stream); the default is collision-proof.
    """
    tracer = session._live()
    sink = RemoteSink(
        addr,
        host_id or f"{socket.gethostname()}:{uuid.uuid4().hex[:10]}",
        num_workers=lambda: tracer.total_count,
        worker_names=lambda: tracer.worker_names(),
        tags=tracer.tags, stacks=tracer.stacks, clock=tracer.clock,
        **kw)
    sink.start()
    tracer.sinks.append(sink)
    return sink


@register_exporter("remote", capabilities={"subscription", "push", "live",
                                           "fleet"})
def _export_remote(rep, *, session=None, addr=None, **kw):
    """``session.export("remote", addr=(host, port))`` — subscription
    exporter: attaches a :class:`RemoteSink` and returns it (no report is
    consumed)."""
    if session is None or addr is None:
        raise ValueError("remote exporter needs session= and addr=")
    return attach_remote(session, addr, **kw)


# ---------------------------------------------------------------------------
# consumer: IngestServer
# ---------------------------------------------------------------------------

class _HostState:
    """Server-side per-host bookkeeping (maps live on the HostStream)."""

    def __init__(self, stream: HostStream, instance: str):
        self.stream = stream
        self.instance = instance    # capture nonce; changes on restart
        self.epoch = 0
        self.next_seq = 0           # dedup floor across reconnects
        self.rows_declared: int | None = None
        self.got_bye = False
        # serializes frame handling across overlapping connections of the
        # same host (an old handler may still drain its socket while the
        # reconnect's handler is live): epoch/seq check-and-commit and the
        # stream push must be one atomic step or a retransmit can fold
        # twice / out of order
        self.lock = threading.Lock()


class IngestServer:
    """Threaded ingest endpoint: N producer connections → one FleetSource.

    ::

        server = IngestServer()            # binds 127.0.0.1:<ephemeral>
        server.start()
        sess = ProfileSession(server.source, n_min=2.0)
        sess.start()
        ...                                 # RemoteSinks connect & stream
        server.wait_idle()                  # every producer said BYE
        rep = sess.result()                 # fleet-wide report
        server.close()
    """

    def __init__(self, addr: tuple[str, int] = ("127.0.0.1", 0), *,
                 source: FleetSource | None = None, tags=None, stacks=None,
                 chunk_events: int = 1 << 16, backlog: int = 16,
                 clock=time.time_ns):
        self.source = source if source is not None else FleetSource(
            tags=tags, stacks=stacks, chunk_events=chunk_events)
        self.clock = clock
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(tuple(addr))
        self._sock.listen(backlog)
        self._sock.settimeout(0.1)
        self.address: tuple[str, int] = self._sock.getsockname()[:2]
        self._accept_thread: threading.Thread | None = None
        self._conn_threads: list[threading.Thread] = []
        self._hosts: dict[str, _HostState] = {}
        self._lock = threading.Lock()
        # leaf lock for bare counters: safe to take under st.lock (taking
        # self._lock there would ABBA-deadlock with _register_host, which
        # holds self._lock and then takes st.lock)
        self._stats_lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._open_conns = 0
        self._stopped = threading.Event()
        # counters
        self.connections = 0
        self.stale_chunks = 0
        self.duplicate_chunks = 0
        self.lost_chunks = 0
        self.bad_rows = 0
        self.proto_errors = 0
        self.worker_growth_rejected = 0

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "IngestServer":
        if self._accept_thread is None:
            self.source.accepting = True
            self._accept_thread = threading.Thread(
                target=self._accept_loop, daemon=True, name="gapp-ingest")
            self._accept_thread.start()
        return self

    def __enter__(self) -> "IngestServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def stop(self) -> None:
        """Stop accepting; existing connections drain to EOF.  The fleet
        chunk stream can then end once every host finished."""
        self._stopped.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)
            self._accept_thread = None
        self.source.accepting = False
        self.source.notify()

    def close(self) -> None:
        self.stop()
        try:
            self._sock.close()
        except OSError:
            pass
        for t in list(self._conn_threads):
            t.join(timeout=2.0)
        self.source.notify()

    def finish_host(self, host_id: str) -> bool:
        """Operator override: retire a host that died without BYE (its
        unfinished stream otherwise pins the merge watermark and healthy
        hosts' chunks buffer until ``request_stop``)."""
        with self._lock:
            st = self._hosts.get(host_id)
        if st is None:
            return False
        st.stream.finish()
        self.source.notify()
        return True

    def wait_idle(self, timeout: float | None = 10.0) -> bool:
        """Block until every host that ever connected said BYE and no
        connection remains open.  True on success."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while True:
                done = (self._open_conns == 0 and self._hosts
                        and all(h.got_bye for h in self._hosts.values()))
                if done:
                    return True
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self._idle.wait(0.05 if rem is None else min(rem, 0.05))

    def stats(self) -> dict:
        with self._lock:
            out = {
                "address": list(self.address),
                "connections": self.connections,
                "open_connections": self._open_conns,
                "hosts": len(self._hosts),
                "stale_chunks": self.stale_chunks,
                "duplicate_chunks": self.duplicate_chunks,
                "lost_chunks": self.lost_chunks,
                "bad_rows": self.bad_rows,
                "proto_errors": self.proto_errors,
            }
        out.update(self.source.stats())
        return out

    # -- accept/connection machinery -----------------------------------------
    def _accept_loop(self) -> None:
        while not self._stopped.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True, name="gapp-ingest-conn")
            # prune finished handlers so a long-lived server with flaky,
            # reconnecting producers doesn't accumulate dead Thread objects
            self._conn_threads = [x for x in self._conn_threads
                                  if x.is_alive()]
            self._conn_threads.append(t)
            with self._lock:
                self.connections += 1
                self._open_conns += 1
            t.start()

    def _register_host(self, hello: dict) -> _HostState:
        host_id = str(hello["host_id"])
        instance = str(hello.get("instance", ""))
        declared = hello.get("clock_offset_ns")
        offset = (int(declared) if declared is not None
                  else int(self.clock()) - int(hello["t_client_ns"]))
        with self._lock:
            st = self._hosts.get(host_id)
            if st is None:
                stream = self.source.add_host(
                    host_id, int(hello["num_workers"]),
                    hello.get("worker_names"), clock_offset_ns=offset)
                st = self._hosts[host_id] = _HostState(stream, instance)
            else:                       # reconnect: new clock-sync epoch
                with st.lock:
                    st.epoch += 1
                    st.stream.clock_offset_ns = offset
                    st.got_bye = False
                    st.stream.finished = False
                    if instance != st.instance:
                        # producer RESTART, not a reconnect: a fresh
                        # capture numbers its chunks from 0 again — reset
                        # the dedup floor or every new chunk would drop as
                        # a retransmit
                        st.instance = instance
                        st.next_seq = 0
                # workers registered since the first HELLO: grow the host's
                # id space when it still owns the tail of the fleet range
                # (growth of an interior host would collide with the next
                # host's offsets — counted, rows filtered as bad_rows)
                nw = int(hello["num_workers"])
                if nw > st.stream.num_workers and not \
                        self.source.try_grow_host(
                            st.stream, nw, hello.get("worker_names")):
                    self.worker_growth_rejected += 1
        return st

    def _serve_conn(self, conn: socket.socket) -> None:
        conn.settimeout(30.0)
        f = conn.makefile("rwb")
        st: _HostState | None = None
        try:
            frame = wire.read_frame(f)
            if frame is None or frame[0] != wire.HELLO:
                raise wire.WireError("expected HELLO")
            st = self._register_host(wire.decode_hello(frame[1]))
            f.write(wire.encode_welcome(st.stream.index, st.epoch,
                                        st.stream.clock_offset_ns))
            f.flush()
            while True:
                frame = wire.read_frame(f)
                if frame is None:
                    break
                kind, payload = frame
                if kind == wire.CHUNK:
                    self._on_chunk(st, wire.decode_chunk(payload))
                elif kind == wire.TAGS:
                    self._on_tags(st, wire.decode_json(payload))
                elif kind == wire.STACKS:
                    self._on_stacks(st, wire.decode_json(payload))
                elif kind == wire.BYE:
                    bye = wire.decode_json(payload)
                    with self._lock:
                        st.rows_declared = int(bye.get("rows_sent", -1))
                        st.got_bye = True
                    st.stream.finish()
                    self.source.notify()
                    break
                else:
                    raise wire.WireError(
                        f"unexpected {wire.KIND_NAMES.get(kind, kind)}")
        except (OSError, wire.WireError, KeyError, ValueError):
            with self._lock:
                self.proto_errors += 1
        finally:
            try:
                f.close()
                conn.close()
            except OSError:
                pass
            with self._idle:
                self._open_conns -= 1
                self._idle.notify_all()
            self.source.notify()

    # -- frame handlers (serialized per host via st.lock) --------------------
    def _on_tags(self, st: _HostState, obj: dict) -> None:
        stream = st.stream
        with st.lock:
            for tid, name, loc in obj["entries"]:
                stream.tag_map = _grow_map(stream.tag_map, int(tid))
                stream.tag_map[int(tid)] = self.source.tags.intern(
                    str(name), str(loc))

    def _on_stacks(self, st: _HostState, obj: dict) -> None:
        stream = st.stream
        with st.lock:
            for sid, path in obj["entries"]:
                fleet_path = []
                for t in path:
                    stream.tag_map = _grow_map(stream.tag_map, int(t))
                    fleet_path.append(int(stream.tag_map[int(t)]))
                stream.stack_map = _grow_map(stream.stack_map, int(sid))
                stream.stack_map[int(sid)] = self.source.stacks.intern(
                    tuple(fleet_path))

    def _on_chunk(self, st: _HostState, chunk: wire.ChunkFrame) -> None:
        with st.lock:
            # epoch/seq check + commit + push are one atomic step: an old
            # connection's handler racing a reconnect's handler must not
            # fold a retransmit twice or interleave pushes out of order
            if chunk.epoch != st.epoch:
                with self._stats_lock:
                    self.stale_chunks += 1
                return
            if chunk.seq < st.next_seq:  # retransmit of a delivered chunk
                with self._stats_lock:
                    self.duplicate_chunks += 1
                return
            if chunk.seq > st.next_seq:
                # a gap means chunks committed producer-side (flush reached
                # the kernel) never arrived — e.g. lost in a reset before
                # the server read them.  They are unrecoverable (the sink
                # only retains the one in-flight chunk), so count them
                # loudly: delivery is at-most-once with loss DETECTION,
                # not exactly-once end-to-end
                with self._stats_lock:
                    self.lost_chunks += int(chunk.seq - st.next_seq)
            st.next_seq = chunk.seq + 1
            w = chunk.workers
            bad = (w < 0) | (w >= st.stream.num_workers)
            if bad.any():              # worker registered after HELLO
                with self._stats_lock:
                    self.bad_rows += int(bad.sum())
                keep = ~bad
                cols = tuple(c[keep] for c in chunk.columns)
            else:
                cols = chunk.columns
            if len(cols[0]) == 0:
                return
            with self.source.cond:
                st.stream.push(*cols)
                self.source.cond.notify_all()
