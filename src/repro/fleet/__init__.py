"""Fleet ingest subsystem — multi-host GAPP profiling.

Turns the single-host streaming profiler into a fleet profiler:

* :mod:`repro.fleet.wire` — versioned length-prefixed binary frame format
  for event chunks (see its docstring for the wire spec table);
* :mod:`repro.fleet.transport` — :class:`RemoteSink` (producer: stream a
  session's drained chunks over a socket, with backpressure + reconnect)
  and :class:`IngestServer` (consumer: N producers → one fleet hub);
* :mod:`repro.fleet.aggregate` — :class:`FleetSource`, an
  :class:`~repro.core.session.EventSource` that k-way-merges per-host
  streams (shard tie-break semantics, clock-offset normalization) so one
  :class:`~repro.core.session.ProfileSession` folds the whole fleet and
  reports bottlenecks with host provenance;
* :mod:`repro.fleet.service` — :class:`ProfilerService`, the live HTTP
  query API, ``/metrics`` exposition and no-dependency dashboard over
  that session (``session.serve(addr, server=ingest)``), with
  :class:`RetentionPolicy` age-pruning the durable journals.

Offline, the same merge ingests spill files copied off the hosts::

    from repro.fleet import FleetSource
    rep = ProfileSession(FleetSource.from_files(paths), n_min=2.0).result()

Importing this package also registers the ``"remote"`` exporter
(``session.export("remote", addr=(host, port))``); :mod:`repro.core`
loads it lazily on first use.

Failure modes & guarantees
--------------------------

What happens to in-flight data under each failure, with journaling on
both sides (producer ``journal_path=``, server ``fleet_dir=``).
*Recovered* means the rows reappear (live replay or offline
``FleetSource.from_fleet_dir`` / ``from_producer_journals``);
*counted-lost* means the rows are gone but the loss is counted
(``lost_chunks`` — never silent); *shed* means live-report rows over the
``max_pending_rows`` budget were dropped from the merge but remain
journaled (``shed_chunks``/``shed_rows``; offline replay recovers them).

==========================  =============================================
failure                     guarantee
==========================  =============================================
producer killed (-9)        unsent chunks survive in its journal; a
                            restarted sink on the same ``journal_path``
                            resumes the capture instance and replays from
                            the server's ack floor → **recovered**
server killed               journals + meta sidecars in ``fleet_dir``
                            persist; a restarted server restores dedup
                            floors, backfills history, producers
                            reconnect and replay unacked chunks →
                            **recovered**
network partition           producer backs off (full-jitter) and
                            replays journaled chunks on reconnect →
                            **recovered**; without a producer journal
                            the gap is **counted-lost**
producer disk full          the chunk is dropped whole before consuming
                            a seq (``journal_errors``/``dropped_chunks``)
                            → **counted-lost**, dedup floor intact
server disk full            the chunk is REFUSED (connection closed, no
                            commit); the producer replays it once the
                            disk recovers → **recovered**
slow / stalled producer     ``read_deadline`` reclaims dead connections;
                            ``idle_release`` (or an idle heartbeat)
                            exempts the host from the merge watermark so
                            it cannot stall healthy hosts; late data
                            clamps like any late joiner
merge overload              journaled hosts: oldest buffered chunks are
                            **shed** (recoverable offline); non-journaled
                            hosts: reads pause (lossless backpressure)
corrupted frame             header/schema validation rejects the frame
                            (``proto_errors``) — corruption is detected,
                            never folded
==========================  =============================================

A ``sink.close()`` is a *delivery barrier*: the server closes a
connection only after consuming its BYE, and a dying server RESETS every
connection it abandons — so a clean close proves the whole stream was
folded, and a flush into a dead socket's buffers can never pass as
delivery.

Every one of these is reproducible deterministically with
:class:`repro.fleet.faults.FaultPlan` (see ``benchmarks/bench_chaos.py``
for the 64-producer chaos gate).
"""
from repro.fleet.aggregate import FleetSource, HostStream
from repro.fleet.faults import FaultPlan
from repro.fleet.service import ProfilerService, RetentionPolicy
from repro.fleet.transport import IngestServer, RemoteSink, attach_remote
from repro.fleet.wire import (CHUNK, ChunkFrame, HELLO, MERGED_SHARD, RAW,
                              SUPPORTED_CODECS, WIRE_VERSION, ZLIB,
                              WireError, decode_chunk, encode_chunk,
                              negotiate_codec, pack_frame, read_frame)

__all__ = [
    "FaultPlan", "FleetSource", "HostStream", "IngestServer",
    "ProfilerService", "RemoteSink", "RetentionPolicy",
    "attach_remote", "WIRE_VERSION", "WireError", "ChunkFrame",
    "encode_chunk", "decode_chunk", "pack_frame", "read_frame",
    "CHUNK", "HELLO", "MERGED_SHARD", "RAW", "ZLIB", "SUPPORTED_CODECS",
    "negotiate_codec",
]
