"""Fleet ingest subsystem — multi-host GAPP profiling.

Turns the single-host streaming profiler into a fleet profiler:

* :mod:`repro.fleet.wire` — versioned length-prefixed binary frame format
  for event chunks (see its docstring for the wire spec table);
* :mod:`repro.fleet.transport` — :class:`RemoteSink` (producer: stream a
  session's drained chunks over a socket, with backpressure + reconnect)
  and :class:`IngestServer` (consumer: N producers → one fleet hub);
* :mod:`repro.fleet.aggregate` — :class:`FleetSource`, an
  :class:`~repro.core.session.EventSource` that k-way-merges per-host
  streams (shard tie-break semantics, clock-offset normalization) so one
  :class:`~repro.core.session.ProfileSession` folds the whole fleet and
  reports bottlenecks with host provenance.

Offline, the same merge ingests spill files copied off the hosts::

    from repro.fleet import FleetSource
    rep = ProfileSession(FleetSource.from_files(paths), n_min=2.0).result()

Importing this package also registers the ``"remote"`` exporter
(``session.export("remote", addr=(host, port))``); :mod:`repro.core`
loads it lazily on first use.
"""
from repro.fleet.aggregate import FleetSource, HostStream
from repro.fleet.transport import IngestServer, RemoteSink, attach_remote
from repro.fleet.wire import (CHUNK, ChunkFrame, HELLO, MERGED_SHARD, RAW,
                              SUPPORTED_CODECS, WIRE_VERSION, ZLIB,
                              WireError, decode_chunk, encode_chunk,
                              negotiate_codec, pack_frame, read_frame)

__all__ = [
    "FleetSource", "HostStream", "IngestServer", "RemoteSink",
    "attach_remote", "WIRE_VERSION", "WireError", "ChunkFrame",
    "encode_chunk", "decode_chunk", "pack_frame", "read_frame",
    "CHUNK", "HELLO", "MERGED_SHARD", "RAW", "ZLIB", "SUPPORTED_CODECS",
    "negotiate_codec",
]
