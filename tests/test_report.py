"""Report rendering + JSON export."""
import json

import numpy as np

from repro.core import detect, imbalance_stats, render_text, to_json
from tests.test_detector import _bottleneck_trace


def test_render_and_json_roundtrip():
    tr, clk, w = _bottleneck_trace()
    rep = detect(tr, None)
    text = render_text(rep)
    assert "GAPP bottleneck profile" in text
    assert "io_phase" in text and "critical slices" in text
    d = json.loads(to_json(rep))
    assert d["total_critical"] == 8
    assert d["paths"][0]["path"] == "io_phase"
    assert abs(d["paths"][0]["cmetric_s"] - 0.04) < 1e-9


def test_json_schema_version_and_roundtrip():
    """to_json -> parse -> the ranked paths and CMetrics survive exactly."""
    tr, clk, w = _bottleneck_trace()
    rep = detect(tr, None)
    d = json.loads(to_json(rep))
    assert d["schema_version"] == 4   # v4 == additive what_if key
    # the host fields are additive: absent entirely for single-host reports
    assert "worker_hosts" not in d and "per_host" not in d
    # ranked paths round-trip in order, with bit-identical CMetrics (json
    # floats are repr'd losslessly) and slice counts
    assert [p["path"] for p in d["paths"]] == \
        [rep.path_str(p) for p in rep.paths]
    assert [p["cmetric_s"] for p in d["paths"]] == \
        [p.cmetric for p in rep.paths]
    assert [p["slices"] for p in d["paths"]] == [p.slices for p in rep.paths]
    assert [p["rank"] for p in d["paths"]] == \
        list(range(1, len(rep.paths) + 1))
    assert d["per_worker_cmetric_s"] == rep.per_worker.tolist()
    assert d["worker_names"] == rep.worker_names
    assert d["total_critical"] == rep.total_critical
    assert d["total_slices"] == rep.total_slices


def test_imbalance_stats():
    s = imbalance_stats(np.array([1.0, 1.0, 1.0, 5.0]))
    assert s["argmax"] == 3
    assert s["max_over_mean"] == 2.5
    assert s["cv"] > 0.8
    z = imbalance_stats(np.zeros(4))
    assert z["cv"] == 0.0 and z["max_over_mean"] == 0.0
