"""Waker analysis + bottleneck classification (paper §7 extensions)."""
import pytest

from repro.core import (Tracer, classify_report, classify_tag,
                        critical_wakers, detect, waker_edges)
from tests.test_tracer import FakeClock


def _lock_trace():
    """w0 holds a 'lock': w1/w2 activate immediately after w0 deactivates."""
    clk = FakeClock()
    tr = Tracer(n_min=2.5, clock=clk)
    w = [tr.register_worker(f"w{i}") for i in range(3)]
    for rep in range(6):
        tr.begin(w[0], "hold_lock")
        clk.advance(3_000_000)
        tr.end(w[0])
        clk.advance(1_000)                  # wake-up latency < eps
        tr.begin(w[1], "critical_section")
        tr.begin(w[2], "critical_section")
        clk.advance(1_000_000)
        tr.end(w[1])
        tr.end(w[2])
        clk.advance(500_000)
    return tr


def test_waker_edges_found():
    tr = _lock_trace()
    log = tr.freeze()
    edges = waker_edges(log, eps_ns=10_000)
    pairs = {(e.waker, e.woken): e.count for e in edges}
    assert pairs.get((0, 1)) == 6
    assert pairs.get((0, 2)) == 6
    # w1/w2 never wake w0 within eps (w0 reactivates 500us later)
    assert (1, 0) not in pairs and (2, 0) not in pairs


def test_critical_waker_ranking():
    tr = _lock_trace()
    ranked = critical_wakers(tr.freeze())
    assert ranked and ranked[0][0] == 0
    assert ranked[0][1] > 0


def test_classification():
    assert classify_tag("train/wait_data") == "data"
    assert classify_tag("ckpt/save") == "checkpoint"
    assert classify_tag("moe/all_to_all") == "collective"
    assert classify_tag("decode/req3") == "serve"
    assert classify_tag("train/step") == "compute"
    assert classify_tag("mystery") == "other"
    tr = _lock_trace()
    rep = detect(tr, None)
    classes = classify_report(rep)
    assert sum(classes.values()) == pytest.approx(
        sum(p.cmetric for p in rep.paths))
