"""Tests for the concurrency lint (repro.lint): one bad + one good
fixture per rule, suppression semantics, baseline roundtrip/staleness,
the CLI contract, the src-tree-stays-clean gate, and the runtime
lock-order watchdog."""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.lint import RULES, run_lint
from repro.lint.engine import Baseline
from repro.lint.runner import collect_files
from repro.lint.watchdog import LockWatchdog, _LockProxy

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "lint_fixtures")


def fixture(name: str) -> str:
    return os.path.join(FIXTURES, name)


def lint_fixture(name: str):
    return run_lint([fixture(name)])


# ---------------------------------------------------------------------------
# rule registry sanity
# ---------------------------------------------------------------------------

def test_rule_names_are_documented():
    assert RULES == ("guarded-by", "lock-order", "loop-blocking",
                     "publication-order")


# ---------------------------------------------------------------------------
# guarded-by
# ---------------------------------------------------------------------------

def test_guarded_by_flags_bad_fixture():
    res = lint_fixture("bad_guarded.py")
    assert all(f.rule == "guarded-by" for f in res.findings)
    assert {f.line for f in res.findings} == {16, 19, 25, 31}
    assert len(res.findings) == 4
    # one of them is the method-contract violation
    assert any("requires" in f.message and "held" in f.message
               for f in res.findings)
    # and one is the unique-owner foreign-receiver mutation
    assert any(f.symbol.startswith("bad_external") for f in res.findings)


def test_guarded_by_passes_good_fixture():
    res = lint_fixture("good_guarded.py")
    assert res.findings == []
    assert res.ok


# ---------------------------------------------------------------------------
# lock-order (seeded ABBA shape from the ingest-server history)
# ---------------------------------------------------------------------------

def test_lock_order_rediscovers_seeded_abba():
    res = lint_fixture("bad_lock_order.py")
    assert len(res.findings) == 1
    f = res.findings[0]
    assert f.rule == "lock-order"
    assert f.symbol.startswith("cycle:")
    assert "_registry_lock" in f.symbol and "_host_lock" in f.symbol
    # the report names concrete acquisition sites for the cycle edges
    assert "bad_lock_order.py:" in f.message


def test_lock_order_passes_leaf_hierarchy():
    res = lint_fixture("good_lock_order.py")
    assert res.findings == []


# ---------------------------------------------------------------------------
# loop-blocking (blocking call inside a selector callback)
# ---------------------------------------------------------------------------

def test_loop_blocking_flags_reachable_calls():
    res = lint_fixture("bad_blocking.py")
    assert {f.line for f in res.findings} == {24, 28}
    assert all(f.rule == "loop-blocking" for f in res.findings)
    # findings carry the call chain back to the annotated loop root
    assert all("reachable from event loop via" in f.message
               for f in res.findings)
    assert any("time.sleep" in f.message for f in res.findings)
    assert any("os.fsync" in f.message for f in res.findings)


def test_loop_blocking_ignores_unreachable_and_safe_calls():
    res = lint_fixture("good_blocking.py")
    assert res.findings == []


# ---------------------------------------------------------------------------
# publication-order
# ---------------------------------------------------------------------------

def test_publication_order_flags_torn_row():
    res = lint_fixture("bad_publication.py")
    assert len(res.findings) == 2
    by_kind = {f.symbol.rsplit(":", 1)[-1]: f for f in res.findings}
    assert set(by_kind) == {"unwritten", "late-write"}
    assert by_kind["unwritten"].line == 15
    assert by_kind["late-write"].line == 16


def test_publication_order_passes_ordered_writes():
    res = lint_fixture("good_publication.py")
    assert res.findings == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

BUMP_TEMPLATE = """\
import threading


class C:
    def __init__(self):
        self.lock = threading.Lock()
        self.n = 0          # guarded-by: self.lock

    def bump(self):
        self.n += 1{suffix}
"""


def test_suppression_with_reason_moves_finding_aside(tmp_path):
    p = tmp_path / "sup.py"
    p.write_text(BUMP_TEMPLATE.format(
        suffix="  # lint: disable=guarded-by(single-threaded test helper)"))
    res = run_lint([str(p)])
    assert res.findings == []
    assert len(res.suppressed) == 1
    assert res.suppressed[0].suppressed_by == "single-threaded test helper"


def test_suppression_without_reason_is_itself_a_finding(tmp_path):
    p = tmp_path / "sup.py"
    p.write_text(BUMP_TEMPLATE.format(suffix="  # lint: disable=guarded-by"))
    res = run_lint([str(p)])
    assert len(res.findings) == 1
    assert res.findings[0].symbol.endswith(":no-reason")
    assert not res.ok


def test_suppression_on_line_above_statement(tmp_path):
    p = tmp_path / "sup.py"
    body = BUMP_TEMPLATE.format(suffix="").replace(
        "        self.n += 1",
        "        # lint: disable=guarded-by(shutdown path, single owner)\n"
        "        self.n += 1")
    p.write_text(body)
    res = run_lint([str(p)])
    assert res.findings == []
    assert len(res.suppressed) == 1


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_and_staleness(tmp_path):
    bl_path = str(tmp_path / "baseline.json")
    res = lint_fixture("bad_guarded.py")
    assert len(res.findings) == 4
    Baseline.write(bl_path, res.findings, reason="accepted for test")

    bl = Baseline.load(bl_path)
    res2 = run_lint([fixture("bad_guarded.py")], baseline=bl)
    assert res2.findings == []
    assert len(res2.baselined) == 4
    assert res2.stale_baseline == []
    assert res2.ok

    # the same baseline against a clean file: every entry is stale, and a
    # stale entry fails the run (it means the debt was paid — delete it)
    bl3 = Baseline.load(bl_path)
    res3 = run_lint([fixture("good_guarded.py")], baseline=bl3)
    assert len(res3.stale_baseline) == 4
    assert not res3.ok


def test_baseline_fingerprints_are_line_free(tmp_path):
    res = lint_fixture("bad_guarded.py")
    for f in res.findings:
        assert f.fingerprint == f"{f.rule}:{f.path}:{f.symbol}"
        assert f":{f.line}" not in f.fingerprint.replace(f.path, "")


# ---------------------------------------------------------------------------
# the annotated tree itself must stay clean (the CI gate, in-process)
# ---------------------------------------------------------------------------

def test_src_tree_lints_clean(monkeypatch):
    monkeypatch.chdir(ROOT)
    files = collect_files(["src"])
    assert files, "src tree not found"
    baseline = Baseline.load("lint-baseline.json")
    res = run_lint(files, baseline=baseline)
    assert res.errors == []
    assert res.findings == [], "\n".join(f.render() for f in res.findings)
    assert res.stale_baseline == []


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        capture_output=True, text=True, cwd=ROOT, env=env, timeout=120)


def test_cli_bad_fixture_exits_1_with_json_report():
    proc = _run_cli("--no-baseline", "--json",
                    os.path.join("tests", "lint_fixtures", "bad_blocking.py"))
    assert proc.returncode == 1
    report = json.loads(proc.stdout)
    assert report["ok"] is False
    assert len(report["findings"]) == 2
    assert all(f["rule"] == "loop-blocking" for f in report["findings"])


def test_cli_good_fixture_exits_0():
    proc = _run_cli("--no-baseline",
                    os.path.join("tests", "lint_fixtures", "good_guarded.py"))
    assert proc.returncode == 0
    assert "0 findings" in proc.stdout


# ---------------------------------------------------------------------------
# runtime lock-order watchdog
# ---------------------------------------------------------------------------

@pytest.fixture
def _session_graph_guard(lock_order_watchdog):
    """The tests below create cyclic acquisition orders ON PURPOSE.  The
    session-wide watchdog (conftest) proxies the inner locks too — and
    its ``_creation_site`` walks past the nested watchdog's frames to the
    very same test lines — so restore its edge graph afterwards or the
    deliberate ABBA would fail the whole session at teardown."""
    if lock_order_watchdog is None:
        yield
        return
    with lock_order_watchdog._mu:
        snapshot = dict(lock_order_watchdog.edges)
    yield
    with lock_order_watchdog._mu:
        lock_order_watchdog.edges.clear()
        lock_order_watchdog.edges.update(snapshot)


@pytest.mark.usefixtures("_session_graph_guard")
def test_watchdog_detects_sequential_abba():
    wd = LockWatchdog()
    wd.install()
    try:
        a = threading.Lock()
        b = threading.Lock()
        with a:
            with b:
                pass
        with b:        # opposite order: never deadlocks in this run,
            with a:    # but the order graph now has a cycle
                pass
    finally:
        wd.uninstall()
    cycles = wd.cycles()
    assert cycles, "ABBA acquisition order not detected"
    assert "->" in cycles[0]


def test_watchdog_accepts_consistent_hierarchy():
    wd = LockWatchdog()
    wd.install()
    try:
        a = threading.Lock()
        b = threading.Lock()
        c = threading.Lock()
        for _ in range(3):
            with a:
                with b:
                    with c:
                        pass
    finally:
        wd.uninstall()
    assert wd.cycles() == []


@pytest.mark.usefixtures("_session_graph_guard")
def test_watchdog_ignores_same_site_nesting():
    wd = LockWatchdog()
    wd.install()
    try:
        locks = [threading.Lock() for _ in range(2)]  # ONE creation site
        with locks[0]:
            with locks[1]:
                pass
        with locks[1]:
            with locks[0]:
                pass
    finally:
        wd.uninstall()
    # a site-level graph cannot order instances of one site: no self-edge
    assert wd.cycles() == []


@pytest.mark.usefixtures("_session_graph_guard")
def test_watchdog_records_through_condition():
    wd = LockWatchdog()
    wd.install()
    try:
        a = threading.Lock()
        b = threading.Lock()
        cond = threading.Condition(a)   # wraps the proxy
        with cond:
            with b:
                pass
        with b:
            with a:
                pass
    finally:
        wd.uninstall()
    assert wd.cycles(), "Condition-wrapped acquire was not recorded"


def test_watchdog_thread_start_completes():
    """Regression: a thread started while the watchdog is installed sets
    its ``_started`` Event through a proxied lock BEFORE the thread is
    registered in ``threading._active`` (3.10 bootstrap order); the
    recorder must not call ``current_thread()`` there — the _DummyThread
    it fabricates acquires another proxied lock and recurses forever,
    hanging ``Thread.start()`` in the parent."""
    wd = LockWatchdog()
    wd.install()
    try:
        done = []
        threads = [threading.Thread(target=done.append, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10)
        assert all(not t.is_alive() for t in threads)
        assert sorted(done) == list(range(8))
    finally:
        wd.uninstall()


def test_watchdog_uninstall_restores_factories():
    wd = LockWatchdog()
    before_lock, before_rlock = threading.Lock, threading.RLock
    wd.install()
    assert threading.Lock is not before_lock
    lk = threading.Lock()
    assert isinstance(lk, _LockProxy)
    wd.uninstall()
    assert threading.Lock is before_lock
    assert threading.RLock is before_rlock


def test_watchdog_reentrant_rlock_records_no_self_edge():
    wd = LockWatchdog()
    wd.install()
    try:
        r = threading.RLock()
        with r:
            with r:     # legal re-entrancy
                pass
    finally:
        wd.uninstall()
    assert wd.edges == {}
    assert wd.cycles() == []
