"""Robustness contracts of the event-loop ingest server + RemoteSink.

Deadline honoring (wait_idle/flush return False instead of hanging on a
dead peer), heartbeat keepalives and idle-host watermark release, ghost
hosts (handshake, no data) neither pinning the merge nor leaking empty
journals, full-jitter backoff bounds, and overload shedding with offline
recovery.
"""
import os
import time

import numpy as np

from repro.core import ProfileSession, detect_offline
from repro.fleet import (FleetSource, IngestServer, RemoteSink,
                         attach_remote)
from tests.test_tracer import FakeClock


def _wait(cond, timeout=5.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        time.sleep(0.01)
    assert cond()


def _stream_spans(s, w, clk, n, tag="x"):
    for _ in range(n):
        s.begin(w, tag)
        clk.advance(1000)
        s.end(w)
        clk.advance(500)


# ---------------------------------------------------------------------------
# deadline honoring: never hang on a dead/hung peer
# ---------------------------------------------------------------------------

def test_wait_idle_returns_false_on_deadline_not_hangs():
    server = IngestServer()
    server.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         heartbeat_interval=None)
    try:
        _stream_spans(s, w, clk, 5)
        s.snapshot()
        assert sink.flush(5.0)
        # the host never says BYE: wait_idle must give up AT the deadline
        t0 = time.monotonic()
        assert server.wait_idle(0.3) is False
        assert time.monotonic() - t0 < 2.0
    finally:
        s.close()
        sink.close()
        server.close()


def test_flush_returns_false_against_unreachable_server():
    # nothing listens on this address: the sender retries forever, the
    # chunk stays pending — flush must return False at its deadline
    probe = IngestServer()                 # grab a port, never start it
    addr = probe.address
    probe.close()
    sink = RemoteSink(addr, "h", num_workers=1, worker_names=["w"],
                      clock_offset_ns=0, reconnect_delay=0.01,
                      backoff_max=0.05, max_reconnects=1 << 30,
                      heartbeat_interval=None)
    sink.start()
    try:
        sink.append_columns(np.array([1], np.int64), np.zeros(1, np.int32),
                            np.ones(1, np.int8), np.zeros(1, np.int32),
                            np.full(1, -1, np.int32))
        t0 = time.monotonic()
        assert sink.flush(0.5) is False
        assert time.monotonic() - t0 < 3.0
    finally:
        sink.abort()


def test_read_deadline_reclaims_silent_connection():
    """A peer that handshakes and then goes SILENT — no FIN, no frames,
    no heartbeats (a partitioned or frozen producer) — used to hold its
    connection open forever; the read deadline must reclaim it, and
    wait_idle must still honor its own deadline meanwhile."""
    import socket as socketlib
    from repro.fleet import wire
    server = IngestServer(read_deadline=0.2, idle_release=None)
    server.start()
    raw = socketlib.create_connection(server.address)
    try:
        f = raw.makefile("rwb")
        f.write(wire.encode_hello("frozen", 1, ["w"], t_client_ns=0,
                                  clock_offset_ns=0))
        f.flush()
        assert wire.read_frame(f)[0] == wire.WELCOME
        t0 = time.monotonic()
        assert server.wait_idle(0.5) is False       # host never says BYE
        assert time.monotonic() - t0 < 2.0
        _wait(lambda: server.stats()["deadline_closed"] >= 1)
        _wait(lambda: server.stats()["open_connections"] == 0)
    finally:
        raw.close()
        server.close()


# ---------------------------------------------------------------------------
# heartbeats & idle hosts
# ---------------------------------------------------------------------------

def test_heartbeat_keeps_idle_connection_alive():
    server = IngestServer(read_deadline=0.3, idle_release=None)
    server.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         heartbeat_interval=0.05)
    try:
        _stream_spans(s, w, clk, 3)
        s.snapshot()
        assert sink.flush(5.0)
        time.sleep(1.0)                 # >3x the read deadline, zero data
        st = server.stats()
        assert st["open_connections"] == 1, st      # beacons kept it alive
        assert st["deadline_closed"] == 0, st
        assert st["heartbeats"] >= 3, st
        assert sink.heartbeats_sent >= 3
    finally:
        s.close()
        sink.close()
        server.close()


def test_silent_host_released_from_watermark_and_leaves_no_journal(tmp_path):
    """A host that handshakes and then never sends a CHUNK: idle_release
    un-gates the merge so healthy hosts emit, and closing the server
    removes the ghost's empty journal + meta (from_fleet_dir must not
    replay a ghost)."""
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir, read_deadline=None,
                          idle_release=0.15)
    server.start()
    fleet_sess = ProfileSession(server.source, n_min=1.0)
    fleet_sess.start()
    ghost = RemoteSink(server.address, "ghost", num_workers=1,
                       worker_names=["g0"], clock_offset_ns=0,
                       heartbeat_interval=None)
    ghost.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         heartbeat_interval=None)
    try:
        _wait(lambda: server.stats()["hosts"] == 2)
        _stream_spans(s, w, clk, 10)
        s.snapshot()
        assert sink.flush(5.0)
        # the ghost pins nothing: its idle_release exemption lets the
        # healthy host's rows reach the fold while the ghost stays open
        _wait(lambda: server.stats()["idle_hosts"] >= 1)
        _wait(lambda: fleet_sess.stats()["events_folded"] >= 20)
        s.result()
        sink.close()
        rep = fleet_sess.result()
        assert rep.total_slices == 10
    finally:
        fleet_sess.stop()
        ghost.abort()
        server.close()
    st = server.stats()
    assert st["idle_released"] >= 1, st
    # no ghost journal/meta leaked; from_fleet_dir sees only the real host
    names = os.listdir(fleet_dir)
    assert not any(n.startswith("ghost") for n in names), names
    src = FleetSource.from_fleet_dir(fleet_dir)
    assert [h.host_id for h in src.hosts] == ["h"]
    assert len(src.full_log()) == 20


def test_dataless_heartbeat_does_not_pin_watermark():
    """An alive-but-dataless producer (heartbeats, no rows) must not gate
    the merge: its null-watermark beacons mark it exempt."""
    server = IngestServer(read_deadline=None, idle_release=None)
    server.start()
    fleet_sess = ProfileSession(server.source, n_min=1.0)
    fleet_sess.start()
    idle = RemoteSink(server.address, "idle", num_workers=1,
                      worker_names=["i0"], clock_offset_ns=0,
                      heartbeat_interval=0.05)
    idle.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         heartbeat_interval=None)
    try:
        _wait(lambda: server.stats()["hosts"] == 2)
        _stream_spans(s, w, clk, 10)
        s.snapshot()
        assert sink.flush(5.0)
        _wait(lambda: server.stats()["idle_hosts"] >= 1)
        # rows flow despite the dataless host (the watermark holds back
        # only the newest in-flight row of the live gating host)
        _wait(lambda: fleet_sess.stats()["events_folded"] >= 10)
    finally:
        s.close()
        sink.close()
        idle.abort()
        fleet_sess.stop()
        server.close()


# ---------------------------------------------------------------------------
# finish_host idempotence
# ---------------------------------------------------------------------------

def test_finish_host_idempotent_and_unknown_false():
    server = IngestServer()
    server.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         heartbeat_interval=None)
    try:
        _stream_spans(s, w, clk, 5)
        s.snapshot()
        assert sink.flush(5.0)
        assert server.finish_host("h") is True
        assert server.finish_host("h") is True      # idempotent
        assert server.finish_host("nope") is False
        rep = ProfileSession(server.source, n_min=1.0).result()
        assert rep.total_slices == 5                # finished, data intact
    finally:
        s.close()
        sink.abort()
        server.close()


# ---------------------------------------------------------------------------
# reconnect backoff: full jitter, bounded, seeded
# ---------------------------------------------------------------------------

def test_backoff_full_jitter_bounded_and_seeded(monkeypatch):
    import repro.fleet.transport as T
    slept = []
    monkeypatch.setattr(T.time, "sleep", lambda s: slept.append(s))
    sink = RemoteSink(("127.0.0.1", 1), "h", reconnect_delay=0.05,
                      backoff_max=0.4, backoff_seed=42)
    for a in range(12):
        sink._backoff(a)
    assert len(slept) == 12
    for a, d in enumerate(slept):
        cap = min(0.4, 0.05 * (1 << min(a, 16)))
        assert 0.0 <= d <= cap          # full jitter: uniform(0, cap)
    assert max(slept) <= 0.4            # capped despite attempt growth
    assert len(set(round(d, 12) for d in slept)) > 1    # actually jittered
    # the same seed replays the same schedule (chaos reproducibility)
    sink2 = RemoteSink(("127.0.0.1", 1), "h", reconnect_delay=0.05,
                       backoff_max=0.4, backoff_seed=42)
    slept2 = []
    monkeypatch.setattr(T.time, "sleep", lambda s: slept2.append(s))
    for a in range(12):
        sink2._backoff(a)
    assert slept2 == slept


# ---------------------------------------------------------------------------
# overload shedding: live report degrades, journals stay complete
# ---------------------------------------------------------------------------

def test_overload_sheds_oldest_but_journals_recover(tmp_path):
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir, max_pending_rows=20,
                          read_deadline=None, idle_release=None)
    server.start()                      # NOTE: no session draining
    journal = str(tmp_path / "h.journal")
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         journal=journal, heartbeat_interval=None)
    try:
        for _ in range(10):             # 10 chunks x 10 rows >> budget
            _stream_spans(s, w, clk, 5)
            s.snapshot()
        s.result()
        sink.close()
        assert server.wait_idle(10), server.stats()
        st = server.stats()
    finally:
        server.close()
    assert st["shed_chunks"] > 0, st
    assert st["shed_rows"] >= st["shed_chunks"], st
    assert st["lost_chunks"] == 0, st
    assert st["rows_in"] == 100         # every row was ACCEPTED (then shed)
    assert st["buffered_rows"] <= 20 + 10   # budget + one in-flight chunk
    # the journals kept what the live merge shed: offline replay is whole,
    # and the server journal agrees with the producer journal
    fleet = FleetSource.from_fleet_dir(fleet_dir)
    flog = fleet.full_log()
    assert len(flog) == 100
    prod = FleetSource.from_producer_journals([journal])
    plog = prod.full_log()
    np.testing.assert_array_equal(flog.times, plog.times)
    ra = detect_offline(flog, fleet.tags, fleet.stacks, n_min=1.0)
    rb = detect_offline(plog, prod.tags, prod.stacks, n_min=1.0)
    np.testing.assert_array_equal(ra.per_worker, rb.per_worker)
    assert ra.total_slices == rb.total_slices == 50


def test_non_journaled_overload_pauses_reads_lossless():
    """Without fleet_dir there is nothing to recover shed rows from, so
    overload must PAUSE reads (TCP backpressure), not shed."""
    server = IngestServer(max_pending_rows=20, read_deadline=None,
                          idle_release=None)
    server.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, server.address, host_id="h", clock_offset_ns=0,
                         heartbeat_interval=None)
    try:
        for _ in range(8):
            _stream_spans(s, w, clk, 5)
            s.snapshot()
        _wait(lambda: server.stats()["buffered_rows"] >= 20)
        time.sleep(0.2)                 # reads paused: no shedding ever
        st = server.stats()
        assert st["shed_chunks"] == 0, st
        assert st["buffered_rows"] <= 30, st
        # draining the merge resumes the reads and the rest arrives
        fleet_sess = ProfileSession(server.source, n_min=1.0)
        fleet_sess.start()
        s.result()
        sink.close()
        assert server.wait_idle(10), server.stats()
        rep = fleet_sess.result()
        fleet_sess.stop()
        assert server.stats()["rows_in"] == 80
        assert rep.total_slices == 40
    finally:
        s.close()
        server.close()


# ---------------------------------------------------------------------------
# close() is a delivery barrier: a flush into a dead socket's kernel
# buffers must never pass as delivery
# ---------------------------------------------------------------------------

def test_close_delivery_barrier_replays_into_restarted_server(tmp_path):
    """The silent-loss failure mode from the chaos gate: the server dies
    while the producer's tail (chunks + BYE) sits unread in socket
    buffers.  Every flush() succeeded, so without a barrier the sink
    would exit "clean" and the rows would vanish.  The dying server RSTs
    abandoned connections and a live server only closes a connection
    AFTER consuming its BYE, so close() discovers the loss, reconnects,
    and replays the journal tail into the restarted server."""
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir)
    addr = server.address
    server.start()
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    sink = attach_remote(s, addr, host_id="h", clock_offset_ns=0,
                         journal=str(tmp_path / "h.journal"),
                         reconnect_delay=0.01, backoff_max=0.05,
                         max_reconnects=1 << 30, heartbeat_interval=None)
    server2 = None
    try:
        _stream_spans(s, w, clk, 5)
        s.snapshot()
        assert sink.flush(5.0)
        # hard server loss with the producer mid-capture; the remaining
        # chunks and the BYE are written into a connection nobody will
        # ever read again
        server.close()
        _stream_spans(s, w, clk, 5)
        s.snapshot()
        s.result()
        # resurrect the aggregator on the same port + fleet_dir, THEN
        # close: the barrier must surface the dead-socket loss and the
        # replay must land everything in the restarted server
        server2 = IngestServer(addr, fleet_dir=fleet_dir)
        server2.start()
        sink.close(timeout=10.0)
        assert not sink.failed, sink.last_error
        assert sink.stats()["pending"] == 0
        assert server2.wait_idle(10.0), server2.stats()
        st = server2.stats()
        assert st["lost_chunks"] == 0, st
        src = FleetSource.from_fleet_dir(fleet_dir)
        oracle = detect_offline(src.full_log(), src.tags, src.stacks,
                                n_min=1.0)
        assert oracle.total_slices == 10     # nothing silently eaten
    finally:
        s.close()
        sink.close()
        server.close()
        if server2 is not None:
            server2.close()
