"""Detector post-processing: sample attachment, path merge, top-N ranking,
stack-top fallback, offline sampling replay."""
import pytest

from repro.core import (SampleBuffer, Tracer, detect, detect_offline,
                        simulate_samples)
from tests.test_tracer import FakeClock


def _bottleneck_trace(n_min=1.9):
    """3 workers: w0/w1 parallel bursts, w2 long serial sections under two
    different call paths."""
    clk = FakeClock()
    tr = Tracer(n_min=n_min, clock=clk)
    w = [tr.register_worker(f"w{i}") for i in range(3)]
    for rep in range(8):
        tr.begin(w[0], "par")
        tr.begin(w[1], "par")
        clk.advance(2_000_000)
        tr.end(w[0])
        tr.end(w[1])
        tr.begin(w[2], "io_phase")
        tr.push(w[2], "flush" if rep % 2 else "compress")
        clk.advance(5_000_000)
        tr.pop(w[2])
        tr.end(w[2])
    return tr, clk, w


def test_merge_and_rank():
    """Slices sharing a call path merge: CMetrics summed, slices counted."""
    tr, clk, w = _bottleneck_trace()
    rep = detect(tr, None, top_n=5)
    assert rep.total_critical == 8
    # the inner flush/compress frames are popped before switch-out, so all 8
    # serial slices share the "io_phase" call path and merge into one entry
    # (the inner frames are what the sampling probe attributes — tested in
    # test_offline_pipeline_with_simulated_sampler)
    assert rep.path_str(rep.paths[0]) == "io_phase"
    assert rep.paths[0].slices == 8
    assert rep.paths[0].cmetric == pytest.approx(8 * 5e-3, rel=1e-6)


def test_distinct_paths_ranked_separately():
    """Different span tags produce separate ranked entries, ordered by
    cumulative CMetric."""
    clk = FakeClock()
    tr = Tracer(n_min=1.9, clock=clk)
    w = tr.register_worker("w")
    tr.register_worker("other")
    for rep in range(6):
        tr.begin(w, "slow_path")
        clk.advance(4_000_000)
        tr.end(w)
        tr.begin(w, "fast_path")
        clk.advance(1_000_000)
        tr.end(w)
    rep = detect(tr, None, top_n=5)
    assert rep.path_str(rep.paths[0]) == "slow_path"
    assert rep.path_str(rep.paths[1]) == "fast_path"
    assert rep.paths[0].cmetric == pytest.approx(4 * rep.paths[1].cmetric,
                                                 rel=1e-6)


def test_stack_top_fallback():
    """Critical slice with zero samples attaches the stack-top tag."""
    tr, clk, w = _bottleneck_trace()
    rep = detect(tr, None, top_n=5)           # no sampler at all
    top = rep.paths[0]
    assert sum(top.tag_counts.values()) == 0
    assert sum(top.stack_top_counts.values()) == top.slices


def test_sample_attachment_window():
    tr, clk, w = _bottleneck_trace()
    buf = SampleBuffer()
    # one sample inside w2's 3rd serial slice, one outside any slice
    crit = tr.critical[2]
    buf.append((crit.start_ns + crit.end_ns) // 2, crit.worker, 7)
    buf.append(crit.end_ns + 10, crit.worker, 9)
    rep = detect(tr, buf, top_n=5)
    counts = {}
    for p in rep.paths:
        for t, c in p.tag_counts.items():
            counts[t] = counts.get(t, 0) + c
    assert counts.get(7) == 1
    assert 9 not in counts


def test_offline_pipeline_with_simulated_sampler():
    tr, clk, w = _bottleneck_trace()
    log = tr.freeze()
    rep = detect_offline(log, tr.tags, tr.stacks, n_min=1.9,
                         sample_dt_ns=500_000, backend="vector", top_n=5)
    assert rep.total_critical == 8
    top_names = [rep.path_str(p) for p in rep.paths[:2]]
    assert any("io_phase" in n for n in top_names)
    # sampled tags should hit the refined frames (flush/compress)
    top = rep.paths[0]
    assert sum(top.tag_counts.values()) > 0
    sampled = {rep.tag_name(t) for t in top.tag_counts}
    assert sampled & {"flush", "compress", "io_phase"}


def test_simulate_samples_only_below_nmin():
    tr, clk, w = _bottleneck_trace()
    log = tr.freeze()
    buf = simulate_samples(log, dt_ns=250_000, n_min=2)
    t, sw, tags = buf.frozen()
    # all samples must fall inside w2's solo sections (active count == 1)
    assert len(buf) > 0
    assert set(sw.tolist()) == {2}


def test_cr_and_totals():
    tr, clk, w = _bottleneck_trace()
    rep = detect(tr, None)
    assert rep.total_slices == 24
    assert rep.critical_ratio == pytest.approx(8 / 24)
    assert rep.total_time == pytest.approx(8 * 7e-3, rel=1e-6)
