"""CMetric algorithm: paper Figure-1 hand example, backend equivalence,
hypothesis invariants."""
import numpy as np
import pytest

try:                                   # `python -m pytest` from the repo root
    from tests.conftest import given, settings, st
except ImportError:                    # plain `pytest` (tests/ on sys.path)
    from conftest import given, settings, st

from repro.core import (EventLog, compute_numpy, compute_streaming,
                        compute_vectorized, compute, synthetic_log)
from repro.core.events import ACTIVATE, DEACTIVATE, NO_STACK, NO_TAG


def make_log(events, num_workers):
    """events: list of (t_seconds, worker, delta)."""
    t, w, d = zip(*events)
    order = np.argsort(np.asarray(t, np.float64), kind="stable")
    e = len(events)
    return EventLog(
        times=(np.asarray(t, np.float64) * 1e9).astype(np.int64)[order],
        workers=np.asarray(w, np.int32)[order],
        deltas=np.asarray(d, np.int8)[order],
        tags=np.full(e, NO_TAG, np.int32),
        stacks=np.full(e, NO_STACK, np.int32),
        num_workers=num_workers,
    )


FIG1 = make_log([
    (0, 0, ACTIVATE), (2, 1, ACTIVATE), (4, 2, ACTIVATE),
    (8, 1, DEACTIVATE), (10, 0, DEACTIVATE), (12, 2, DEACTIVATE),
], num_workers=3)

# hand-computed: intervals [0,2)n1 [2,4)n2 [4,8)n3 [8,10)n2 [10,12)n1
FIG1_CM = np.array([2 + 1 + 4 / 3 + 1, 1 + 4 / 3, 4 / 3 + 1 + 2])


@pytest.mark.parametrize("backend", ["numpy", "stream", "vector", "pallas"])
def test_figure1_hand_example(backend):
    res = compute(FIG1, backend=backend)
    np.testing.assert_allclose(res.per_worker, FIG1_CM, rtol=1e-5)
    assert res.num_slices == 3
    assert res.idle_time == 0.0
    assert res.total_time == pytest.approx(12.0)
    # thread 0's slice spans [0,10): harmonic avg parallelism = 10/5.333
    i = list(res.slice_worker).index(0)
    assert res.slice_threads_av[i] == pytest.approx(10 / FIG1_CM[0])


def test_timeslice_records_match_paper_rule():
    # worker 1's slice [2,8) spans three switching intervals; its CMetric
    # must be global_cm(8) - global_cm(2) (the local_cm snapshot rule)
    res = compute_numpy(FIG1)
    i = list(res.slice_worker).index(1)
    assert res.slice_start[i] == pytest.approx(2.0)
    assert res.slice_end[i] == pytest.approx(8.0)
    assert res.slice_cm[i] == pytest.approx(1 + 4 / 3)


def test_idle_time_accounted():
    log = make_log([(0, 0, ACTIVATE), (1, 0, DEACTIVATE),
                    (3, 1, ACTIVATE), (4, 1, DEACTIVATE)], 2)
    res = compute_numpy(log)
    assert res.idle_time == pytest.approx(2.0)
    np.testing.assert_allclose(res.per_worker, [1.0, 1.0])


def test_straggler_dominates():
    rng = np.random.default_rng(7)
    skew = np.ones(16)
    skew[3] = 10.0
    log = synthetic_log(rng, 16, 80, skew=skew)
    res = compute_numpy(log)
    assert res.per_worker.argmax() == 3
    # the straggler's CMetric share must exceed its time share
    assert res.per_worker[3] / res.per_worker.sum() > 0.3


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(1, 40), st.integers(0, 10_000))
def test_backends_agree(num_workers, slices, seed):
    rng = np.random.default_rng(seed)
    log = synthetic_log(rng, num_workers, slices)
    log.validate()
    r0 = compute_numpy(log)
    for backend in (compute_streaming, compute_vectorized):
        r = backend(log)
        np.testing.assert_allclose(r.per_worker, r0.per_worker,
                                   rtol=1e-4, atol=1e-6)
        assert r.num_slices == r0.num_slices
        np.testing.assert_allclose(np.sort(r.slice_cm),
                                   np.sort(r0.slice_cm), rtol=1e-3,
                                   atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(1, 30), st.integers(0, 10_000))
def test_conservation_invariant(num_workers, slices, seed):
    """Σ_w CMetric(w) + idle == wall time (the CMetric partitions time)."""
    rng = np.random.default_rng(seed)
    log = synthetic_log(rng, num_workers, slices)
    res = compute_numpy(log)
    assert res.per_worker.sum() + res.idle_time == pytest.approx(
        res.total_time, rel=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(1, 20), st.integers(0, 10_000))
def test_slice_bounds_invariant(num_workers, slices, seed):
    """Per-slice: dur/n_workers <= CMetric <= dur; threads_av in [1, W]."""
    rng = np.random.default_rng(seed)
    log = synthetic_log(rng, num_workers, slices)
    res = compute_numpy(log)
    dur = res.slice_end - res.slice_start
    assert np.all(res.slice_cm <= dur + 1e-9)
    assert np.all(res.slice_cm >= dur / num_workers - 1e-9)
    assert np.all(res.slice_threads_av >= 1 - 1e-9)
    assert np.all(res.slice_threads_av <= num_workers + 1e-9)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(1, 20), st.integers(0, 10_000))
def test_worker_relabel_equivariance(num_workers, slices, seed):
    rng = np.random.default_rng(seed)
    log = synthetic_log(rng, num_workers, slices)
    perm = np.random.default_rng(seed + 1).permutation(num_workers)
    relabeled = EventLog(log.times, perm[log.workers].astype(np.int32),
                         log.deltas, log.tags, log.stacks, num_workers)
    a = compute_numpy(log).per_worker
    b = compute_numpy(relabeled).per_worker
    np.testing.assert_allclose(b[perm], a, rtol=1e-9)


def test_empty_and_single_event():
    empty = make_log([], 2) if False else EventLog(
        times=np.zeros(0, np.int64), workers=np.zeros(0, np.int32),
        deltas=np.zeros(0, np.int8), tags=np.zeros(0, np.int32),
        stacks=np.zeros(0, np.int32), num_workers=2)
    for backend in ("numpy", "stream", "vector"):
        r = compute(empty, backend=backend)
        assert r.num_slices == 0 and r.per_worker.sum() == 0
