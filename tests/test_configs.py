"""Assigned-config fidelity: exact values from the assignment table."""
import pytest

from repro import configs


EXPECTED = {
    "deepseek-7b": dict(num_layers=30, d_model=4096, num_heads=32,
                        num_kv_heads=32, d_ff=11008, vocab_size=102400),
    "qwen1.5-4b": dict(num_layers=40, d_model=2560, num_heads=20,
                       num_kv_heads=20, d_ff=6912, vocab_size=151936,
                       qkv_bias=True),
    "qwen3-32b": dict(num_layers=64, d_model=5120, num_heads=64,
                      num_kv_heads=8, d_ff=25600, vocab_size=151936,
                      qk_norm=True),
    "gemma3-1b": dict(num_layers=26, d_model=1152, num_heads=4,
                      num_kv_heads=1, d_ff=6912, vocab_size=262144),
    "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                              num_kv_heads=1, d_ff=7680, vocab_size=256000),
    "seamless-m4t-large-v2": dict(num_layers=24, d_model=1024, num_heads=16,
                                  num_kv_heads=16, d_ff=8192,
                                  vocab_size=256206, enc_layers=24),
    "internvl2-2b": dict(num_layers=24, d_model=2048, num_heads=16,
                         num_kv_heads=8, d_ff=8192, vocab_size=92553),
    "grok-1-314b": dict(num_layers=64, d_model=6144, num_heads=48,
                        num_kv_heads=8, d_ff=32768, vocab_size=131072,
                        num_experts=8, top_k=2),
    "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                        num_kv_heads=8, d_ff=4864, vocab_size=32000,
                        num_experts=128, top_k=2, dense_residual=True),
    "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                       vocab_size=65536),
}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_exact_config_values(arch):
    cfg = configs.get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_gemma3_pattern_5to1():
    cfg = configs.get_config("gemma3-1b")
    assert cfg.block_pattern == ("local",) * 5 + ("dense",)
    assert cfg.num_groups == 4 and cfg.tail_pattern == ("local", "local")


def test_recurrentgemma_pattern_1to2():
    cfg = configs.get_config("recurrentgemma-2b")
    assert cfg.block_pattern == ("rglru", "rglru", "local")
    assert cfg.num_groups == 8 and cfg.tail_pattern == ("rglru", "rglru")


def test_grid_and_skips():
    cells = configs.grid()
    assert len(cells) == 33  # 10*4 minus 7 long_500k full-attention skips
    assert ("deepseek-7b", "long_500k") not in cells
    assert ("rwkv6-1.6b", "long_500k") in cells
    assert ("gemma3-1b", "long_500k") in cells
    assert ("recurrentgemma-2b", "long_500k") in cells


def test_shapes_table():
    s = configs.SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524288 and s["long_500k"].global_batch == 1


def test_param_counts_full_configs():
    """Full-size analytic param counts near the published sizes."""
    approx = {
        "deepseek-7b": 7e9, "qwen1.5-4b": 4e9, "qwen3-32b": 32e9,
        "gemma3-1b": 1e9, "recurrentgemma-2b": 2.7e9,
        "grok-1-314b": 314e9, "arctic-480b": 480e9, "rwkv6-1.6b": 1.6e9,
    }
    for arch, target in approx.items():
        n = configs.get_config(arch).param_count()
        assert 0.5 * target < n < 1.8 * target, (arch, n, target)
