"""ProfilerService: the live HTTP/JSON query API + dashboard.

Acceptance properties (ISSUE 9):

* ``GET /api/report`` is byte-identical to ``session.export("json")``;
* ``GET /api/top?window=`` equals an offline re-fold of exactly that
  window over the durable fleet_dir;
* watch callbacks with ``payload=True`` and ``/api/stream`` frames come
  from the same builder (key-set parity is structural);
* age-based retention never prunes a block a served query window still
  references.
"""
import json
import time
import urllib.error
import urllib.request

import pytest

from repro.core import ProfileSession
from repro.core.report import path_entries
from repro.fleet import (FleetSource, IngestServer, ProfilerService,
                         RetentionPolicy, attach_remote)
from repro.fleet.aggregate import fleet_dir_time_span
from repro.obs import http as obs_http
from repro.obs.payload import PAYLOAD_SCHEMA_VERSION, build_watch_payload
from repro.obs.prom import flatten_stats, render_metrics
from tests.test_tracer import FakeClock


def _wait(cond, timeout=10.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        time.sleep(0.01)
    assert cond()


def _stream_spans(s, w, clk, n, tag="x"):
    for _ in range(n):
        s.begin(w, tag)
        clk.advance(1000)
        s.end(w)
        clk.advance(500)


def _get(svc, path, timeout=5):
    url = "http://%s:%d%s" % (svc.address[0], svc.address[1], path)
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, dict(r.headers), r.read()


def _get_json(svc, path):
    status, _, body = _get(svc, path)
    assert status == 200
    return json.loads(body)


def _populate(server, tmp_path, hosts=("alpha", "beta"), spans=40):
    """Two producers, deterministic FakeClock times, zero clock offset.

    The hosts occupy DISJOINT fleet-time ranges (beta starts where alpha
    ends), so exactly one of the two workers is ever active — every
    slice is serialized under ``n_min=2.0`` and both hosts contribute
    bottleneck paths (an overlapped timeline would show zero critical
    slices and make top-N assertions vacuous)."""
    for i, hid in enumerate(hosts):
        clk = FakeClock()
        clk.t = i * spans * 1500
        s = ProfileSession(n_min=2.0, clock=clk, drain_interval=0.001)
        w = s.register_worker("w0")
        sink = attach_remote(s, server.address, host_id=hid,
                             clock_offset_ns=0,
                             journal=str(tmp_path / f"{hid}.journal"))
        _stream_spans(s, w, clk, spans, tag=f"work-{hid}")
        s.result()
        sink.close()
        assert not sink.failed and sink.dropped_chunks == 0


@pytest.fixture
def fleet(tmp_path):
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir)
    server.start()
    sess = ProfileSession(server.source, n_min=2.0)
    sess.start()
    svc = ProfilerService(sess, server=server).start()
    try:
        _populate(server, tmp_path)
        assert server.wait_idle(10), server.stats()
        _wait(lambda: sess.stats()["events_folded"] >= 160)
        yield svc, sess, server, fleet_dir
    finally:
        svc.close()
        sess.stop()
        server.close()


# ---------------------------------------------------------------------------
# acceptance: /api/report == export("json"), bit-equal
# ---------------------------------------------------------------------------

def test_api_report_byte_equal_to_export_json(fleet):
    svc, sess, _, _ = fleet
    status, headers, body = _get(svc, "/api/report")
    assert status == 200
    assert headers["Content-Type"].startswith("application/json")
    assert body == sess.export("json").encode("utf-8")
    doc = json.loads(body)
    assert doc["schema_version"] == 4
    assert set(doc["per_host"]) == {"alpha", "beta"}


# ---------------------------------------------------------------------------
# acceptance: windowed /api/top == offline re-fold of the same window
# ---------------------------------------------------------------------------

def test_api_top_windowed_matches_offline_refold(fleet):
    svc, sess, _, fleet_dir = fleet
    window_s = 2e-05                       # 20 us of FakeClock time
    doc = _get_json(svc, f"/api/top?n=10&window={window_s}")
    lo, hi = doc["window_ns"]
    span = fleet_dir_time_span(fleet_dir)
    assert hi == span[1] and lo == hi - int(window_s * 1e9)
    # oracle: a fresh offline fold over exactly that window
    src = FleetSource.from_fleet_dir(fleet_dir, window_ns=(lo, hi))
    oracle = ProfileSession(src, n_min=2.0).result(10)
    want = path_entries(oracle, 10)
    assert want, "window must cover real bottleneck paths"
    got = [{k: e[k] for k in want[0]} for e in doc["entries"]]
    assert got == want
    # the window genuinely subsets the capture
    full = _get_json(svc, "/api/top?n=10")
    assert sum(e["slices"] for e in doc["entries"]) < \
        sum(e["slices"] for e in full["entries"])


def test_api_top_deltas_against_previous_poll(fleet):
    svc, _, _, _ = fleet
    first = _get_json(svc, "/api/top?n=5")
    assert first["baseline"] is False
    assert all(e["delta_cmetric_s"] is None for e in first["entries"])
    second = _get_json(svc, "/api/top?n=5")
    assert second["baseline"] is True
    for e in second["entries"]:
        assert e["delta_cmetric_s"] is not None     # steady capture: ~0
        assert abs(e["delta_cmetric_s"]) < 1e-6
        assert e["prev_rank"] == e["rank"]


def test_api_top_window_requires_fleet_dir(tmp_path):
    s = ProfileSession(n_min=1.0, clock=FakeClock())
    w = s.register_worker("w")
    s.begin(w, "t")
    s.end(w)
    svc = ProfilerService(s).start()
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(svc, "/api/top?window=1")
        assert ei.value.code == 400
    finally:
        svc.close()
        s.result()


# ---------------------------------------------------------------------------
# hosts drill-down
# ---------------------------------------------------------------------------

def test_api_hosts_and_drilldown(fleet):
    svc, _, _, _ = fleet
    doc = _get_json(svc, "/api/hosts")
    assert set(doc["hosts"]) == {"alpha", "beta"}
    assert doc["ingest"]["lost_chunks"] == 0
    assert doc["health"]["hosts"] == 2
    one = _get_json(svc, "/api/hosts/alpha")
    assert one["host_id"] == "alpha"
    assert one["workers"] == 1 and one["worker_lanes"][0]["name"] \
        == "alpha/w0"
    assert one["stream"]["rows_in"] == 80
    assert one["journal"]["blocks"] >= 1
    assert one["journal"]["time_bounds_ns"][0] >= 0
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(svc, "/api/hosts/nope")
    assert ei.value.code == 404


# ---------------------------------------------------------------------------
# /metrics
# ---------------------------------------------------------------------------

def test_metrics_exposition(fleet):
    svc, _, _, _ = fleet
    _get(svc, "/api/top?n=3")              # count at least one request
    status, headers, body = _get(svc, "/metrics")
    text = body.decode()
    assert status == 200 and "0.0.4" in headers["Content-Type"]
    for needle in (
        "gapp_session_events_folded 160",
        "gapp_fleet_hosts 2",
        "gapp_ingest_lost_chunks 0",
        'gapp_journal_bytes{host="alpha"}',
        'gapp_journal_bytes{host="beta"}',
        'gapp_service_requests{route="/api/top"}',
        "gapp_service_snapshot_seconds_last",
        "gapp_service_fold_events_per_s",
    ):
        assert needle in text, f"missing {needle!r}\n{text}"
    # exposition shape: every sample line parses as name{...} value
    for line in text.strip().splitlines():
        if line.startswith("#"):
            assert line.startswith(("# TYPE ", "# HELP "))
        else:
            name, value = line.rsplit(" ", 1)
            float(value)
            assert name[0].isalpha()


def test_prom_flatten_and_render_unit():
    samples = list(flatten_stats("p", {
        "a": 2, "flag": True, "skip_str": "x", "skip_none": None,
        "nest": {"b": 1.5}, "9bad name": 7,
    }, labels=None))
    assert ("p_a", None, 2.0) in samples
    assert ("p_flag", None, 1.0) in samples
    assert ("p_nest_b", None, 1.5) in samples
    assert ("p__9bad_name", None, 7.0) in samples
    assert not any("skip" in s[0] for s in samples)
    text = render_metrics(samples + [("p_a", {"h": 'q"x'}, 3)])
    assert '# TYPE p_a gauge' in text
    assert 'p_a{h="q\\"x"} 3' in text
    assert text.index("p_a") < text.index("p_flag")     # sorted


# ---------------------------------------------------------------------------
# /api/stream and the shared watch payload
# ---------------------------------------------------------------------------

def test_api_stream_frames_match_watch_payload(fleet):
    svc, sess, _, _ = fleet
    url = "http://%s:%d/api/stream?every=0.05&n=4" % svc.address
    with urllib.request.urlopen(url, timeout=5) as r:
        assert r.headers["Content-Type"].startswith("application/x-ndjson")
        frames = []
        while len(frames) < 2:
            ln = r.readline().strip()
            if ln:
                frames.append(json.loads(ln))
    direct = build_watch_payload(sess, top_n=4)
    for f in frames:
        assert f["schema_version"] == PAYLOAD_SCHEMA_VERSION
        assert set(f) == set(direct)                    # same builder
        assert set(f["per_host"]) == {"alpha", "beta"}
        assert len(f["top"]) <= 4
        assert f["health"]["shed_chunks"] == 0


def test_watch_payload_has_host_lanes(tmp_path):
    server = IngestServer()
    server.start()
    sess = ProfileSession(server.source, n_min=2.0)
    frames = []
    sess.watch(frames.append, every=0.0, payload=True)
    sess.start()
    try:
        _populate(server, tmp_path)
        assert server.wait_idle(10)
        _wait(lambda: sess.stats()["events_folded"] >= 160)
        _wait(lambda: len(frames) >= 1
              and frames[-1]["events_folded"] >= 160)
        f = frames[-1]          # grabbed pre-stop: source still accepting
    finally:
        sess.stop()
        server.close()
    assert f["worker_hosts"] == ["alpha", "beta"]
    assert set(f["per_host"]) == {"alpha", "beta"}
    assert f["per_host"]["alpha"]["workers"] == 1
    assert f["health"]["accepting"] is True
    assert f["mode"] == "offline"
    assert [e["path"] for e in f["top"]]


def test_watch_exporter_payload_flag(tmp_path):
    clk = FakeClock()
    s = ProfileSession(n_min=1.0, clock=clk, drain_interval=0.001)
    w = s.register_worker("w")
    frames, reports = [], []
    s.export("watch", callback=frames.append, every=0.0, payload=True)
    s.export("watch", callback=reports.append, every=0.0)
    _stream_spans(s, w, clk, 5)
    s.result()
    assert frames and isinstance(frames[-1], dict)
    assert frames[-1]["total_slices"] == 5
    assert frames[-1]["worker_hosts"] == []     # single host: slim form
    assert reports and not isinstance(reports[-1], dict)


# ---------------------------------------------------------------------------
# dashboard + protocol errors
# ---------------------------------------------------------------------------

def test_dashboard_html(fleet):
    svc, _, _, _ = fleet
    status, headers, body = _get(svc, "/")
    assert status == 200
    assert headers["Content-Type"].startswith("text/html")
    for needle in (b"GAPP fleet profiler", b"/api/top", b"/api/hosts",
                   b"per-host lanes"):
        assert needle in body


def test_http_errors(fleet):
    svc, _, _, _ = fleet
    for path, code in [("/api/nope", 404), ("/api/top?n=zap", 400),
                       ("/api/top?window=-2", 400)]:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(svc, path)
        assert ei.value.code == code, path
        assert ei.value.read().startswith(b"{")        # JSON error body
    req = urllib.request.Request(
        "http://%s:%d/api/report" % svc.address, data=b"x=1")  # POST
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=5)
    assert ei.value.code == 405
    assert svc.stats()["http_errors"] >= 4


def test_http_parse_request_unit():
    assert obs_http.parse_request(b"GET /x HTTP/1.1\r\n") is None  # partial
    req, used = obs_http.parse_request(
        b"GET /api/top?n=5&window=1.5 HTTP/1.1\r\nHost: h\r\n"
        b"X-Thing: v\r\n\r\ntrailing")
    assert used == len(b"GET /api/top?n=5&window=1.5 HTTP/1.1\r\n"
                       b"Host: h\r\nX-Thing: v\r\n\r\n")
    assert (req.method, req.path) == ("GET", "/api/top")
    assert req.query == {"n": "5", "window": "1.5"}
    assert req.headers["x-thing"] == "v"
    assert req.query_int("n") == 5 and req.query_float("window") == 1.5
    assert req.query_int("n", lo=10) == 10              # clamped
    assert req.query_int("missing", 7) == 7
    with pytest.raises(obs_http.HttpError):
        obs_http.parse_request(b"FTP JUNK\r\n\r\n")
    with pytest.raises(obs_http.HttpError):
        obs_http.parse_request(b"G" * (obs_http.MAX_REQUEST_BYTES + 1))


# ---------------------------------------------------------------------------
# offline mode + session.serve wiring
# ---------------------------------------------------------------------------

def test_from_fleet_dir_offline_service(fleet):
    svc, sess, _, fleet_dir = fleet
    off = ProfilerService.from_fleet_dir(fleet_dir, n_min=2.0)
    off.start()
    try:
        # the offline service's /api/report is byte-equal to folding the
        # same fleet_dir by hand (live per-host criticality can differ:
        # the incremental fold judged alpha before beta ever attached)
        status, _, body = _get(off, "/api/report")
        osess = ProfileSession(FleetSource.from_fleet_dir(fleet_dir),
                               n_min=2.0)
        osess.result()
        assert status == 200 and body == osess.export("json").encode()
        doc = json.loads(body)
        live = json.loads(_get(svc, "/api/report")[2])
        assert doc["total_slices"] == live["total_slices"]
        assert set(doc["per_host"]) == set(live["per_host"])
        # windowed queries work offline too (same journals)
        top = _get_json(off, "/api/top?n=5&window=2e-05")
        assert top["entries"]
        hosts = _get_json(off, "/api/hosts")
        assert set(hosts["hosts"]) == {"alpha", "beta"}
        assert "ingest" not in hosts            # no live server attached
        assert hosts["mode"] == "offline"
        met = _get(off, "/metrics")[2].decode()
        assert 'gapp_journal_bytes{host="alpha"}' in met
    finally:
        off.close()


def test_session_serve_returns_started_service(fleet):
    _, sess, server, _ = fleet
    svc2 = sess.serve(server=server)
    try:
        assert svc2.address[1] > 0
        assert _get_json(svc2, "/api/hosts")["ingest"]["lost_chunks"] == 0
    finally:
        svc2.close()


# ---------------------------------------------------------------------------
# retention: age budget prunes sealed history, never a served window
# ---------------------------------------------------------------------------

def test_retention_prunes_aged_segments(tmp_path):
    fleet_dir = str(tmp_path / "fleet")
    server = IngestServer(fleet_dir=fleet_dir, fleet_rotate_bytes=1)
    server.start()
    sess = ProfileSession(server.source, n_min=2.0)
    sess.start()
    svc = ProfilerService(
        sess, server=server,
        retention=RetentionPolicy(max_age_s=1e-05, sweep_interval_s=60))
    svc.start()
    try:
        clk = FakeClock()
        s = ProfileSession(n_min=2.0, clock=clk, drain_interval=0.001)
        w = s.register_worker("w")
        sink = attach_remote(s, server.address, host_id="h",
                             clock_offset_ns=0)
        for _ in range(10):                 # 10 explicitly-synced batches
            _stream_spans(s, w, clk, 4)     # -> 10 chunks -> 10 one-
            s.tracer.sync()                 # block rotated segments
        s.result()
        sink.close()
        assert server.wait_idle(10)
        store = server.host_journals()["h"]
        assert store.segments >= 3          # rotated history
        before = store.blocks
        pruned = svc.retention_sweep()      # budget: newest 10 us only
        assert pruned > 0
        assert store.pruned_blocks == pruned
        # surviving history starts inside the capture, not at 0, and the
        # newest block always survives (the budget anchors on it)
        tb = store.time_bounds()
        assert tb[0] > 0
        assert tb[1] == 10 * 4 * 1500 - 500
        assert store.first_block == pruned
        assert store.blocks == before       # global indices untouched
        # a served window holds retention back: ask for the full span,
        # then shrink the budget to nothing — the sweep keeps the window
        svc2_doc = _get_json(svc, "/api/top?n=5&window=1")  # 1 s >> span
        assert svc2_doc["entries"]
        assert svc.retention_sweep() == 0   # guard = max(budget, window)
    finally:
        svc.close()
        sess.stop()
        server.close()
