"""Wire format: frame round-trips, version/length validation, chunk
encode/decode bit-exactness."""
import io
import struct

import numpy as np
import pytest

from repro.fleet import wire


def _roundtrip(raw: bytes):
    return wire.read_frame(io.BytesIO(raw))


def test_chunk_roundtrip_bit_exact():
    rng = np.random.default_rng(0)
    n = 257
    times = rng.integers(0, 2**62, n).astype(np.int64)
    workers = rng.integers(0, 64, n).astype(np.int32)
    deltas = rng.choice([-1, 1], n).astype(np.int8)
    tags = rng.integers(-1, 100, n).astype(np.int32)
    stacks = rng.integers(-1, 50, n).astype(np.int32)
    raw = wire.encode_chunk(3, wire.MERGED_SHARD, 7, 42, times, workers,
                            deltas, tags, stacks)
    kind, payload = _roundtrip(raw)
    assert kind == wire.CHUNK
    c = wire.decode_chunk(payload)
    assert (c.host_index, c.shard_id, c.epoch, c.seq) == \
        (3, wire.MERGED_SHARD, 7, 42)
    assert len(c) == n
    for got, want in zip(c.columns, (times, workers, deltas, tags, stacks)):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_chunk_roundtrip_empty():
    z = [np.zeros(0, dt) for dt in wire.COL_DTYPES]
    kind, payload = _roundtrip(wire.encode_chunk(0, 5, 0, 0, *z))
    c = wire.decode_chunk(payload)
    assert len(c) == 0 and c.shard_id == 5


def test_chunk_misaligned_columns_rejected():
    z = [np.zeros(3, dt) for dt in wire.COL_DTYPES]
    z[2] = np.zeros(2, np.int8)
    with pytest.raises(wire.WireError):
        wire.encode_chunk(0, 0, 0, 0, *z)


def test_chunk_payload_length_validated():
    z = [np.zeros(4, dt) for dt in wire.COL_DTYPES]
    _, payload = _roundtrip(wire.encode_chunk(0, 0, 0, 0, *z))
    with pytest.raises(wire.WireError):
        wire.decode_chunk(payload[:-1])
    with pytest.raises(wire.WireError):
        wire.decode_chunk(payload + b"\0")


def test_hello_welcome_roundtrip():
    raw = wire.encode_hello("hostA", 4, ["a", "b", "c", "d"],
                            t_client_ns=123, clock_offset_ns=None)
    kind, payload = _roundtrip(raw)
    assert kind == wire.HELLO
    h = wire.decode_hello(payload)
    assert h["host_id"] == "hostA" and h["num_workers"] == 4
    assert h["clock_offset_ns"] is None and h["t_client_ns"] == 123
    assert h["codecs"] == list(wire.SUPPORTED_CODECS)

    kind, payload = _roundtrip(wire.encode_welcome(2, 1, -50, ack_seq=7,
                                                   codec=wire.ZLIB,
                                                   tags_seen=3))
    assert kind == wire.WELCOME
    w = wire.decode_json(payload)
    assert w == {"host_index": 2, "epoch": 1, "clock_offset_ns": -50,
                 "ack_seq": 7, "codec": "zlib", "tags_seen": 3,
                 "stacks_seen": 0, "server_wire_version": wire.WIRE_VERSION}


def test_heartbeat_roundtrip():
    kind, payload = _roundtrip(wire.encode_heartbeat(12345))
    assert kind == wire.HEARTBEAT
    assert wire.decode_json(payload) == {"t_ns": 12345}
    # a producer with no data yet beacons a null watermark
    kind, payload = _roundtrip(wire.encode_heartbeat(None, codec=wire.ZLIB))
    assert kind == wire.HEARTBEAT
    assert wire.decode_json(payload) == {"t_ns": None}


def test_frame_from_buffer_incremental():
    """The event-loop parser: byte-at-a-time feeding yields exactly the
    frames read_frame would, at exact boundaries."""
    raw = wire.encode_bye(1, 1) + wire.encode_heartbeat(7)
    buf = bytearray()
    got = []
    for b in raw:
        buf.append(b)
        r = wire.frame_from_buffer(buf)
        if r is not None:
            kind, payload, consumed = r
            del buf[:consumed]
            got.append((kind, wire.decode_json(payload)))
    assert not buf
    assert got == [(wire.BYE, {"rows_sent": 1, "chunks_sent": 1}),
                   (wire.HEARTBEAT, {"t_ns": 7})]


def test_registry_sync_roundtrip():
    kind, payload = _roundtrip(wire.encode_tags([(0, "a", "m:1"),
                                                 (1, "b", "m:2")]))
    assert kind == wire.TAGS
    assert wire.decode_json(payload)["entries"] == [[0, "a", "m:1"],
                                                    [1, "b", "m:2"]]
    kind, payload = _roundtrip(wire.encode_stacks([(0, (1, 2)), (1, ())]))
    assert kind == wire.STACKS
    assert wire.decode_json(payload)["entries"] == [[0, [1, 2]], [1, []]]


def test_bad_magic_and_version_rejected():
    kind, payload = _roundtrip(wire.encode_json(wire.HELLO, {"magic": "x"}))
    with pytest.raises(wire.WireError):
        wire.decode_hello(payload)
    # corrupt the schema_version field in the frame header
    raw = bytearray(wire.encode_bye(0, 0))
    struct.pack_into("<H", raw, 2, wire.WIRE_VERSION + 1)
    with pytest.raises(wire.WireError):
        _roundtrip(bytes(raw))


def test_stream_truncation_detected():
    raw = wire.encode_bye(10, 2)
    assert _roundtrip(raw[:0]) is None          # clean EOF at boundary
    with pytest.raises(wire.WireError):
        _roundtrip(raw[:5])                      # mid-header
    with pytest.raises(wire.WireError):
        _roundtrip(raw[:-2])                     # mid-payload


def test_oversized_frame_rejected_before_alloc():
    hdr = struct.pack("<BBHI", wire.BYE, 0, wire.WIRE_VERSION,
                      wire.MAX_PAYLOAD + 1)
    with pytest.raises(wire.WireError):
        _roundtrip(hdr)


def test_multiple_frames_stream():
    buf = io.BytesIO(wire.encode_bye(1, 1) + wire.encode_bye(2, 2))
    k1, p1 = wire.read_frame(buf)
    k2, p2 = wire.read_frame(buf)
    assert wire.read_frame(buf) is None
    assert (wire.decode_json(p1)["rows_sent"],
            wire.decode_json(p2)["rows_sent"]) == (1, 2)


# ---------------------------------------------------------------------------
# compression codec (v2): negotiated zlib frames, flag bit, inflate guard
# ---------------------------------------------------------------------------

def _synthetic_cols(n=512, seed=1):
    rng = np.random.default_rng(seed)
    return (np.sort(rng.integers(0, 10**9, n)).astype(np.int64),
            rng.integers(0, 8, n).astype(np.int32),
            rng.choice([-1, 1], n).astype(np.int8),
            rng.integers(-1, 4, n).astype(np.int32),
            rng.integers(-1, 3, n).astype(np.int32))


def test_compressed_chunk_roundtrip_bit_exact():
    cols = _synthetic_cols()
    raw = wire.encode_chunk(1, wire.MERGED_SHARD, 2, 3, *cols)
    comp = wire.encode_chunk(1, wire.MERGED_SHARD, 2, 3, *cols,
                             codec=wire.ZLIB)
    assert len(comp) < len(raw)                 # it actually compressed
    assert comp[1] & wire.FLAG_COMPRESSED       # flag bit in the header
    assert wire.frame_raw_bytes(comp) == len(raw)
    kind, payload = _roundtrip(comp)
    assert kind == wire.CHUNK
    c = wire.decode_chunk(payload)
    assert (c.host_index, c.epoch, c.seq) == (1, 2, 3)
    for got, want in zip(c.columns, cols):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_compressed_roundtrip_all_json_kinds():
    """Every control-plane frame kind round-trips identically under zlib
    (padded so the payloads clear the compress-min threshold)."""
    pad = "x" * 200
    frames = [
        (wire.HELLO, wire.encode_hello("h" + pad, 2, ["a", "b"],
                                       t_client_ns=1, clock_offset_ns=0)),
        (wire.TAGS, wire.encode_tags([(i, f"tag{i}{pad}", "m:1")
                                      for i in range(8)], codec=wire.ZLIB)),
        (wire.STACKS, wire.encode_stacks([(i, (0, 1, 2))
                                          for i in range(30)],
                                         codec=wire.ZLIB)),
    ]
    for kind, raw in frames:
        k, payload = _roundtrip(raw)
        assert k == kind
        wire.decode_json(payload)               # valid JSON after inflate
    assert frames[1][1][1] & wire.FLAG_COMPRESSED


def test_incompressible_payload_falls_back_to_raw():
    """Per-frame fallback: when deflate does not shrink the payload the
    flag stays clear and the bytes ship raw."""
    import os as _os
    noise = _os.urandom(4096)
    f = wire.pack_frame(wire.BYE, noise, codec=wire.ZLIB)
    assert not (f[1] & wire.FLAG_COMPRESSED)
    assert _roundtrip(f) == (wire.BYE, noise)
    # tiny payloads never bother compressing either
    tiny = wire.pack_frame(wire.BYE, b"{}", codec=wire.ZLIB)
    assert not (tiny[1] & wire.FLAG_COMPRESSED)


def test_inflate_guard_rejects_bad_lengths_and_garbage():
    import zlib as _zlib
    good = _zlib.compress(b"a" * 1000)
    # declared length lies small -> reject (stream longer than declared)
    bad = struct.pack("<I", 10) + good
    hdr = struct.pack("<BBHI", wire.BYE, wire.FLAG_COMPRESSED,
                      wire.WIRE_VERSION, len(bad))
    with pytest.raises(wire.WireError):
        _roundtrip(hdr + bad)
    # declared length exceeds MAX_PAYLOAD -> rejected BEFORE inflating
    bomb = struct.pack("<I", wire.MAX_PAYLOAD + 1) + good
    hdr = struct.pack("<BBHI", wire.BYE, wire.FLAG_COMPRESSED,
                      wire.WIRE_VERSION, len(bomb))
    with pytest.raises(wire.WireError):
        _roundtrip(hdr + bomb)
    # declared length of ZERO means UNLIMITED to zlib's max_length — it
    # must be rejected outright or a bomb inflates before the size check
    zero = struct.pack("<I", 0) + good
    hdr = struct.pack("<BBHI", wire.BYE, wire.FLAG_COMPRESSED,
                      wire.WIRE_VERSION, len(zero))
    with pytest.raises(wire.WireError):
        _roundtrip(hdr + zero)
    # not a zlib stream at all
    junk = struct.pack("<I", 100) + b"not-zlib-data"
    hdr = struct.pack("<BBHI", wire.BYE, wire.FLAG_COMPRESSED,
                      wire.WIRE_VERSION, len(junk))
    with pytest.raises(wire.WireError):
        _roundtrip(hdr + junk)
    # unknown flag bits are still rejected
    hdr = struct.pack("<BBHI", wire.BYE, 0x80, wire.WIRE_VERSION, 0)
    with pytest.raises(wire.WireError):
        _roundtrip(hdr)


def test_v1_frames_still_accepted():
    """Additive bump: a v1 peer's frames (flags 0, version 1) decode."""
    payload = b'{"rows_sent":1,"chunks_sent":1}'
    v1 = struct.pack("<BBHI", wire.BYE, 0, 1, len(payload)) + payload
    assert _roundtrip(v1) == (wire.BYE, payload)
    # ... but a FUTURE version is rejected
    v3 = struct.pack("<BBHI", wire.BYE, 0, wire.WIRE_VERSION + 1,
                     len(payload)) + payload
    with pytest.raises(wire.WireError):
        _roundtrip(v3)


def test_negotiate_codec():
    assert wire.negotiate_codec(["zlib", "raw"]) == "zlib"
    assert wire.negotiate_codec(["raw"]) == "raw"
    assert wire.negotiate_codec(None) == "raw"          # v1 HELLO
    assert wire.negotiate_codec([]) == "raw"
    assert wire.negotiate_codec(["br", "zstd"]) == "raw"  # no overlap
    assert wire.negotiate_codec(["zlib"], preferred=("raw",)) == "raw"
    assert wire.negotiate_codec(["zlib"], preferred=(None,)) == "raw"
