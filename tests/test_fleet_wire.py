"""Wire format: frame round-trips, version/length validation, chunk
encode/decode bit-exactness."""
import io
import struct

import numpy as np
import pytest

from repro.fleet import wire


def _roundtrip(raw: bytes):
    return wire.read_frame(io.BytesIO(raw))


def test_chunk_roundtrip_bit_exact():
    rng = np.random.default_rng(0)
    n = 257
    times = rng.integers(0, 2**62, n).astype(np.int64)
    workers = rng.integers(0, 64, n).astype(np.int32)
    deltas = rng.choice([-1, 1], n).astype(np.int8)
    tags = rng.integers(-1, 100, n).astype(np.int32)
    stacks = rng.integers(-1, 50, n).astype(np.int32)
    raw = wire.encode_chunk(3, wire.MERGED_SHARD, 7, 42, times, workers,
                            deltas, tags, stacks)
    kind, payload = _roundtrip(raw)
    assert kind == wire.CHUNK
    c = wire.decode_chunk(payload)
    assert (c.host_index, c.shard_id, c.epoch, c.seq) == \
        (3, wire.MERGED_SHARD, 7, 42)
    assert len(c) == n
    for got, want in zip(c.columns, (times, workers, deltas, tags, stacks)):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_chunk_roundtrip_empty():
    z = [np.zeros(0, dt) for dt in wire.COL_DTYPES]
    kind, payload = _roundtrip(wire.encode_chunk(0, 5, 0, 0, *z))
    c = wire.decode_chunk(payload)
    assert len(c) == 0 and c.shard_id == 5


def test_chunk_misaligned_columns_rejected():
    z = [np.zeros(3, dt) for dt in wire.COL_DTYPES]
    z[2] = np.zeros(2, np.int8)
    with pytest.raises(wire.WireError):
        wire.encode_chunk(0, 0, 0, 0, *z)


def test_chunk_payload_length_validated():
    z = [np.zeros(4, dt) for dt in wire.COL_DTYPES]
    _, payload = _roundtrip(wire.encode_chunk(0, 0, 0, 0, *z))
    with pytest.raises(wire.WireError):
        wire.decode_chunk(payload[:-1])
    with pytest.raises(wire.WireError):
        wire.decode_chunk(payload + b"\0")


def test_hello_welcome_roundtrip():
    raw = wire.encode_hello("hostA", 4, ["a", "b", "c", "d"],
                            t_client_ns=123, clock_offset_ns=None)
    kind, payload = _roundtrip(raw)
    assert kind == wire.HELLO
    h = wire.decode_hello(payload)
    assert h["host_id"] == "hostA" and h["num_workers"] == 4
    assert h["clock_offset_ns"] is None and h["t_client_ns"] == 123

    kind, payload = _roundtrip(wire.encode_welcome(2, 1, -50))
    assert kind == wire.WELCOME
    w = wire.decode_json(payload)
    assert w == {"host_index": 2, "epoch": 1, "clock_offset_ns": -50}


def test_registry_sync_roundtrip():
    kind, payload = _roundtrip(wire.encode_tags([(0, "a", "m:1"),
                                                 (1, "b", "m:2")]))
    assert kind == wire.TAGS
    assert wire.decode_json(payload)["entries"] == [[0, "a", "m:1"],
                                                    [1, "b", "m:2"]]
    kind, payload = _roundtrip(wire.encode_stacks([(0, (1, 2)), (1, ())]))
    assert kind == wire.STACKS
    assert wire.decode_json(payload)["entries"] == [[0, [1, 2]], [1, []]]


def test_bad_magic_and_version_rejected():
    kind, payload = _roundtrip(wire.encode_json(wire.HELLO, {"magic": "x"}))
    with pytest.raises(wire.WireError):
        wire.decode_hello(payload)
    # corrupt the schema_version field in the frame header
    raw = bytearray(wire.encode_bye(0, 0))
    struct.pack_into("<H", raw, 2, wire.WIRE_VERSION + 1)
    with pytest.raises(wire.WireError):
        _roundtrip(bytes(raw))


def test_stream_truncation_detected():
    raw = wire.encode_bye(10, 2)
    assert _roundtrip(raw[:0]) is None          # clean EOF at boundary
    with pytest.raises(wire.WireError):
        _roundtrip(raw[:5])                      # mid-header
    with pytest.raises(wire.WireError):
        _roundtrip(raw[:-2])                     # mid-payload


def test_oversized_frame_rejected_before_alloc():
    hdr = struct.pack("<BBHI", wire.BYE, 0, wire.WIRE_VERSION,
                      wire.MAX_PAYLOAD + 1)
    with pytest.raises(wire.WireError):
        _roundtrip(hdr)


def test_multiple_frames_stream():
    buf = io.BytesIO(wire.encode_bye(1, 1) + wire.encode_bye(2, 2))
    k1, p1 = wire.read_frame(buf)
    k2, p2 = wire.read_frame(buf)
    assert wire.read_frame(buf) is None
    assert (wire.decode_json(p1)["rows_sent"],
            wire.decode_json(p2)["rows_sent"]) == (1, 2)
