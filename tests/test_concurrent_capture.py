"""Concurrent capture on the sharded tracer: no lost or torn events.

N real threads hammer ``span()`` on their own workers; afterwards
``freeze()`` + ``detect_offline`` must agree with the numpy oracle on the
merged log, every event must be accounted for (ring-drop counters
surfaced), and a freeze racing the producers must only ever observe
fully-published events.  Also covers the deferred stack-interning rule
(paper §4.2: stacks only for critical slices) and the EventRing torn-row
regression.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (EventRing, LockedTracer, Tracer, compute_numpy,
                        detect_offline)


def _hammer(tracer, wid, iters, tags=("step", "io", "net")):
    h = tracer.handle(wid)
    for i in range(iters):
        with h.span(tags[i % len(tags)]):
            pass


def test_concurrent_span_capture_matches_oracle():
    nt, iters = 4, 3000
    tr = Tracer(n_min=2.0, capacity=1 << 16)
    wids = [tr.register_worker(f"t{i}") for i in range(nt)]
    threads = [threading.Thread(target=_hammer, args=(tr, w, iters))
               for w in wids]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # every event accounted for: no drops, no tears
    assert tr.ring.dropped == 0
    assert tr.ring.dropped_per_shard() == [0] * nt
    log = tr.freeze()
    assert len(log) == 2 * nt * iters
    log.validate()                    # sorted, alternating per worker
    # online (batched fold) state == numpy oracle on the merged log, exactly
    res = compute_numpy(log)
    np.testing.assert_array_equal(res.per_worker, tr.per_worker_cm())
    assert res.idle_time == tr.idle_time
    # and the whole offline pipeline agrees on the critical set
    rep = detect_offline(log, tr.tags, tr.stacks, tr._resolved_n_min(),
                         worker_names=tr.worker_names())
    assert rep.total_slices == nt * iters
    assert rep.total_critical == len(tr.critical)
    np.testing.assert_array_equal(rep.per_worker, tr.per_worker_cm())


def test_concurrent_capture_with_autoflush_pressure():
    """Tiny shards force mid-run drains while producers keep appending;
    nothing may be lost or reordered badly enough to fail validation."""
    nt, iters = 3, 2000
    tr = Tracer(n_min=0.0, capacity=256)      # shard = 256 events
    wids = [tr.register_worker(f"t{i}") for i in range(nt)]
    threads = [threading.Thread(target=_hammer, args=(tr, w, iters))
               for w in wids]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    log = tr.freeze()
    # full accounting: stored + ring-dropped + tolerance-dropped (an end
    # whose begin was ring-dropped is removed by the §3.2 filter at flush)
    assert (len(log) + tr.ring.dropped + tr.tolerance_dropped
            == 2 * nt * iters)
    log.validate()
    res = compute_numpy(log)
    np.testing.assert_array_equal(res.per_worker, tr.per_worker_cm())


def test_freeze_races_producers_without_tearing():
    """freeze() while producers are mid-flight: every observed event is
    fully published (valid worker/delta/timestamp), never a torn row."""
    tr = Tracer(n_min=0.0, capacity=1 << 14)
    stop = threading.Event()
    wids = [tr.register_worker(f"t{i}") for i in range(3)]

    def spin(wid):
        h = tr.handle(wid)
        while not stop.is_set():
            h.begin("x")
            h.end()

    threads = [threading.Thread(target=spin, args=(w,)) for w in wids]
    for t in threads:
        t.start()
    try:
        for _ in range(20):
            log = tr.freeze()
            if len(log):
                assert np.all((log.workers >= 0) & (log.workers < 3))
                assert np.all(np.abs(log.deltas) == 1)
                assert np.all(np.diff(log.times) >= 0)
                assert np.all(log.times > 0)
            time.sleep(0.001)
    finally:
        stop.set()
        for t in threads:
            t.join()
    log = tr.freeze()
    log.validate()


def test_eventring_freeze_observes_only_published_rows():
    """Regression for the seed race: EventRing reserved the slot under the
    lock but stored the row after release, so freeze() could copy
    half-written events.  Now rows are stored inside the critical section —
    a racing freeze must never see a zero/default row below head."""
    ring = EventRing(capacity=1 << 14)
    stop = threading.Event()

    def producer(wid):
        i = 1
        while not stop.is_set():
            ring.append(i, wid, 1 if i % 2 else -1, tag=7, stack=9)
            i += 1

    threads = [threading.Thread(target=producer, args=(w,))
               for w in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(50):
            log = ring.freeze(4)
            if len(log):
                # a torn row would surface defaults: t=0, tag=-1, stack=-1
                assert np.all(log.times >= 1)
                assert np.all(log.tags == 7)
                assert np.all(log.stacks == 9)
                assert np.all(np.abs(log.deltas) == 1)
    finally:
        stop.set()
        for t in threads:
            t.join()


def test_noncritical_ends_intern_no_stacks():
    """Paper §4.2 regression: stacks are captured-by-reference at end() and
    interned only when the finished timeslice is critical — fully parallel
    work must allocate zero stack ids (the seed interned on every end)."""
    from tests.test_tracer import FakeClock
    clk = FakeClock()
    tr = Tracer(n_min=1.0, clock=clk)     # threads_av >= 1 always: nothing
    a = tr.register_worker("a")           # is ever critical
    b = tr.register_worker("b")
    for _ in range(50):
        tr.begin(a, "par")
        tr.begin(b, "par")
        clk.advance(10_000)
        tr.end(a)
        tr.end(b)
    tr.sync()
    assert len(tr.critical) == 0
    assert len(tr.stacks) == 0            # no stack ids allocated at all
    # the locked seed probe body interned one path per end()
    lt = LockedTracer(n_min=1.0, clock=FakeClock())
    la = lt.register_worker("a")
    lt.begin(la, "par")
    lt.end(la)
    assert len(lt.stacks) > 0

    # ... and when a slice IS critical, its path is interned on demand
    clk2 = FakeClock()
    tr2 = Tracer(n_min=1.5, clock=clk2)
    w = tr2.register_worker("w")
    tr2.register_worker("idle")
    tr2.begin(w, "serial")
    clk2.advance(10_000)
    tr2.end(w)
    tr2.sync()
    assert len(tr2.critical) == 1
    assert len(tr2.stacks) == 1
    path = tr2.stacks.paths[tr2.critical[0].stack_id]
    assert tr2.tags.names[path[-1]] == "serial"


def test_locked_and_sharded_tracers_agree():
    """The retained LockedTracer (seed probe body) and the sharded tracer
    produce the same per-worker CMetrics and critical count on the same
    deterministic schedule."""
    from tests.test_tracer import FakeClock

    def drive(tr):
        clk = tr.clock
        w = [tr.register_worker(f"w{i}") for i in range(3)]
        for rep in range(20):
            for wid in w:
                tr.begin(wid, "work")
                clk.advance(1_000)
            for wid in w:
                tr.end(wid)
                clk.advance(500)
            tr.begin(w[0], "solo")
            clk.advance(3_000)
            tr.end(w[0])
        return tr

    locked = drive(LockedTracer(n_min=1.5, clock=FakeClock()))
    sharded = drive(Tracer(n_min=1.5, clock=FakeClock()))
    np.testing.assert_allclose(sharded.per_worker_cm(),
                               locked.per_worker_cm(), rtol=1e-9)
    assert len(sharded.critical) == len(locked.critical)
    # the locked body accrues dt from raw ns, the fold from rebased seconds
    # (the oracle's arithmetic) — equal only up to float association
    assert sharded.idle_time == pytest.approx(locked.idle_time, rel=1e-9)
